"""ISO deep-dive demo: split policies, multi-chunk pipelines, int8 comm, and
the structural overlap evidence from lowered HLO.

    PYTHONPATH=src python examples/iso_prefill_demo.py
"""
import jax
import jax.numpy as jnp

from repro.config import ISOConfig, ModelConfig, get_model_config
from repro.core.chunking import split_chunks
from repro.core.overlap import AxisCtx
from repro.models import api
from repro.perf.model import prefill_time

cfg = ModelConfig(name="demo", family="dense", num_layers=2, d_model=128,
                  num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=1024,
                  qk_norm=True)
key = jax.random.PRNGKey(0)
params = api.init_params(key, cfg, tp=1, dtype=jnp.float32)
ctx = AxisCtx()
batch = api.make_inputs(cfg, 768, 1, key=key, dtype=jnp.float32)
ref = api.prefill(params, cfg, ctx, ISOConfig(enabled=False), batch)

print("=== split policies (all exact) ===")
for policy in ("even", "asymmetric", "adaptive"):
    for n in (2, 3, 4):
        iso = ISOConfig(enabled=True, num_chunks=n, split_policy=policy,
                        min_chunk_tokens=32, chunk_align=32)
        out = api.prefill(params, cfg, ctx, iso, batch)
        d = float(jnp.max(jnp.abs(ref["logits_local"] - out["logits_local"])))
        print(f"  {policy:10s} n={n}: chunks={out['chunk_lengths']} "
              f"maxdiff={d:.1e}")
        assert d < 1e-4

print("\n=== analytic pipeline times, paper-70b @ 32k prefill ===")
p70 = get_model_config("paper-70b")
for hw, tp in (("4090", 8), ("a800", 8), ("v5e", 16)):
    base = prefill_time(p70, 32768, hw, tp, iso=False)
    rows = []
    for n in (2, 3, 4):
        iso = ISOConfig(enabled=True, num_chunks=n)
        lengths = split_chunks(32768, iso, p70, tp=tp)
        t = prefill_time(p70, 32768, hw, tp, lengths=lengths)
        rows.append(f"n={n}: -{100 * (1 - t / base):.1f}%")
    print(f"  {hw:5s} tp={tp:2d}  base={base * 1e3:7.1f}ms  " + "  ".join(rows))
print("\n(multi-chunk n>2 is this repo's beyond-paper extension: deeper "
      "pipeline, smaller exposed head/tail bubbles)")

"""End-to-end serving driver (deliverable b): Engine with continuous batching,
ISO prefill, batched decode — multiple synthetic requests, ISO on vs off.

    PYTHONPATH=src python examples/serve_batch.py [--arch hymba-1.5b]
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if not any(a.startswith("--arch") for a in argv):
        argv = ["--arch", "qwen3-4b", "--requests", "5", "--prompt-len", "96",
                "--max-new", "12"] + argv
    raise SystemExit(main(argv))

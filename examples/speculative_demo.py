"""Speculative decoding demo (paper §Discussion): self-drafted K-token verify
cuts model calls per generated token while the output stream stays exactly
greedy.  On comm-bound platforms the K-token verify step also moves decode into
the regime where ISO-style overlap pays (the paper's motivation).

    PYTHONPATH=src python examples/speculative_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import Config, ISOConfig, ModelConfig, ParallelConfig
from repro.models import api
from repro.serving import Engine, Request
from repro.serving.requests import SamplingParams

cfg = ModelConfig(name="spec-demo", family="dense", num_layers=2, d_model=128,
                  num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=64,
                  qk_norm=True)
config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                iso=ISOConfig(enabled=True, num_chunks=2, min_chunk_tokens=16,
                              chunk_align=8))
params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1, dtype=jnp.float32)

rng = np.random.default_rng(1)
base = rng.integers(2, 64, 6).astype(np.int32)
prompt = np.tile(base, 6)                  # repetitive -> draftable

for spec_k in (0, 3):
    eng = Engine(config, params, mesh=None, max_batch=1, max_len=256,
                 bucket=16, spec_k=spec_k)
    rid = eng.add_request(Request(prompt=prompt.copy(),
                                  sampling=SamplingParams(max_new_tokens=24,
                                                          eos_id=-1)))
    outs = eng.run_until_complete()
    m = eng.metrics
    label = f"spec_k={spec_k}" if spec_k else "plain  "
    print(f"{label}: 24 tokens in {m['decode_calls']} model calls "
          f"(accepted drafts: {m['spec_accepted']})")
    if spec_k == 0:
        plain = outs[rid]
    else:
        assert outs[rid] == plain, "speculative stream diverged!"
        print("output streams identical — speculation is exact")

"""End-to-end training driver (deliverable b): trains a reduced qwen3-family
model on the synthetic LM pipeline with the full distributed train step
(AdamW, grad clip, cosine schedule, checkpointing).

Defaults are sized for this single-CPU container; on a real mesh use
``--preset 100m --steps 300 --data 16 --model 16``.

    PYTHONPATH=src python examples/train_small.py [--steps 40]
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if not any(a.startswith("--arch") for a in argv):
        argv = ["--arch", "qwen3-4b", "--preset", "tiny", "--steps", "40",
                "--seq-len", "128", "--batch", "4", "--log-every", "5"] + argv
    raise SystemExit(main(argv))

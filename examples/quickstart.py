"""Quickstart: build a small model, run baseline vs ISO prefill, verify the
paper's invariant, and show the analytic speedup the schedule buys on real HW.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.config import Config, ISOConfig, ModelConfig, ParallelConfig
from repro.core.overlap import AxisCtx
from repro.models import api
from repro.perf.model import speedup_table

cfg = ModelConfig(name="quickstart", family="dense", num_layers=4, d_model=256,
                  num_heads=8, num_kv_heads=4, d_ff=1024, vocab_size=4096,
                  qk_norm=True)
key = jax.random.PRNGKey(0)
params = api.init_params(key, cfg, tp=1, dtype=jnp.float32)
ctx = AxisCtx()                     # single device; collectives no-op

batch = api.make_inputs(cfg, seq_len=512, global_batch=2, key=key,
                        dtype=jnp.float32)

baseline = api.prefill(params, cfg, ctx, ISOConfig(enabled=False), batch)
iso = api.prefill(params, cfg, ctx,
                  ISOConfig(enabled=True, num_chunks=2, min_chunk_tokens=64),
                  batch)

diff = float(jnp.max(jnp.abs(baseline["logits_local"] - iso["logits_local"])))
print(f"chunks: baseline={baseline['num_chunks']} iso={iso['num_chunks']} "
      f"({iso['chunk_lengths']})")
print(f"ISO exactness: max |logits_baseline - logits_iso| = {diff:.2e}")
assert diff < 1e-4

print("\nAnalytic prefill-latency reduction from the ISO schedule "
      "(paper Table 1 shape):")
for hw, tp, int8 in (("4090", 4, True), ("a800", 8, False), ("v5e", 16, False)):
    tbl = speedup_table(cfg, hw, tp, [4096, 16384, 65536], int8_comm=int8)
    row = "  ".join(f"{s//1024}k: {r:5.1f}%" for s, r in tbl.items())
    print(f"  {hw:5s} tp={tp:2d}  {row}")

"""Paper §6 / Figure 3: split-policy comparison through the analytic model —
even vs asymmetric (60/40) vs adaptive (cost-balancing) vs auto (simulated
search), per platform.  Derived column = simulated prefill time reduction vs
baseline for each policy."""
from __future__ import annotations

from repro.config import ISOConfig, get_model_config
from repro.core.chunking import split_chunks
from repro.perf.model import prefill_time


def run(emit):
    seq = 16384
    results = {}
    for hw, tp in (("4090", 8), ("a800", 8), ("v5e", 16)):
        cfg = get_model_config("paper-70b")
        base = prefill_time(cfg, seq, hw, tp, iso=False)
        for policy in ("even", "asymmetric", "adaptive", "auto"):
            iso = ISOConfig(enabled=True, num_chunks=2, split_policy=policy)
            lengths = split_chunks(seq, iso, cfg, tp=tp, hw_name=hw)
            t = prefill_time(cfg, seq, hw, tp, lengths=lengths)
            red = 100 * (1 - t / base)
            results[(hw, policy)] = red
            emit(f"split/{hw}/{policy}", t * 1e6,
                 f"lengths={lengths};reduction={red:.1f}%")
    # adaptive/auto must never lose to even (they can fall back to it)
    for hw in ("4090", "a800", "v5e"):
        assert results[(hw, "auto")] >= results[(hw, "even")] - 0.2, hw
    return results

"""Paper Table 1: % reduction in prefill duration, model x platform x prompt
length — reproduced through the analytic pipeline model (perf/model.py), since
this container has no GPUs.  The model carries the paper's two frictions
(compute penalty under concurrent comm on A800-class parts; int8 wire on 4090)
and must land in the paper's bands: ~35% avg on 4090, ~15% avg on A800 for
prompts >= 4k."""
from __future__ import annotations

from repro.config import get_model_config
from repro.perf.model import speedup_table

ROWS = [
    ("4090-4c", "paper-30b", "4090", 4, True,
     [1024, 2048, 4096, 8192, 16384, 32768]),
    ("4090-4c", "paper-70b", "4090", 4, True,
     [1024, 2048, 4096, 8192, 16384, 32768]),
    ("4090-8c", "paper-30b", "4090", 8, True,
     [1024, 2048, 4096, 8192, 16384, 32768, 65536]),
    ("4090-8c", "paper-70b", "4090", 8, True,
     [1024, 2048, 4096, 8192, 16384, 32768, 65536]),
    ("a800-4c", "paper-30b", "a800", 4, False,
     [1024, 2048, 4096, 8192, 16384, 32768, 65536, 131072]),
    ("a800-4c", "paper-70b", "a800", 4, False,
     [1024, 2048, 4096, 8192, 16384, 32768, 65536, 131072]),
    ("a800-8c", "paper-30b", "a800", 8, False,
     [1024, 2048, 4096, 8192, 16384, 32768, 65536, 131072]),
    ("a800-8c", "paper-70b", "a800", 8, False,
     [1024, 2048, 4096, 8192, 16384, 32768, 65536, 131072]),
]

# paper Table 1 (percent), for side-by-side comparison
PAPER = {
    ("4090-4c", "paper-30b"): {1024: 38, 2048: 42, 4096: 43, 8192: 44,
                               16384: 47, 32768: 48},
    ("4090-4c", "paper-70b"): {1024: 43, 2048: 44, 4096: 45, 8192: 46,
                               16384: 47, 32768: 46},
    ("4090-8c", "paper-30b"): {1024: 11, 2048: 10, 4096: 18, 8192: 21,
                               16384: 30, 32768: 33, 65536: 36},
    ("4090-8c", "paper-70b"): {1024: 14, 2048: 19, 4096: 22, 8192: 23,
                               16384: 35, 32768: 42, 65536: 39},
    ("a800-4c", "paper-30b"): {1024: 0, 2048: 8, 4096: 18, 8192: 11,
                               16384: 12, 32768: 9, 65536: 10, 131072: 5},
    ("a800-4c", "paper-70b"): {1024: -6, 2048: 2, 4096: 8, 8192: 10,
                               16384: 9, 32768: 8, 65536: 8, 131072: 3},
    ("a800-8c", "paper-30b"): {1024: 8, 2048: 24, 4096: 22, 8192: 20,
                               16384: 16, 32768: 25, 65536: 11, 131072: 10},
    ("a800-8c", "paper-70b"): {1024: 3, 2048: 9, 4096: 14, 8192: 15,
                               16384: 16, 32768: 15, 65536: 14, 131072: 7},
}


def run(emit):
    band_4090, band_a800 = [], []
    for platform, model, hw, tp, int8, lengths in ROWS:
        cfg = get_model_config(model)
        ours = speedup_table(cfg, hw, tp, lengths, int8_comm=int8)
        paper = PAPER[(platform, model)]
        for s in lengths:
            emit(f"table1/{platform}/{model}/{s}", 0.0,
                 f"ours={ours[s]:.1f}%;paper={paper.get(s, float('nan'))}%")
            if s >= 4096:
                (band_4090 if hw == "4090" else band_a800).append(ours[s])
    avg4090 = sum(band_4090) / len(band_4090)
    avga800 = sum(band_a800) / len(band_a800)
    emit("table1/avg_4090_ge4k", 0.0,
         f"ours={avg4090:.1f}%;paper~35%;band=[25,50]")
    emit("table1/avg_a800_ge4k", 0.0,
         f"ours={avga800:.1f}%;paper~15%;band=[5,25]")
    assert 25 <= avg4090 <= 50, avg4090
    assert 5 <= avga800 <= 25, avga800

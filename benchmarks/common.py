"""Shared benchmark timing helpers."""
from __future__ import annotations

import time

import jax


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")

"""Shared benchmark timing helpers + the BENCH field schema.

``HEADLINE_FIELDS`` is the single source of truth for the headline metrics
lifted out of engine-bench rows into top-level ``BENCH_pr.json`` fields:
which row carries each metric, which ``derived`` key holds it, how to cast
it, which direction is better, and the regression tolerances the CI gate
(benchmarks/check_regression.py) applies against ``BENCH_baseline.json``.
``ci_smoke.py`` lifts fields through :func:`lift_headlines`; the gate reads
the same table — one schema, no drift between writer and checker.
"""
from __future__ import annotations

import json
import time
from typing import Any, Dict, Sequence

import jax


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")


# ---------------------------------------------------------------------------
# BENCH headline-field schema
# ---------------------------------------------------------------------------
# field -> {row: engine-bench row name, key: derived key, cast: float|int,
#           default, better: "higher"|"lower"|None (None = informational,
#           never gated), rel_tol/abs_tol: a PR value passes the regression
#           gate when it is within EITHER tolerance of baseline in the bad
#           direction (CPU CI runners are noisy; tolerances are deliberately
#           loose — the gate catches cliffs, not jitter)}

HEADLINE_FIELDS: Dict[str, Dict[str, Any]] = {
    "accepted_per_call": {
        "row": "engine/speculative", "key": "accepted_per_call",
        "cast": float, "default": 0.0, "better": "higher",
        "rel_tol": 0.15, "abs_tol": 0.25},
    "prefill_call_reduction": {
        "row": "engine/batched_prefill_4", "key": "call_reduction",
        "cast": float, "default": 0.0, "better": "higher",
        "rel_tol": 0.15, "abs_tol": 0.25},
    "decode_split_speedup": {
        "row": "engine/decode_split_128", "key": "split_speedup",
        "cast": float, "default": 0.0, "better": "higher",
        "rel_tol": 0.10, "abs_tol": 0.10},
    "overlap_efficiency": {
        "row": "engine/observability", "key": "overlap_efficiency",
        "cast": float, "default": 0.0, "better": "higher",
        "rel_tol": 0.50, "abs_tol": 0.25},
    "obs_overhead_pct": {
        "row": "engine/observability", "key": "obs_overhead_pct",
        "cast": float, "default": 0.0, "better": "lower",
        "rel_tol": 1.0, "abs_tol": 15.0},
    # informational (better=None): latency/occupancy depend on runner load;
    # recorded per push for the trajectory, never gated
    "ladder_speedup": {
        # sequential/ladder decode-step ratio from the schedule probe; a
        # PROXY on the standard-wired bench engine (the probe times the
        # ladder-rewired twin at identical shapes) and noise-bound on a CPU
        # runner where there is no collective to hide — informational until
        # a multi-device perf lane exists to gate it
        "row": "engine/observability", "key": "ladder_speedup",
        "cast": float, "default": 0.0, "better": None},
    "overlap_efficiency_ladder": {
        "row": "engine/observability", "key": "overlap_efficiency_ladder",
        "cast": float, "default": 0.0, "better": None},
    "ttft_p50": {
        "row": "engine/observability", "key": "ttft_p50",
        "cast": float, "default": 0.0, "better": None},
    "ttft_p99": {
        "row": "engine/observability", "key": "ttft_p99",
        "cast": float, "default": 0.0, "better": None},
    "pool_occupancy_peak": {
        "row": "engine/observability", "key": "pool_occupancy_peak",
        "cast": int, "default": 0, "better": None},
    # disaggregated prefill/decode: page-migration volume and host-side
    # transfer cost on the standard mixed workload (informational — both
    # track workload shape, not a speedup; the bench asserts token equality)
    "migrated_pages": {
        "row": "engine/disagg", "key": "migrated_pages",
        "cast": int, "default": 0, "better": None},
    "migration_us": {
        "row": "engine/disagg", "key": "migration_us",
        "cast": float, "default": 0.0, "better": None},
}


def parse_derived(derived: str) -> Dict[str, str]:
    """``"a=1;b=2"`` -> ``{"a": "1", "b": "2"}`` (the engine-bench ``derived``
    column convention; parts without ``=`` are skipped)."""
    out: Dict[str, str] = {}
    for part in derived.split(";"):
        k, eq, v = part.partition("=")
        if eq:
            out[k.strip()] = v.strip()
    return out


def lift_headlines(rows: Sequence[Dict[str, Any]]) -> Dict[str, Any]:
    """Lift every ``HEADLINE_FIELDS`` metric out of engine-bench rows
    (``{"name", "us_per_call", "derived"}``) into a flat field dict.
    Missing rows/keys yield the field's default — a bench subset run still
    produces a schema-complete document."""
    by_name = {row["name"]: parse_derived(row.get("derived", ""))
               for row in rows}
    out: Dict[str, Any] = {}
    for field, spec in HEADLINE_FIELDS.items():
        raw = by_name.get(spec["row"], {}).get(spec["key"])
        try:
            out[field] = spec["cast"](raw) if raw is not None \
                else spec["default"]
        except ValueError:
            out[field] = spec["default"]
    return out


def write_json(doc: Any, path: str) -> str:
    """The one JSON writer every bench artifact goes through (stable
    formatting → clean diffs for committed baselines)."""
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return path

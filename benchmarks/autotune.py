"""Offline autotune entrypoint: profile this machine, emit a cost table.

Runs the three measurement sweeps in ``repro.perf.costmodel`` (alpha-beta
psum probe, per-bucket prefill timings, per-(K, S) decode-depth timings)
against a real ``PagedEngine`` built from ``--arch``, and writes the
versioned per-platform JSON table the serving stack loads through
``ServingConfig.cost_table``:

    PYTHONPATH=src python -m benchmarks.autotune \
        --arch qwen3-4b --reduce tiny --out src/repro/perf/tables/cpu_tp1.json

``--smoke`` shrinks every sweep to the CI-sized subset (same schema, fewer
points) — the ci.yml ``autotune-table`` lane runs exactly:

    python -m benchmarks.autotune --smoke --out cost_table.json --verify

``--verify`` re-serves a mixed-traffic workload (prefix sharing + chunked
prefill + speculation) twice — static defaults vs the just-emitted table —
and asserts the token streams are IDENTICAL.  Decisions may differ (that is
the point); tokens may not, because every decision axis is token-neutral by
construction (chunk boundaries are exact splits, pack width and split count
are call-grouping only, skipping speculation is the plain-decode path).
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def _build(arch: str, reduce: str, tp: int, spec_k: int):
    import jax
    import jax.numpy as jnp

    from repro.config import (Config, ISOConfig, ParallelConfig,
                              ServingConfig, get_model_config)
    from repro.launch.train import reduce_cfg
    from repro.models import api

    cfg = get_model_config(arch)
    if reduce:
        cfg = reduce_cfg(cfg, reduce)
    iso = ISOConfig(enabled=True, num_chunks=2, min_chunk_tokens=16,
                    chunk_align=16)
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=tp),
                    iso=iso,
                    serving=ServingConfig(page_size=16, max_batch=4,
                                          max_len=160,
                                          prefill_token_budget=64,
                                          spec_k=spec_k))
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=tp,
                             dtype=jnp.float32)
    return config, params


def _serve_tokens(config, params, cost_model=None):
    """Mixed traffic (repetitive + random + shared-prefix pair) through a
    fresh engine; returns (token streams, decision-event count)."""
    import dataclasses

    from repro.serving import PagedEngine, Request
    from repro.serving.requests import SamplingParams

    sv = dataclasses.replace(config.serving, cost_model=cost_model)
    eng = PagedEngine(config, params, serving=sv)
    rng = np.random.default_rng(11)
    V = config.model.vocab_size
    base = rng.integers(2, V, 6).astype(np.int32)
    shared = rng.integers(2, V, 32).astype(np.int32)
    prompts = [
        np.tile(base, 8)[:44],
        rng.integers(2, V, 57).astype(np.int32),
        np.concatenate([shared, rng.integers(2, V, 9).astype(np.int32)]),
        np.concatenate([shared, rng.integers(2, V, 5).astype(np.int32)]),
    ]
    rids = [eng.add_request(Request(
        prompt=p, sampling=SamplingParams(max_new_tokens=8, eos_id=-1)))
        for p in prompts]
    outs = eng.run_until_complete()
    decisions = sum(1 for e in eng.trace.events() if e.kind == "decision")
    return [outs[r] for r in rids], decisions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduce", default="tiny",
                    help="launch.train.reduce_cfg preset ('' = full size)")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--spec-k", type=int, default=2,
                    help="spec window the decode sweep measures (K=spec_k+1)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweeps (same schema, fewer points)")
    ap.add_argument("--out", default=None,
                    help="table path (default: the bundled per-platform "
                         "location under src/repro/perf/tables/)")
    ap.add_argument("--verify", action="store_true",
                    help="assert model-driven serving is token-identical "
                         "to static defaults with the emitted table")
    args = ap.parse_args(argv)

    import jax

    from repro.perf.costmodel import (CostModel, autotune,
                                      default_table_path, write_table)

    assert args.tp == 1 or jax.device_count() >= args.tp, \
        f"--tp {args.tp} needs {args.tp} devices, have {jax.device_count()}"
    config, params = _build(args.arch, args.reduce, args.tp, args.spec_k)
    mesh = None
    if args.tp > 1:
        from jax.sharding import Mesh
        mesh = Mesh(np.array(jax.devices()[:args.tp]).reshape(1, args.tp),
                    ("data", "model"))
    table = autotune(config, params, mesh=mesh, smoke=args.smoke,
                     log=lambda m: print(m, flush=True))
    out = args.out or default_table_path(table["platform"], args.tp)
    write_table(table, out)
    print(f"wrote {out}: {len(table['prefill_us'])} prefill + "
          f"{len(table['decode_us'])} decode points, "
          f"alpha={table['alpha_beta']['alpha_s']:.3e}s "
          f"beta={table['alpha_beta']['beta_s_per_byte']:.3e}s/B")

    if args.verify:
        static, _ = _serve_tokens(config, params, cost_model=None)
        modeled, decisions = _serve_tokens(config, params,
                                           cost_model=CostModel(table))
        assert modeled == static, \
            "model-driven serving diverged from static defaults!"
        print(f"verify OK: token-identical across {len(static)} requests "
              f"({decisions} model decisions taken)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Reproduce the EXPERIMENTS.md §Perf hillclimb ledgers (H1/H2/H3).

Standalone (takes ~10 min of compiles; not part of `benchmarks.run`):

    PYTHONPATH=src python -m benchmarks.perf_ledger

CI runs the ``--smoke`` subset (one ledger, two variants) and ``--json`` dumps
the rows for the bench-smoke artifact (benchmarks/ci_smoke.py).
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

LEDGERS = [
    ("H1: kimi-k2-1t-a32b x train_4k", "kimi-k2-1t-a32b", "train_4k", [
        ("baseline (paper-faithful ISO n=2)", {}),
        ("int8 DP grads", {"grad_int8": True}),
        ("+ int8 TP collectives", {"grad_int8": True, "quantized": True}),
        ("ZeRO-1 + int8 TP", {"zero1": True, "quantized": True}),
    ]),
    ("H2: qwen3-32b x prefill_32k", "qwen3-32b", "prefill_32k", [
        ("baseline", {}),
        ("XLA blockwise attention", {"blockwise_attn": True}),
        ("int8 TP collectives", {"quantized": True}),
    ]),
    ("H3: qwen3-8b x prefill_32k", "qwen3-8b", "prefill_32k", [
        ("baseline", {}),
        ("int8 TP collectives", {"quantized": True}),
        ("+ blockwise attention", {"quantized": True, "blockwise_attn": True}),
    ]),
]

# CI bench-smoke subset: one prefill ledger, baseline + one lever — enough to
# keep the perf trajectory populated without the full ~10 min of compiles
SMOKE_LEDGERS = [
    ("H3: qwen3-8b x prefill_32k", "qwen3-8b", "prefill_32k", [
        ("baseline", {}),
        ("int8 TP collectives", {"quantized": True}),
    ]),
]


def run_ledgers(ledgers):
    """Lower + roofline every (ledger, variant); returns structured rows."""
    from repro.launch.dryrun import lower_shape
    rows = []
    for title, arch, shape, variants in ledgers:
        print(f"\n=== {title} ===")
        print(f"{'variant':38s} {'compute':>10s} {'memory<=':>10s} "
              f"{'collective':>11s}")
        for label, kw in variants:
            r = lower_shape(arch, shape, verbose=False, **kw)
            ro = r["roofline"]
            print(f"{label:38s} {ro['compute_s']:10.3g} {ro['memory_s']:10.3g} "
                  f"{ro['collective_s']:11.3g}")
            rows.append({"ledger": title, "arch": arch, "shape": shape,
                         "variant": label,
                         "compute_s": float(ro["compute_s"]),
                         "memory_s": float(ro["memory_s"]),
                         "collective_s": float(ro["collective_s"])})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: one ledger, two variants")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="dump the rows as JSON")
    args = ap.parse_args(argv)
    rows = run_ledgers(SMOKE_LEDGERS if args.smoke else LEDGERS)
    if args.json:
        from benchmarks.common import write_json
        write_json(rows, args.json)


if __name__ == "__main__":
    main()

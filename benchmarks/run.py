"""Benchmark harness — one module per paper table/figure + system benches.
Prints ``name,us_per_call,derived`` CSV.  ``python -m benchmarks.run [names]``"""
from __future__ import annotations

import sys
import traceback

from benchmarks.common import emit


def main() -> None:
    from benchmarks import (asymmetry, engine_bench, kernel_bench,
                            overlap_micro, roofline_table, split_policies,
                            table1_prefill)
    suites = {
        "table1": table1_prefill.run,        # paper Table 1
        "asymmetry": asymmetry.run,          # paper Figure 2
        "split": split_policies.run,         # paper Figure 3 / §6
        "overlap": overlap_micro.run,        # Figure 1 structure (HLO-level)
        "roofline": roofline_table.run,      # §Roofline source table
        "kernels": kernel_bench.run,
        "engine": engine_bench.run,
    }
    names = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    failed = []
    for n in names:
        try:
            suites[n](emit)
        except Exception:  # noqa: BLE001
            failed.append(n)
            traceback.print_exc()
            emit(f"{n}/FAILED", 0.0, "see stderr")
    if failed:
        raise SystemExit(f"benchmark suites failed: {failed}")


if __name__ == '__main__':
    main()

"""End-to-end serving wall-clock on CPU with a reduced model: ISO on vs off.
On CPU there is no collective to hide, so the derived column reports the
CORRECTNESS-preserving overhead of the chunked schedule (paper: the split cost
that longer prompts amortise) plus tokens/s."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import Config, ISOConfig, ParallelConfig, get_model_config
from repro.launch.train import reduce_cfg
from repro.models import api
from repro.serving import Engine, Request
from repro.serving.requests import SamplingParams


def _run(cfg, iso, n_req=3, plen=96, new=8):
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    iso=iso)
    # fp32 so greedy argmax is insensitive to the (valid) fp reassociation the
    # chunked schedule introduces — the token-equality check below is exact
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    eng = Engine(config, params, mesh=None, max_batch=2,
                 max_len=plen + new + 8, bucket=32)
    rng = np.random.default_rng(0)
    rids = []
    for i in range(n_req):
        rids.append(eng.add_request(Request(
            prompt=rng.integers(2, cfg.vocab_size, plen).astype(np.int32),
            sampling=SamplingParams(max_new_tokens=new, eos_id=-1))))
    t0 = time.perf_counter()
    outs = eng.run_until_complete()
    wall = time.perf_counter() - t0
    # rids are globally monotonic across engines: compare by submission order
    return [outs[r] for r in rids], wall, eng.metrics


def run(emit):
    cfg = reduce_cfg(get_model_config("qwen3-4b"), "tiny")
    out_b, wall_b, m_b = _run(cfg, ISOConfig(enabled=False))
    out_i, wall_i, m_i = _run(cfg, ISOConfig(enabled=True, num_chunks=2,
                                             min_chunk_tokens=16,
                                             chunk_align=16))
    assert out_b == out_i, "ISO changed generated tokens!"
    emit("engine/baseline", wall_b * 1e6,
         f"prefill_s={m_b['prefill_s']:.2f};completed={m_b['completed']}")
    emit("engine/iso2", wall_i * 1e6,
         f"prefill_s={m_i['prefill_s']:.2f};completed={m_i['completed']};"
         f"tokens_equal=True")

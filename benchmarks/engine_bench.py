"""End-to-end serving wall-clock on CPU with a reduced model: ISO on vs off,
and paged-vs-dense engines.  On CPU there is no collective to hide, so the
derived columns report the CORRECTNESS-preserving overhead of the chunked
schedule (paper: the split cost that longer prompts amortise), tokens/s, and —
for the paged mode — the KV memory footprint and time-to-first-token with
chunked-prefill interleaving enabled."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import (Config, ISOConfig, ParallelConfig, ServingConfig,
                          get_model_config)
from repro.launch.train import reduce_cfg
from repro.models import api
from repro.serving import Engine, PagedEngine, Request
from repro.serving.requests import SamplingParams


def _run(cfg, iso, n_req=3, plen=96, new=8):
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    iso=iso)
    # fp32 so greedy argmax is insensitive to the (valid) fp reassociation the
    # chunked schedule introduces — the token-equality check below is exact
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    eng = Engine(config, params, mesh=None, max_batch=2,
                 max_len=plen + new + 8, bucket=32)
    rng = np.random.default_rng(0)
    rids = []
    for i in range(n_req):
        rids.append(eng.add_request(Request(
            prompt=rng.integers(2, cfg.vocab_size, plen).astype(np.int32),
            sampling=SamplingParams(max_new_tokens=new, eos_id=-1))))
    t0 = time.perf_counter()
    outs = eng.run_until_complete()
    wall = time.perf_counter() - t0
    # rids are globally monotonic across engines: compare by submission order
    return [outs[r] for r in rids], wall, eng.metrics


def _run_paged(cfg, iso, params, *, lengths, new=8, budget=48, page_size=16,
               max_len=0, shared_prefix=0, prefix_sharing=True, spec_k=0,
               repetitive=False, max_batch=2, prefill_batching=True):
    max_len = max_len or (max(lengths) + new + 8)
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    iso=iso,
                    serving=ServingConfig(page_size=page_size,
                                          max_batch=max_batch,
                                          max_len=max_len,
                                          prefill_token_budget=budget,
                                          prefix_sharing=prefix_sharing,
                                          prefill_batching=prefill_batching,
                                          spec_k=spec_k))
    eng = PagedEngine(config, params)
    rng = np.random.default_rng(0)
    system = rng.integers(2, cfg.vocab_size, shared_prefix).astype(np.int32) \
        if shared_prefix else None
    rids, peak_pages = [], 0
    for n in lengths:
        if repetitive:
            # looped base phrase: the bigram self-draft gets real acceptances
            base = rng.integers(2, cfg.vocab_size, 6).astype(np.int32)
            prompt = np.tile(base, -(-n // len(base)))[:n]
        else:
            prompt = rng.integers(2, cfg.vocab_size, n).astype(np.int32)
        if system is not None:
            prompt = np.concatenate([system, prompt[:max(n - len(system), 1)]])
        rids.append(eng.add_request(Request(
            prompt=prompt,
            sampling=SamplingParams(max_new_tokens=new, eos_id=-1))))
    t0 = time.perf_counter()
    while eng.scheduler.waiting or any(s is not None for s in eng.slots) or \
            not eng.metrics["steps"]:
        eng.step()
        peak_pages = max(peak_pages, eng.alloc.used_pages)
        if eng.metrics["steps"] > 10_000:
            break
    wall = time.perf_counter() - t0
    outs = {st.request.rid: st.generated for st in eng._finished}
    missing = [r for r in rids if r not in outs]
    assert not missing, \
        f"paged engine stalled on rids {missing}: metrics={eng.metrics}"
    return [outs[r] for r in rids], wall, eng, peak_pages


def run(emit):
    cfg = reduce_cfg(get_model_config("qwen3-4b"), "tiny")
    out_b, wall_b, m_b = _run(cfg, ISOConfig(enabled=False))
    iso2 = ISOConfig(enabled=True, num_chunks=2, min_chunk_tokens=16,
                     chunk_align=16)
    out_i, wall_i, m_i = _run(cfg, iso2)
    assert out_b == out_i, "ISO changed generated tokens!"
    emit("engine/baseline", wall_b * 1e6,
         f"prefill_s={m_b['prefill_s']:.2f};completed={m_b['completed']}")
    emit("engine/iso2", wall_i * 1e6,
         f"prefill_s={m_i['prefill_s']:.2f};completed={m_i['completed']};"
         f"tokens_equal=True")

    # ---- paged vs dense: mixed-length workload, chunked-prefill interleave
    lengths, new = (96, 48, 32), 8
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    iso=iso2)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    max_len = max(lengths) + new + 8
    dense = Engine(config, params, mesh=None, max_batch=2, max_len=max_len,
                   bucket=32)
    rng = np.random.default_rng(0)
    d_rids = [dense.add_request(Request(
        prompt=rng.integers(2, cfg.vocab_size, n).astype(np.int32),
        sampling=SamplingParams(max_new_tokens=new, eos_id=-1)))
        for n in lengths]
    t0 = time.perf_counter()
    d_outs = dense.run_until_complete()
    wall_d = time.perf_counter() - t0
    # dense footprint: every slot reserves max_len KV
    dense_kv = sum(l.size * l.dtype.itemsize
                   for c in dense.caches for k, l in c.items()
                   if k in ("k", "v"))

    p_outs, wall_p, peng, peak_pages = _run_paged(
        cfg, iso2, params, lengths=lengths, new=new, max_len=max_len)
    equal = [d_outs[r] for r in d_rids] == p_outs
    m = peng.metrics
    ttft_ms = 1e3 * m["ttft_sum"] / max(m["ttft_n"], 1)
    peak_kv = peak_pages * peng.kv.page_bytes()
    emit("engine/dense_cache", wall_d * 1e6,
         f"kv_bytes={dense_kv};completed={dense.metrics['completed']}")
    emit("engine/paged_cache", wall_p * 1e6,
         f"kv_bytes_peak={peak_kv};ttft_ms={ttft_ms:.1f};"
         f"prefill_calls={m['prefill_calls']};steps={m['steps']};"
         f"tokens_equal={equal}")
    assert equal, "paged engine changed generated tokens!"
    # grant-size bucketing: compiled-closure count stays O(#buckets) and the
    # compile-guard bound holds on this mixed-length trace
    compiles = peng.prefill_compile_count()
    bound = peng.max_prefill_compiles()
    assert bound is None or compiles <= bound, (compiles, bound)
    emit("engine/bucketed_prefill", wall_p * 1e6,
         f"prefill_compiles={compiles};compile_bound={bound};"
         f"pad_tokens={m['prefill_pad_tokens']};"
         f"buckets={len(peng._buckets or ())}")

    # ---- batched multi-request prefill grants -----------------------------
    # same-length bursts pack into one forward call per tick; the packed
    # stream must stay byte-identical to batch-1 while the prefill
    # forward-call count (and with it TTFT) drops.  The 4-wide ratio is the
    # headline lifted into BENCH_pr.json by benchmarks/ci_smoke.py.
    for n_pack in (1, 2, 4):
        bp_lengths = (64,) * n_pack
        outs_b1, wall_b1, eng_b1, _ = _run_paged(
            cfg, iso2, params, lengths=bp_lengths, new=new, budget=256,
            max_batch=4, prefix_sharing=False, prefill_batching=False)
        outs_bp, wall_bp, eng_bp, _ = _run_paged(
            cfg, iso2, params, lengths=bp_lengths, new=new, budget=256,
            max_batch=4, prefix_sharing=False, prefill_batching=True)
        assert outs_bp == outs_b1, \
            f"batched prefill changed generated tokens at {n_pack} grants!"
        m1, mp = eng_b1.metrics, eng_bp.metrics
        assert mp["prefill_grants"] == m1["prefill_grants"]
        ratio = m1["prefill_calls"] / max(mp["prefill_calls"], 1)
        ttft_b1 = 1e3 * m1["ttft_sum"] / max(m1["ttft_n"], 1)
        ttft_bp = 1e3 * mp["ttft_sum"] / max(mp["ttft_n"], 1)
        tps_b1 = m1["prefill_tokens"] / max(m1["prefill_s"], 1e-9)
        tps_bp = mp["prefill_tokens"] / max(mp["prefill_s"], 1e-9)
        emit(f"engine/batched_prefill_{n_pack}", wall_bp * 1e6,
             f"calls={mp['prefill_calls']};calls_batch1={m1['prefill_calls']};"
             f"call_reduction={ratio:.2f};ttft_ms={ttft_bp:.1f};"
             f"ttft_ms_batch1={ttft_b1:.1f};prefill_tok_s={tps_bp:.0f};"
             f"prefill_tok_s_batch1={tps_b1:.0f};tokens_equal=True")
        if n_pack == 4:
            assert ratio >= 2.0, \
                f"4 packed grants reduced prefill calls only {ratio:.2f}x"
            bound = eng_bp.max_prefill_compiles()
            assert eng_bp.prefill_compile_count() <= bound, \
                (eng_bp.prefill_compile_count(), bound)

    # ---- CoW prefix sharing: shared-system-prompt workload ----------------
    sh_lengths = (96, 96, 96)
    outs_on, wall_on, eng_on, peak_on = _run_paged(
        cfg, iso2, params, lengths=sh_lengths, new=new, max_len=max_len,
        shared_prefix=64, prefix_sharing=True)
    outs_off, wall_off, eng_off, peak_off = _run_paged(
        cfg, iso2, params, lengths=sh_lengths, new=new, max_len=max_len,
        shared_prefix=64, prefix_sharing=False)
    assert outs_on == outs_off, "prefix sharing changed generated tokens!"
    m_on = eng_on.metrics
    emit("engine/prefix_shared", wall_on * 1e6,
         f"kv_bytes_peak={peak_on * eng_on.kv.page_bytes()};"
         f"pages_peak={peak_on};pages_peak_unshared={peak_off};"
         f"shared_tokens={m_on['prefix_shared_tokens']};"
         f"cow_copies={m_on['cow_copies']};tokens_equal=True")
    assert peak_on < peak_off, "sharing saved no pages on a shared workload"

    # ---- speculative decoding: K-token verify through the paged kernel ----
    # repetitive prompts so the bigram self-draft actually hits; the spec
    # stream must be token-identical to the plain greedy stream
    sp_lengths, sp_new = (48, 48), 24
    outs_plain, wall_plain, _, _ = _run_paged(
        cfg, iso2, params, lengths=sp_lengths, new=sp_new, repetitive=True)
    outs_spec, wall_spec, eng_spec, _ = _run_paged(
        cfg, iso2, params, lengths=sp_lengths, new=sp_new, repetitive=True,
        spec_k=3)
    assert outs_spec == outs_plain, "speculation changed generated tokens!"
    m_sp = eng_spec.metrics
    apc = eng_spec.accepted_per_call()
    assert m_sp["spec_calls"] > 0 and apc > 1.0, \
        f"no speculative speedup on repetitive prompts: {m_sp}"
    emit("engine/speculative", wall_spec * 1e6,
         f"spec_k=3;verify_calls={m_sp['spec_calls']};"
         f"accepted_per_call={apc:.3f};"
         f"decode_calls={m_sp['decode_calls']};"
         f"decode_tokens={m_sp['decode_tokens']};tokens_equal=True")

    # ---- split-KV flash-decode: long-context sequence parallelism ---------
    _decode_split_section(emit)

    # ---- disaggregated prefill/decode: migration cost, per-phase latency --
    _disagg_section(cfg, iso2, params, emit)

    # ---- observability: overhead, latency percentiles, overlap probe ------
    _obs_section(cfg, iso2, params, emit)


def _decode_split_section(emit, kv_splits=4):
    """Split-KV vs sequential page walk at 8/32/128 resident pages.

    ``split_speedup`` is the MODELED decode critical-path ratio
    ``MB / (ceil(MB/S) + 1)``: a sequential walk chains MB dependent page
    steps, the split walk chains ceil(MB/S) per span (spans independent)
    plus one reduce step.  On this CPU container the Pallas interpreter
    executes the grid sequentially, so measured wall time CANNOT show the
    parallel win — it is reported alongside (wall_us_seq/wall_us_split) as
    an honesty check that the split adds no blow-up, while the modeled ratio
    is what real hardware parallelism delivers (ci_smoke lifts the 128-page
    row into BENCH_pr.json).  Numerics are asserted equal each depth."""
    from repro.kernels.flash_decode import flash_decode

    rng = np.random.default_rng(0)
    ps, hq, hkv, hd = 16, 4, 2, 32

    def _time(fn, *args, iters=5):
        fn(*args)[0].block_until_ready()          # compile outside the timer
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / iters

    for mb in (8, 32, 128):
        L = mb * ps
        k_pages = jnp.asarray(
            rng.standard_normal((mb + 1, ps, hkv, hd)), jnp.float32)
        v_pages = jnp.asarray(
            rng.standard_normal((mb + 1, ps, hkv, hd)), jnp.float32)
        bt = jnp.arange(mb, dtype=jnp.int32)[None]
        lens = jnp.asarray([L], jnp.int32)
        q = jnp.asarray(rng.standard_normal((1, hq, hd)), jnp.float32)

        seq_fn = jax.jit(lambda *a: flash_decode(*a, kv_splits=1))
        spl_fn = jax.jit(lambda *a: flash_decode(*a, kv_splits=kv_splits))
        args = (q, k_pages, v_pages, bt, lens)
        o_seq = seq_fn(*args)[0]
        o_spl = spl_fn(*args)[0]
        assert float(jnp.max(jnp.abs(o_seq - o_spl))) < 1e-5, \
            f"split-KV diverged at {mb} pages"
        wall_seq = _time(seq_fn, *args)
        wall_spl = _time(spl_fn, *args)
        depth_seq = mb
        depth_spl = -(-mb // kv_splits) + 1       # spans parallel + reduce
        speedup = depth_seq / depth_spl
        emit(f"engine/decode_split_{mb}", wall_spl * 1e6,
             f"split_speedup={speedup:.3f};pages={mb};kv_splits={kv_splits};"
             f"wall_us_seq={wall_seq * 1e6:.1f};"
             f"wall_us_split={wall_spl * 1e6:.1f};tokens_equal=True")


def _disagg_section(cfg, iso2, params, emit):
    """Disaggregated prefill/decode (serving/disagg.py) vs the single paged
    engine on the same mixed-length workload.  On one CPU host both layouts
    run the same math, so wall time is an honesty check, not the headline —
    the row reports what disaggregation actually changes: the page-migration
    volume and host transfer cost (lifted into BENCH_pr.json), plus the
    per-phase latency split (TTFT lives on the prefill engine, TPOT on the
    decode engine).  Token streams must be byte-identical."""
    from repro.serving import DisaggRouter

    lengths, new = (96, 48, 32), 8
    max_len = max(lengths) + new + 8
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    iso=iso2,
                    serving=ServingConfig(page_size=16, max_batch=2,
                                          max_len=max_len,
                                          prefill_token_budget=48))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, n).astype(np.int32)
               for n in lengths]

    def _submit(eng):
        return [eng.add_request(Request(
            prompt=p, sampling=SamplingParams(max_new_tokens=new, eos_id=-1)))
            for p in prompts]

    single = PagedEngine(config, params)
    s_rids = _submit(single)
    t0 = time.perf_counter()
    s_outs = single.run_until_complete()
    wall_s = time.perf_counter() - t0

    router = DisaggRouter(config, params)
    d_rids = _submit(router)
    t0 = time.perf_counter()
    d_outs = router.run_until_complete()
    wall_d = time.perf_counter() - t0

    equal = [s_outs[r] for r in s_rids] == [d_outs[r] for r in d_rids]
    assert equal, "disaggregation changed generated tokens!"
    ms = router.migration_stats()
    assert ms["pending_transfers"] == 0 and ms["migrated_requests"] >= \
        len(prompts), ms
    mp, md = router.prefill.metrics, router.decode.metrics
    m1 = single.metrics
    ttft_d = 1e3 * mp["ttft_sum"] / max(mp["ttft_n"], 1)
    ttft_s = 1e3 * m1["ttft_sum"] / max(m1["ttft_n"], 1)
    tpot_d = 1e3 * md["decode_s"] / max(md["decode_tokens"], 1)
    tpot_s = 1e3 * m1["decode_s"] / max(m1["decode_tokens"], 1)
    emit("engine/disagg", wall_d * 1e6,
         f"migrated_pages={ms['migrated_pages']};"
         f"migration_us={ms['migration_us']:.1f};"
         f"migrations={ms['migrations']};"
         f"migrated_requests={ms['migrated_requests']};"
         f"deferrals={ms['deferrals']};"
         f"ttft_ms_prefill={ttft_d:.1f};ttft_ms_single={ttft_s:.1f};"
         f"tpot_ms_decode={tpot_d:.2f};tpot_ms_single={tpot_s:.2f};"
         f"wall_us_single={wall_s * 1e6:.1f};tokens_equal={equal}")


def _steady_decode(cfg, iso, params, obs_on, timed_steps=30):
    """Engine in steady-state decode; returns (engine, median step wall,
    outputs).  Prefill and closure compilation happen before the timed
    region, so the median isolates per-step host+device work — the region
    the observability layer adds its bookkeeping to."""
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    iso=iso,
                    serving=ServingConfig(page_size=16, max_batch=2,
                                          max_len=160,
                                          prefill_token_budget=128,
                                          observability=obs_on))
    eng = PagedEngine(config, params)
    rng = np.random.default_rng(0)
    for i in range(2):
        eng.add_request(Request(
            prompt=rng.integers(2, cfg.vocab_size, 48).astype(np.int32),
            sampling=SamplingParams(max_new_tokens=timed_steps + 8,
                                    eos_id=-1)))
    while eng.scheduler.waiting or \
            any(s is not None and s.prefilled < sum(s.chunk_plan)
                for s in eng.slots):
        eng.step()
    for _ in range(3):                        # decode warm-up
        eng.step()
    times = []
    for _ in range(timed_steps):
        t0 = time.perf_counter()
        eng.step()
        times.append(time.perf_counter() - t0)
    outs = eng.run_until_complete()
    return eng, sorted(times)[len(times) // 2], outs


def _obs_section(cfg, iso2, params, emit):
    """Registry/trace overhead on the decode loop (obs on vs off), TTFT
    percentiles from the typed histogram, pool-occupancy peak, and the
    decode overlap-efficiency probe.  ci_smoke lifts these into first-class
    BENCH_pr.json fields."""
    eng_on, med_on, outs_on = _steady_decode(cfg, iso2, params, obs_on=True)
    eng_off, med_off, outs_off = _steady_decode(cfg, iso2, params,
                                                obs_on=False)
    # rids auto-increment globally, so compare streams in submission order
    toks_on = [outs_on[r] for r in sorted(outs_on)]
    toks_off = [outs_off[r] for r in sorted(outs_off)]
    assert toks_on == toks_off, "observability changed generated tokens!"
    overhead_pct = 100.0 * (med_on - med_off) / max(med_off, 1e-9)
    ttft = eng_on.registry.histogram("ttft")
    ovl = eng_on.measure_overlap_efficiency(iters=6, warmup=2)
    exp = ovl["exposed_comm_s"]
    assert len(eng_on.trace.events()) > 0 and eng_on.trace.dropped == 0
    assert len(eng_off.trace.events()) == 0, "obs off must silence the trace"
    emit("engine/observability", med_on * 1e6,
         f"obs_overhead_pct={overhead_pct:.2f};"
         f"ttft_p50={ttft.percentile(0.5):.4f};"
         f"ttft_p99={ttft.percentile(0.99):.4f};"
         f"pool_occupancy_peak={eng_on.metrics['peak_used_pages']};"
         f"overlap_efficiency={ovl['overlap_efficiency']:.4f};"
         f"ladder_speedup={ovl['ladder_speedup']:.4f};"
         f"overlap_efficiency_ladder={ovl['overlap_efficiency_ladder']:.4f};"
         f"exposed_comm_ms={(-1.0 if exp is None else exp * 1e3):.3f};"
         f"trace_events={len(eng_on.trace.events())};tokens_equal=True")

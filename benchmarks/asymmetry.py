"""Paper Figure 2: the two asymmetric regimes.

(a) Communication dominates (4090): int8 wire traffic halves the comm share.
(b) Computation dominates (A800): the in-flight-collective compute penalty eats
    part of the ISO win; the table quantifies the sensitivity.
"""
from __future__ import annotations

import dataclasses

from repro.config import get_model_config
from repro.perf.model import HW_PROFILES, layer_costs, prefill_time


def run(emit):
    cfg30 = get_model_config("paper-30b")
    seq = 8192
    # (a) comm share, fp16 vs int8, 4090 tp=4
    hw = HW_PROFILES["4090"]
    for mode, int8 in (("fp16", False), ("int8", True)):
        c = layer_costs(cfg30, 0, seq, hw, 4, int8_comm=int8)
        share = 2 * c["comm"] / (c["attn"] + c["mlp"] + 2 * c["comm"])
        emit(f"asym/comm_share/4090/{mode}", c["comm"] * 1e6,
             f"share={share:.2f};paper={'~0.75' if mode == 'fp16' else '~0.5'}")
    # (b) penalty sweep on a800-like parts (paper: 15-20% compute slowdown)
    cfg70 = get_model_config("paper-70b")
    base = prefill_time(cfg70, seq, "a800", 8, iso=False)
    for pen in (0.0, 0.10, 0.18, 0.25):
        hw_p = dataclasses.replace(HW_PROFILES["a800"], comm_penalty=pen)
        import repro.perf.model as pm
        old = pm.HW_PROFILES["a800"]
        pm.HW_PROFILES["a800"] = hw_p
        try:
            t = prefill_time(cfg70, seq, "a800", 8,
                             lengths=[seq // 2, seq // 2])
        finally:
            pm.HW_PROFILES["a800"] = old
        emit(f"asym/penalty/a800/{pen:.2f}", t * 1e6,
             f"reduction={100 * (1 - t / base):.1f}%")

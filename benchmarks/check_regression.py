"""CI perf-regression gate: BENCH_pr.json vs the committed baseline.

Compares every gated field in ``benchmarks/common.HEADLINE_FIELDS`` (the
same table ``ci_smoke.py`` lifts the fields with — one schema source of
truth) against ``benchmarks/BENCH_baseline.json`` and exits non-zero when
any field regressed past BOTH its tolerances:

  * ``better="higher"`` fields regress downward, ``"lower"`` upward;
  * a PR value passes when it is within ``rel_tol`` (fraction of baseline)
    OR ``abs_tol`` of the baseline in the bad direction — CI CPU runners
    are noisy, so tolerances catch cliffs, not jitter;
  * ``better=None`` fields are informational: printed, never gated.

Improvements always pass (and are worth folding into the baseline).

    PYTHONPATH=src python -m benchmarks.check_regression \
        --pr BENCH_pr.json [--baseline benchmarks/BENCH_baseline.json]

Updating the baseline (a deliberate act — commit the diff with an
explanation of what moved and why):

    PYTHONPATH=src python -m benchmarks.check_regression \
        --pr BENCH_pr.json --update-baseline
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from benchmarks.common import HEADLINE_FIELDS, write_json

BASELINE_SCHEMA = "bench-baseline-v1"
DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "BENCH_baseline.json")


def check_field(field: str, base: float, got: float) -> tuple[bool, str]:
    """(ok, verdict line) for one gated field."""
    spec = HEADLINE_FIELDS[field]
    better = spec["better"]
    if better is None:
        return True, f"  info  {field}: {got:g} (baseline {base:g})"
    delta = got - base
    bad = -delta if better == "higher" else delta
    if bad <= 0:
        tag = "  ok  " if bad == 0 else "  up  "
        return True, f"{tag}{field}: {got:g} (baseline {base:g})"
    rel_ok = abs(base) > 0 and bad / abs(base) <= spec.get("rel_tol", 0.0)
    abs_ok = bad <= spec.get("abs_tol", 0.0)
    if rel_ok or abs_ok:
        return True, (f"  tol  {field}: {got:g} vs {base:g} "
                      f"(within tolerance)")
    return False, (f"  FAIL {field}: {got:g} vs baseline {base:g} — "
                   f"regressed {bad:g} (> rel {spec.get('rel_tol', 0)} "
                   f"and abs {spec.get('abs_tol', 0)})")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pr", required=True, help="BENCH_pr.json from ci_smoke")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from --pr instead of checking")
    args = ap.parse_args(argv)

    with open(args.pr) as f:
        pr = json.load(f)
    fields = {k: pr.get(k, spec["default"])
              for k, spec in HEADLINE_FIELDS.items()}

    if args.update_baseline:
        write_json({"schema": BASELINE_SCHEMA,
                    "source_env": pr.get("env", {}),
                    "fields": fields}, args.baseline)
        print(f"baseline updated: {args.baseline}")
        for k, v in fields.items():
            print(f"  {k} = {v:g}")
        return 0

    if not os.path.exists(args.baseline):
        print(f"no baseline at {args.baseline} — nothing to gate "
              f"(run --update-baseline to create one)")
        return 0
    with open(args.baseline) as f:
        baseline = json.load(f)
    if baseline.get("schema") != BASELINE_SCHEMA:
        print(f"FAIL: baseline schema {baseline.get('schema')!r} != "
              f"{BASELINE_SCHEMA!r}")
        return 1
    base_fields = baseline.get("fields", {})

    failures = 0
    for field in HEADLINE_FIELDS:
        if field not in base_fields:
            print(f"  skip {field}: not in baseline")
            continue
        ok, line = check_field(field, float(base_fields[field]),
                               float(fields[field]))
        print(line)
        failures += 0 if ok else 1
    if failures:
        print(f"\n{failures} field(s) regressed past tolerance. If the "
              f"change is intentional, update the baseline "
              f"(--update-baseline) and justify it in the PR.")
        return 1
    print("\nperf gate: all fields within tolerance of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())

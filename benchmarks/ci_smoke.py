"""CI bench-smoke: engine bench + perf-ledger smoke -> BENCH_pr.json.

Runs on every push (ci.yml ``bench-smoke`` job) so the perf trajectory is
recorded per commit instead of staying empty:

  * ``benchmarks/engine_bench.py`` end-to-end on CPU — ISO vs baseline,
    paged vs dense (KV bytes, TTFT), CoW prefix sharing, the
    bucketed-prefill counters (pad tokens, compiled-closure count), and the
    batched-prefill section (packed vs batch-1 grants at 1/2/4 requests;
    the 4-wide call reduction is lifted into ``prefill_call_reduction``),
    and the split-KV decode section (the 128-page modeled critical-path
    ratio is lifted into ``decode_split_speedup``);
  * ``benchmarks/perf_ledger.py --smoke`` in a subprocess (it forces 512
    placeholder XLA devices at import, which must not leak into the
    engine-bench process whose jit runs on the single real CPU device).

The artifact is a single JSON document:

    {"schema": "bench-smoke-v1", "env": {...}, "wall_s": ...,
     "engine": [{"name", "us_per_call", "derived"}, ...],
     "perf_ledger": [{"ledger", "variant", "compute_s", ...}, ...]}

    PYTHONPATH=src python -m benchmarks.ci_smoke --out BENCH_pr.json
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import subprocess
import sys
import tempfile
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_pr.json")
    ap.add_argument("--skip-ledger", action="store_true",
                    help="engine bench only (fast local run)")
    args = ap.parse_args(argv)

    import jax
    from benchmarks import engine_bench

    rows = []

    def emit(name: str, us: float, derived: str = "") -> None:
        rows.append({"name": name, "us_per_call": us, "derived": derived})
        print(f"{name},{us:.1f},{derived}", flush=True)

    t0 = time.perf_counter()
    engine_bench.run(emit)
    ledger = []
    if not args.skip_ledger:
        with tempfile.TemporaryDirectory() as td:
            path = os.path.join(td, "ledger.json")
            subprocess.run(
                [sys.executable, "-m", "benchmarks.perf_ledger", "--smoke",
                 "--json", path],
                check=True, env=dict(os.environ))
            with open(path) as f:
                ledger = json.load(f)
    # headline metrics as first-class fields so the per-push artifact tracks
    # them without parsing derived strings: speculative accept rate, the
    # batched-prefill call reduction at 4 packed grants, and the
    # observability section's latency/occupancy/overlap numbers
    accepted_per_call = 0.0
    prefill_call_reduction = 0.0
    decode_split_speedup = 0.0
    obs = {"overlap_efficiency": 0.0, "ttft_p50": 0.0, "ttft_p99": 0.0,
           "pool_occupancy_peak": 0, "obs_overhead_pct": 0.0}
    for row in rows:
        if row["name"] == "engine/speculative":
            for part in row["derived"].split(";"):
                if part.startswith("accepted_per_call="):
                    accepted_per_call = float(part.split("=", 1)[1])
        if row["name"] == "engine/batched_prefill_4":
            for part in row["derived"].split(";"):
                if part.startswith("call_reduction="):
                    prefill_call_reduction = float(part.split("=", 1)[1])
        if row["name"] == "engine/decode_split_128":
            # long-context split-KV: modeled critical-path ratio at 128
            # resident pages (see engine_bench._decode_split_section)
            for part in row["derived"].split(";"):
                if part.startswith("split_speedup="):
                    decode_split_speedup = float(part.split("=", 1)[1])
        if row["name"] == "engine/observability":
            for part in row["derived"].split(";"):
                k, _, v = part.partition("=")
                if k in obs:
                    obs[k] = int(v) if k == "pool_occupancy_peak" \
                        else float(v)
    doc = {
        "schema": "bench-smoke-v1",
        "env": {"python": platform.python_version(),
                "platform": platform.platform(),
                "jax": jax.__version__,
                "backend": jax.default_backend()},
        "wall_s": round(time.perf_counter() - t0, 2),
        "accepted_per_call": accepted_per_call,
        "prefill_call_reduction": prefill_call_reduction,
        "decode_split_speedup": decode_split_speedup,
        **obs,
        "engine": rows,
        "perf_ledger": ledger,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out} ({len(rows)} engine rows, "
          f"{len(ledger)} ledger rows)")


if __name__ == "__main__":
    main()

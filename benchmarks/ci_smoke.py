"""CI bench-smoke: engine bench + perf-ledger smoke -> BENCH_pr.json.

Runs on every push (ci.yml ``bench-smoke`` job) so the perf trajectory is
recorded per commit instead of staying empty:

  * ``benchmarks/engine_bench.py`` end-to-end on CPU — ISO vs baseline,
    paged vs dense (KV bytes, TTFT), CoW prefix sharing, the
    bucketed-prefill counters (pad tokens, compiled-closure count), and the
    batched-prefill section (packed vs batch-1 grants at 1/2/4 requests;
    the 4-wide call reduction is lifted into ``prefill_call_reduction``),
    and the split-KV decode section (the 128-page modeled critical-path
    ratio is lifted into ``decode_split_speedup``), and the disaggregated
    prefill/decode section (token equality asserted; ``migrated_pages`` /
    ``migration_us`` lifted as informational fields);
  * ``benchmarks/perf_ledger.py --smoke`` in a subprocess (it forces 512
    placeholder XLA devices at import, which must not leak into the
    engine-bench process whose jit runs on the single real CPU device).

The artifact is a single JSON document:

    {"schema": "bench-smoke-v1", "env": {...}, "wall_s": ...,
     "engine": [{"name", "us_per_call", "derived"}, ...],
     "perf_ledger": [{"ledger", "variant", "compute_s", ...}, ...]}

    PYTHONPATH=src python -m benchmarks.ci_smoke --out BENCH_pr.json
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import subprocess
import sys
import tempfile
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_pr.json")
    ap.add_argument("--skip-ledger", action="store_true",
                    help="engine bench only (fast local run)")
    args = ap.parse_args(argv)

    import jax
    from benchmarks import engine_bench
    from benchmarks.common import lift_headlines, write_json

    rows = []

    def emit(name: str, us: float, derived: str = "") -> None:
        rows.append({"name": name, "us_per_call": us, "derived": derived})
        print(f"{name},{us:.1f},{derived}", flush=True)

    t0 = time.perf_counter()
    engine_bench.run(emit)
    ledger = []
    if not args.skip_ledger:
        with tempfile.TemporaryDirectory() as td:
            path = os.path.join(td, "ledger.json")
            subprocess.run(
                [sys.executable, "-m", "benchmarks.perf_ledger", "--smoke",
                 "--json", path],
                check=True, env=dict(os.environ))
            with open(path) as f:
                ledger = json.load(f)
    # headline metrics as first-class fields so the per-push artifact tracks
    # them without parsing derived strings; which row/key feeds each field —
    # and the tolerances check_regression.py gates them with — live in ONE
    # place: benchmarks/common.HEADLINE_FIELDS
    doc = {
        "schema": "bench-smoke-v1",
        "env": {"python": platform.python_version(),
                "platform": platform.platform(),
                "jax": jax.__version__,
                "backend": jax.default_backend()},
        "wall_s": round(time.perf_counter() - t0, 2),
        **lift_headlines(rows),
        "engine": rows,
        "perf_ledger": ledger,
    }
    write_json(doc, args.out)
    print(f"wrote {args.out} ({len(rows)} engine rows, "
          f"{len(ledger)} ledger rows)")


if __name__ == "__main__":
    main()

"""Overlap structure micro-benchmark (no TPU => structural, not wall-clock):
lower the real model on an 8-device host mesh in a SUBPROCESS (benches keep 1
device), parse the HLO, and report per-collective hideable dot-FLOPs for
baseline vs ISO, plus collective counts/bytes.  This is the dry-run analogue of
the paper's Figure 1 timeline."""
from __future__ import annotations

import json
import subprocess
import sys

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.config import Config, ISOConfig, ModelConfig, ParallelConfig
from repro.core.analysis import overlap_metric_stablehlo, parse_collectives
from repro.launch.mesh import make_mesh
from repro.launch import runner
from repro.models import api

cfg = ModelConfig(name="bench", family="dense", num_layers=2, d_model=256,
                  num_heads=8, num_kv_heads=4, d_ff=512, vocab_size=1024,
                  qk_norm=True)
out = {}
for label, iso in (("baseline", ISOConfig(enabled=False)),
                   ("iso2", ISOConfig(enabled=True, num_chunks=2,
                                      min_chunk_tokens=8, chunk_align=8)),
                   ("iso3", ISOConfig(enabled=True, num_chunks=3,
                                      min_chunk_tokens=8, chunk_align=8)),
                   ("iso2_int8", ISOConfig(enabled=True, num_chunks=2,
                                           min_chunk_tokens=8, chunk_align=8,
                                           quantized_comm=True))):
    pc = ParallelConfig(data=2, model=4)
    config = Config(model=cfg, parallel=pc, iso=iso)
    mesh = make_mesh(pc)
    pshape = jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0), cfg, tp=4))
    batch = api.make_inputs(cfg, 512, 4, abstract=True)
    build = runner.make_prefill_fn(config, mesh, pshape, logits_mode="last",
                                   global_batch=4)
    with mesh:
        lowered = build(batch).lower(pshape, batch)
        stable = lowered.as_text()          # barriers + per-chunk collectives
        hlo = lowered.compile().as_text()   # final wire bytes
    st = parse_collectives(hlo)
    m = overlap_metric_stablehlo(stable)
    out[label] = {"collectives": dict(st.counts), "wire_bytes": st.wire_bytes,
                  "hideable": m["avg_hideable_dots"],
                  "hideable_frac": m.get("hideable_fraction", 0.0),
                  "total_dots": m.get("total_dots", 0)}
print(json.dumps(out))
"""


def run(emit):
    res = subprocess.run([sys.executable, "-c", _CHILD], capture_output=True,
                         text=True, env=None, cwd=None)
    if res.returncode != 0:
        raise RuntimeError(f"overlap_micro child failed:\n{res.stderr[-2000:]}")
    data = json.loads(res.stdout.strip().splitlines()[-1])
    for label, d in data.items():
        n_ar = sum(d["collectives"].values())
        emit(f"overlap/{label}", 0.0,
             f"collectives={n_ar};wire_bytes={d['wire_bytes']:.2e};"
             f"hideable_dots={d['hideable']:.1f};frac={d['hideable_frac']:.2f}")
    # the paper's claim, structurally: ISO must create hideable work
    assert data["iso2"]["hideable"] > data["baseline"]["hideable"]
    # int8 comm must cut wire bytes vs plain iso2 (paper: ~2x)
    assert data["iso2_int8"]["wire_bytes"] < 0.8 * data["iso2"]["wire_bytes"]
    return data

"""Roofline table (EXPERIMENTS.md §Roofline source): reads the dry-run sweep
JSON (launch/dryrun.py --out) and prints per-(arch x shape x mesh) terms."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")


def run(emit):
    if not os.path.exists(RESULTS):
        emit("roofline/missing", 0.0,
             "run: python -m repro.launch.dryrun --both-meshes --out dryrun_results.json")
        return
    with open(RESULTS) as f:
        data = json.load(f)
    for r in data["reports"]:
        ro = r["roofline"]
        total = ro["compute_s"] + 0  # terms are independent ceilings, not a sum
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
             max(ro["compute_s"], ro["memory_s"], ro["collective_s"]) * 1e6,
             f"compute={ro['compute_s']:.2e};memory={ro['memory_s']:.2e};"
             f"collective={ro['collective_s']:.2e};bneck={ro['bottleneck']};"
             f"useful={ro['useful_flops_ratio']:.3f}")
    n = len(data["reports"])
    nf = len(data.get("failures", []))
    emit("roofline/summary", 0.0, f"pairs_ok={n};failures={nf}")
    assert nf == 0

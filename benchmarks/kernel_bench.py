"""Kernel micro-benchmarks.

Wall-clock on this container measures the XLA path of the pure-jnp references
(the Pallas kernels run in interpret mode here — Python-speed, TPU-only for real
timing), so the derived column carries what a dry run CAN measure: achieved
FLOPs of the reference path and the kernels' VMEM working-set per BlockSpec
tile, checked against the 128-multiple MXU alignment rule."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.kernels import ref


def run(emit):
    key = jax.random.PRNGKey(0)
    # flash attention reference path
    B, Hq, Hkv, Sq, Sk, hd = 1, 8, 2, 1024, 1024, 128
    q = jax.random.normal(key, (B, Hq, Sq, hd), jnp.bfloat16)
    k = jax.random.normal(key, (B, Hkv, Sk, hd), jnp.bfloat16)
    v = jax.random.normal(key, (B, Hkv, Sk, hd), jnp.bfloat16)
    fa = jax.jit(lambda a, b, c: ref.flash_prefill_ref(a, b, c))
    us = time_fn(fa, q, k, v)
    flops = 4.0 * B * Hq * Sq * Sk * hd / 2
    emit("kernel/flash_ref_1k", us, f"gflops={flops / us / 1e3:.1f}")
    # BlockSpec working sets (bytes in VMEM per tile) — the structural check
    for bq, bk in ((128, 128), (256, 512)):
        ws = (bq * hd + 2 * bk * hd + bq * hd) * 4 + bq * (hd + 2) * 4
        emit(f"kernel/flash_vmem_bq{bq}_bk{bk}", 0.0,
             f"vmem_bytes={ws};fits_16MB={ws < 16 * 2**20};aligned="
             f"{bq % 128 == 0 and bk % 128 == 0 and hd % 128 == 0}")
    # quantize
    x = jax.random.normal(key, (4096, 4096), jnp.bfloat16)
    qf = jax.jit(lambda a: ref.quantize_int8_ref(a))
    us = time_fn(qf, x)
    emit("kernel/int8_quant_16M", us,
         f"gbps={x.size * 2 / us / 1e3:.1f}")
    # rmsnorm + swiglu
    g = jax.random.normal(key, (8192, 2048), jnp.bfloat16)
    us = time_fn(jax.jit(lambda a: ref.rms_norm_ref(a, jnp.ones(2048))), g)
    emit("kernel/rmsnorm_16M", us, f"gbps={g.size * 2 / us / 1e3:.1f}")
    u = jax.random.normal(key, (8192, 2048), jnp.bfloat16)
    us = time_fn(jax.jit(ref.swiglu_ref), g, u)
    emit("kernel/swiglu_16M", us, f"gbps={2 * g.size * 2 / us / 1e3:.1f}")

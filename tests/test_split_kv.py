"""Split-KV (sequence-parallel) flash-decode end-to-end through the engine.

The kernel-level parity grid lives in tests/test_flash_decode.py; this file
proves the serving integration: a PagedEngine running with
``decode_kv_splits`` S > 1 must emit token streams IDENTICAL to the
sequential-walk engine (S=1) on mixed traffic — chunked prefill, CoW prefix
sharing, speculative verify windows, batch-split overlap — because the
split's partial-reduce is numerically a re-association of the same online
softmax, well inside fp32 argmax stability for these workloads.

Also pins the auto heuristic (ServingConfig.decode_kv_splits=0): shallow
traffic never pays the reduce step, deep traffic always splits, and either
way the closure cache stays keyed exactly (K, S).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense, iso_cfg
from repro.config import Config, ParallelConfig, ServingConfig
from repro.models import api
from repro.serving import PagedEngine, Request
from repro.serving.requests import SamplingParams

CFG = tiny_dense(vocab_size=64)
ISO = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)


@pytest.fixture(scope="module")
def params():
    return api.init_params(jax.random.PRNGKey(0), CFG, tp=1,
                           dtype=jnp.float32)


def _paged(params, *, kv_splits=1, spec_k=0, budget=16, page_size=8,
           max_len=160, max_batch=2, min_pages=16, factor=4):
    config = Config(model=CFG, parallel=ParallelConfig(data=1, model=1),
                    iso=ISO,
                    serving=ServingConfig(page_size=page_size,
                                          max_batch=max_batch,
                                          max_len=max_len,
                                          prefill_token_budget=budget,
                                          spec_k=spec_k,
                                          decode_kv_splits=kv_splits,
                                          decode_split_min_pages=min_pages,
                                          decode_split_factor=factor))
    return PagedEngine(config, params)


def _repetitive(rng, n, period=6):
    base = rng.integers(2, 64, period).astype(np.int32)
    return np.tile(base, -(-n // period))[:n]


def _mixed_prompts(rng):
    shared = rng.integers(2, 64, 24).astype(np.int32)
    return [
        _repetitive(rng, 30),
        rng.integers(2, 64, 33).astype(np.int32),
        np.concatenate([shared, rng.integers(2, 64, 9).astype(np.int32)]),
        np.concatenate([shared, rng.integers(2, 64, 5).astype(np.int32)]),
    ]


def _run(eng, prompts, new=8):
    rids = [eng.add_request(Request(
        prompt=p.copy(),
        sampling=SamplingParams(max_new_tokens=new, eos_id=-1)))
        for p in prompts]
    outs = eng.run_until_complete()
    return [outs[r] for r in rids]


@pytest.mark.parametrize("kv_splits", [2, 4])
def test_split_engine_matches_sequential(params, kv_splits):
    """Forced split-KV decode is token-identical to the sequential walk on
    mixed traffic (chunked prefill + prefix sharing + batched decode)."""
    rng = np.random.default_rng(31)
    prompts = _mixed_prompts(rng)
    seq = _run(_paged(params, kv_splits=1), prompts)
    split = _run(_paged(params, kv_splits=kv_splits), prompts)
    assert split == seq
    # and the split engine compiled exactly the forced-S closures
    eng = _paged(params, kv_splits=kv_splits)
    _run(eng, prompts[:2])
    assert set(eng._decode_fns) == {(1, kv_splits)}, sorted(eng._decode_fns)


@pytest.mark.parametrize("kv_splits", [2, 4])
def test_split_engine_matches_sequential_with_speculation(params, kv_splits):
    """Split-KV composes with the K-token speculative verify window: the
    (K, S) closure reduces every window position's walk and the greedy
    accept rule sees identical logits."""
    rng = np.random.default_rng(32)
    prompts = _mixed_prompts(rng)
    seq = _run(_paged(params, kv_splits=1, spec_k=2), prompts)
    split = _run(_paged(params, kv_splits=kv_splits, spec_k=2), prompts)
    plain = _run(_paged(params, kv_splits=1), prompts)
    assert split == seq == plain


def test_split_auto_heuristic_engages_on_depth(params):
    """Auto mode: a workload past decode_split_min_pages pages decodes
    through the split closure and still matches the sequential stream."""
    rng = np.random.default_rng(33)
    prompts = [rng.integers(2, 64, 120).astype(np.int32),
               _repetitive(rng, 100)]
    seq = _run(_paged(params, kv_splits=1, budget=64), prompts)
    auto = _paged(params, kv_splits=0, min_pages=4, factor=4, budget=64)
    got = _run(auto, prompts)
    assert got == seq
    assert set(auto._decode_fns) == {(1, 4)}, sorted(auto._decode_fns)
    # shallow traffic under the same auto config stays sequential
    shallow = _paged(params, kv_splits=0, min_pages=16)
    _run(shallow, [rng.integers(2, 64, 12).astype(np.int32)])
    assert set(shallow._decode_fns) == {(1, 1)}, sorted(shallow._decode_fns)


# ---------------------------------------------------------------------------
# hypothesis: arbitrary mixed workloads, split on == split off
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:                                     # pragma: no cover
    HAVE_HYP = False

if HAVE_HYP:
    @settings(max_examples=6, deadline=None)
    @given(st.lists(st.integers(min_value=4, max_value=40), min_size=1,
                    max_size=3),
           st.integers(min_value=0, max_value=2 ** 31 - 1))
    def test_random_walk_split_equals_sequential(lengths, seed):
        """Property: for ANY mixed-length workload, the split-KV paged
        engine emits token streams identical to the sequential-walk paged
        engine — the re-run of the PR-4 speculative walk with
        decode_kv_splits > 1 layered on."""
        params = _WALK_PARAMS[0]
        rng = np.random.default_rng(seed)
        prompts = [_repetitive(rng, n) if i % 2 == 0
                   else rng.integers(2, 64, n).astype(np.int32)
                   for i, n in enumerate(lengths)]
        outs = []
        for kv_splits in (1, 3):
            eng = _paged(params, kv_splits=kv_splits, spec_k=2, max_len=80)
            outs.append(_run(eng, prompts, new=4))
        assert outs[0] == outs[1]

    # module-scope params reused across hypothesis examples (fixtures and
    # @given do not compose)
    _WALK_PARAMS = [api.init_params(jax.random.PRNGKey(0), CFG, tp=1,
                                    dtype=jnp.float32)]
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_random_walk_split_equals_sequential():
        pass

"""CI compile-guard lane: prefill compilation count stays O(#buckets).

Runs the paged engine over a mixed-length trace with many distinct prompt
(and therefore grant) lengths and asserts, via a real jit-cache compile
counter (compat.jit_cache_size), that

  * total prefill compilations <= the engine's published bound
    (2 * #buckets x #row_buckets under batched grants — one closure per
    (length bucket, row bucket, all-fresh|has-resumed) triple; 2 * #buckets
    in batch-1 mode — one per (bucket, fresh|resumed) pair);
  * bucketing actually collapsed shapes (compilations < distinct prompt
    lengths in the trace);
  * each compiled closure was compiled exactly ONCE (a traced-vs-static
    regression — e.g. a Python int sneaking into the closure key — would
    recompile an existing key and trip this);
  * the single decode closure also compiled exactly once.

This is the regression guard for the grant-size bucketing tentpole: before
bucketing, `_prefill_fns` compiled one closure per distinct grant length.
"""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import iso_cfg, tiny_dense
from repro import compat
from repro.config import Config, ParallelConfig, ServingConfig
from repro.models import api
from repro.serving import PagedEngine, Request
from repro.serving.requests import SamplingParams


def _run_trace(lengths, *, grant_bucketing=True, new=3, budget=24,
               prefill_batching=True, **sv_kwargs):
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    iso=iso,
                    serving=ServingConfig(page_size=8, max_batch=4,
                                          max_len=160,
                                          prefill_token_budget=budget,
                                          grant_bucketing=grant_bucketing,
                                          prefill_batching=prefill_batching,
                                          **sv_kwargs))
    eng = PagedEngine(config, params)
    rng = np.random.default_rng(0)
    for n in lengths:
        eng.add_request(Request(
            prompt=rng.integers(2, 64, n).astype(np.int32),
            sampling=SamplingParams(max_new_tokens=new, eos_id=-1)))
    out = eng.run_until_complete()
    assert len(out) == len(lengths), "trace did not complete"
    return eng


def test_prefill_compiles_bounded_by_buckets():
    # 14 distinct prompt lengths, straddling bucket boundaries, several long
    # enough to force resumed grants under the 24-token budget
    lengths = (7, 9, 12, 15, 16, 17, 23, 31, 33, 41, 55, 63, 70, 90)
    eng = _run_trace(lengths)
    bound = eng.max_prefill_compiles()
    assert bound is not None, "bucketing unexpectedly disabled"
    compiles = eng.prefill_compile_count()
    assert compiles <= bound, \
        f"{compiles} prefill compilations exceed the bucket bound {bound}"
    assert compiles < len(set(lengths)), \
        "bucketing failed to collapse distinct grant lengths " \
        f"({compiles} compiles for {len(set(lengths))} lengths)"
    # far more grants ran than closures compiled (the whole point)
    assert eng.metrics["prefill_calls"] > compiles
    # no key recompiled: every cache holds exactly one executable
    for key, fn in eng._prefill_fns.items():
        assert compat.jit_cache_size(fn) == 1, \
            f"prefill closure {key} recompiled"
    # K=1 sequential decode stays ONE closure compiled once — speculative
    # and split-KV support must not widen the plain path's compile footprint
    assert set(eng._decode_fns) == {(1, 1)}, \
        f"unexpected decode closures: {sorted(eng._decode_fns)}"
    assert compat.jit_cache_size(eng._decode_fns[(1, 1)]) == 1, \
        "decode recompiled"


def test_unbucketed_engine_reports_no_bound():
    eng = _run_trace((9, 17, 33), grant_bucketing=False,
                     prefill_batching=False)
    assert eng.max_prefill_compiles() is None
    assert eng.metrics["prefill_pad_tokens"] == 0


def test_batched_grants_compile_bound():
    """Batched multi-request grants: a trace whose steps mix 1-4 simultaneous
    grants (a big budget lets every resident request prefill each tick) must
    compile at most O(#buckets x #row_buckets) prefill closures (the
    published bound: 2x for the all-fresh|has-resumed key bit), exercise
    more than one ROW bucket, and leave the decode closure set untouched at
    {1}."""
    # 4-at-a-time same-bucket bursts + stragglers of other buckets: packs of
    # width 4, 2 and 1 across buckets 16/32/64
    lengths = (16, 15, 14, 13, 32, 31, 30, 29, 64, 63, 33, 7, 70, 90)
    eng = _run_trace(lengths, budget=256)
    assert eng._batch_prefill, "batched prefill unexpectedly disabled"
    bound = eng.max_prefill_compiles()
    # one closure per (length bucket, row bucket, all-fresh|has-resumed)
    assert bound == 2 * len(eng._buckets) * len(eng._row_buckets)
    compiles = eng.prefill_compile_count()
    assert compiles <= bound, \
        f"{compiles} prefill compilations exceed {bound} " \
        f"(= 2 x {len(eng._buckets)} buckets x {len(eng._row_buckets)} " \
        f"row buckets)"
    # packing really happened: strictly fewer calls than grants, and at
    # least two distinct row buckets were exercised
    assert eng.metrics["prefill_calls"] < eng.metrics["prefill_grants"]
    row_buckets_used = {k[1] for k in eng._prefill_fns}
    assert len(row_buckets_used) >= 2, row_buckets_used
    # every closure compiled exactly once (no traced-vs-static key leak)
    for key, fn in eng._prefill_fns.items():
        assert compat.jit_cache_size(fn) == 1, \
            f"batched prefill closure {key} recompiled"
    # decode stays ONE closure compiled once — packing must not widen it
    assert set(eng._decode_fns) == {(1, 1)}, \
        f"unexpected decode closures: {sorted(eng._decode_fns)}"
    assert compat.jit_cache_size(eng._decode_fns[(1, 1)]) == 1, \
        "decode recompiled"


def test_decode_closures_keyed_exactly_K_S():
    """Split-KV traffic compiles decode closures keyed EXACTLY (K, S).

    Forced splits (decode_kv_splits=2) with speculation (spec_k=1, greedy)
    must produce only (K, 2) keys — K in {1, 2} as speculation engages and
    falls back — each compiled exactly once.  A traced-vs-static leak of
    either the verify width or the split count into the closure body would
    recompile an existing key and trip jit_cache_size."""
    lengths = (9, 17, 33, 41)
    eng = _run_trace(lengths, decode_kv_splits=2, spec_k=1)
    keys = set(eng._decode_fns)
    assert keys and keys <= {(1, 2), (2, 2)}, \
        f"unexpected decode closures: {sorted(keys)}"
    for key, fn in eng._decode_fns.items():
        assert compat.jit_cache_size(fn) == 1, f"decode closure {key} recompiled"


def test_decode_split_auto_threshold_keys():
    """Auto mode (decode_kv_splits=0): shallow traffic stays sequential
    ((1, 1) only); traffic past decode_split_min_pages pages compiles the
    split closure ((1, factor)) — the depth heuristic is part of the key."""
    shallow = _run_trace((9, 17), decode_kv_splits=0,
                         decode_split_min_pages=16)
    assert set(shallow._decode_fns) == {(1, 1)}, \
        sorted(shallow._decode_fns)
    # prompt of 120 tokens on 8-token pages = 15 resident pages at first
    # decode, >= min_pages=4 -> every decode step splits by the factor
    deep = _run_trace((120,), decode_kv_splits=0, decode_split_min_pages=4,
                      decode_split_factor=4, budget=64)
    assert set(deep._decode_fns) == {(1, 4)}, sorted(deep._decode_fns)
    for key, fn in deep._decode_fns.items():
        assert compat.jit_cache_size(fn) == 1, f"decode closure {key} recompiled"


def test_batch1_engine_keeps_fresh_resumed_bound():
    """prefill_batching=False keeps the PR-3 key space: one closure per
    (bucket, fresh|resumed) pair, bound 2 x #buckets."""
    lengths = (7, 9, 17, 33, 70, 90)
    eng = _run_trace(lengths, prefill_batching=False)
    bound = eng.max_prefill_compiles()
    assert bound == 2 * len(eng._buckets)
    assert eng.prefill_compile_count() <= bound
    assert all(len(k) == 3 for k in eng._prefill_fns), \
        list(eng._prefill_fns)

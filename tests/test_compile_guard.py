"""CI compile-guard lane: prefill compilation count stays O(#buckets).

Runs the paged engine over a mixed-length trace with many distinct prompt
(and therefore grant) lengths and asserts, via a real jit-cache compile
counter (compat.jit_cache_size), that

  * total prefill compilations <= the engine's published bound
    (2 * #buckets: one closure per (bucket, fresh|resumed) pair);
  * bucketing actually collapsed shapes (compilations < distinct prompt
    lengths in the trace);
  * each compiled closure was compiled exactly ONCE (a traced-vs-static
    regression — e.g. a Python int sneaking into the closure key — would
    recompile an existing key and trip this);
  * the single decode closure also compiled exactly once.

This is the regression guard for the grant-size bucketing tentpole: before
bucketing, `_prefill_fns` compiled one closure per distinct grant length.
"""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import iso_cfg, tiny_dense
from repro import compat
from repro.config import Config, ParallelConfig, ServingConfig
from repro.models import api
from repro.serving import PagedEngine, Request
from repro.serving.requests import SamplingParams


def _run_trace(lengths, *, grant_bucketing=True, new=3):
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    iso=iso,
                    serving=ServingConfig(page_size=8, max_batch=4,
                                          max_len=160,
                                          prefill_token_budget=24,
                                          grant_bucketing=grant_bucketing))
    eng = PagedEngine(config, params)
    rng = np.random.default_rng(0)
    for n in lengths:
        eng.add_request(Request(
            prompt=rng.integers(2, 64, n).astype(np.int32),
            sampling=SamplingParams(max_new_tokens=new, eos_id=-1)))
    out = eng.run_until_complete()
    assert len(out) == len(lengths), "trace did not complete"
    return eng


def test_prefill_compiles_bounded_by_buckets():
    # 14 distinct prompt lengths, straddling bucket boundaries, several long
    # enough to force resumed grants under the 24-token budget
    lengths = (7, 9, 12, 15, 16, 17, 23, 31, 33, 41, 55, 63, 70, 90)
    eng = _run_trace(lengths)
    bound = eng.max_prefill_compiles()
    assert bound is not None, "bucketing unexpectedly disabled"
    compiles = eng.prefill_compile_count()
    assert compiles <= bound, \
        f"{compiles} prefill compilations exceed the bucket bound {bound}"
    assert compiles < len(set(lengths)), \
        "bucketing failed to collapse distinct grant lengths " \
        f"({compiles} compiles for {len(set(lengths))} lengths)"
    # far more grants ran than closures compiled (the whole point)
    assert eng.metrics["prefill_calls"] > compiles
    # no key recompiled: every cache holds exactly one executable
    for key, fn in eng._prefill_fns.items():
        assert compat.jit_cache_size(fn) == 1, \
            f"prefill closure {key} recompiled"
    # K=1 decode stays ONE closure compiled once — speculative support must
    # not widen the plain path's compile footprint
    assert set(eng._decode_fns) == {1}, \
        f"unexpected decode closures: {sorted(eng._decode_fns)}"
    assert compat.jit_cache_size(eng._decode_fns[1]) == 1, "decode recompiled"


def test_unbucketed_engine_reports_no_bound():
    eng = _run_trace((9, 17, 33), grant_bucketing=False)
    assert eng.max_prefill_compiles() is None
    assert eng.metrics["prefill_pad_tokens"] == 0

"""Property-based tests (hypothesis) for PageAllocator with refcounted
prefix/page sharing: arbitrary alloc/free/share/CoW sequences never
double-free, never hand a live page to a new owner, and conserve the pool."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serving.kvcache import (OutOfPages, PageAllocator,  # noqa: E402
                                   pages_for)

PAGE_SIZE = 4
NUM_PAGES = 12
RIDS = list(range(6))


def _check(a: PageAllocator):
    # conservation: every page is free xor referenced, refcounts exact
    refs = {}
    for rid, table in a.tables.items():
        assert len(table) == len(set(table)), f"rid {rid} repeats a page"
        for pg in table:
            refs[pg] = refs.get(pg, 0) + 1
    assert refs == a.refcount, "refcount drift"
    for pg in refs:
        assert pg not in a._free_set, f"page {pg} free AND referenced"
    assert len(refs) + a.free_pages == a.num_pages, "pool not conserved"
    for rid in a.tables:
        assert a.tokens(rid) <= a.capacity(rid)


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_allocator_share_cow_random_walk(data):
    a = PageAllocator(NUM_PAGES, PAGE_SIZE)
    n_ops = data.draw(st.integers(10, 80), label="n_ops")
    for _ in range(n_ops):
        op = data.draw(st.sampled_from(["grow", "free", "adopt", "cow"]),
                       label="op")
        live = sorted(a.tables)
        if op == "grow":
            rid = data.draw(st.sampled_from(RIDS), label="rid")
            want = a.tokens(rid) + data.draw(st.integers(1, 9), label="toks")
            before_free = a.free_pages
            try:
                a.ensure(rid, want)
                a.commit(rid, want - a.tokens(rid))
            except OutOfPages:
                assert a.free_pages == before_free, "failed ensure leaked"
        elif op == "free" and live:
            rid = data.draw(st.sampled_from(live), label="free_rid")
            released = a.free(rid)
            for pg in released:
                assert a.refcount.get(pg, 0) == 0
                assert pg in a._free_set
        elif op == "adopt" and live:
            donor = data.draw(st.sampled_from(live), label="donor")
            fresh = [r for r in range(20, 60) if r not in a.tables]
            if not fresh:
                continue
            rid = fresh[0]
            k = data.draw(st.integers(1, max(1, len(a.tables[donor]))),
                          label="k_pages")
            k = min(k, len(a.tables[donor]))
            if k:
                n_tok = min(a.tokens(donor), k * PAGE_SIZE)
                a.adopt(rid, a.tables[donor][:k], n_tok)
                assert a.tokens(rid) == n_tok
        elif op == "cow" and live:
            rid = data.draw(st.sampled_from(live), label="cow_rid")
            if a.tables[rid]:
                blk = data.draw(
                    st.integers(0, len(a.tables[rid]) - 1), label="blk")
                old = a.tables[rid][blk]
                was_shared = a.page_shared(old)
                try:
                    pair = a.cow(rid, blk)
                except OutOfPages:
                    pair = "oom"
                if pair not in (None, "oom"):
                    assert was_shared
                    assert pair[0] == old and a.tables[rid][blk] == pair[1]
                elif pair is None:
                    assert not was_shared        # exclusive page: no copy
        _check(a)
    # drain everything: the pool must come back whole
    for rid in sorted(a.tables):
        a.free(rid)
    assert a.free_pages == a.num_pages
    assert not a.refcount


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fresh_pages_never_alias_live_ones(seed):
    """Pages handed out by ensure/cow must never be held by anyone else."""
    rng = np.random.default_rng(seed)
    a = PageAllocator(8, PAGE_SIZE)
    for _ in range(40):
        live = sorted(a.tables)
        roll = rng.integers(0, 3)
        held_before = {pg for t in a.tables.values() for pg in t}
        if roll == 0:
            rid = int(rng.integers(0, 4))
            before = set(a.tables.get(rid, ()))
            try:
                a.ensure(rid, a.tokens(rid) + int(rng.integers(1, 8)))
            except OutOfPages:
                continue
            fresh = set(a.tables[rid]) - before
            assert not (fresh & (held_before - before)), \
                "ensure handed out a page another request holds"
        elif roll == 1 and live:
            donor = live[int(rng.integers(0, len(live)))]
            rid = 100 + int(rng.integers(0, 1000))
            if rid not in a.tables and a.tables[donor]:
                a.adopt(rid, a.tables[donor][:1],
                        min(a.tokens(donor), PAGE_SIZE))
        elif roll == 2 and live:
            rid = live[int(rng.integers(0, len(live)))]
            if a.tables[rid]:
                blk = int(rng.integers(0, len(a.tables[rid])))
                try:
                    pair = a.cow(rid, blk)
                except OutOfPages:
                    continue
                if pair is not None:
                    assert pair[1] not in held_before, \
                        "cow target aliases a live page"
        _check(a)

"""Perf-regression gate (benchmarks/check_regression.py) and the shared
headline-field schema (benchmarks/common.HEADLINE_FIELDS).

The gate is CI's only defence against silent perf cliffs, so its compare
logic is pinned here: improvements always pass, bad-direction deltas pass
within EITHER tolerance (rel OR abs — CPU runners are noisy), informational
fields never gate, and a regression past both tolerances fails the run.
"""
import json

import pytest

from benchmarks.check_regression import (BASELINE_SCHEMA, check_field,
                                         main as gate_main)
from benchmarks.common import (HEADLINE_FIELDS, lift_headlines,
                               parse_derived, write_json)


# ---------------------------------------------------------------------------
# common.py: the single source of truth ci_smoke + the gate both read
# ---------------------------------------------------------------------------

def test_headline_schema_is_well_formed():
    assert HEADLINE_FIELDS, "schema must not be empty"
    for field, spec in HEADLINE_FIELDS.items():
        assert spec["better"] in ("higher", "lower", None), field
        assert "row" in spec and "key" in spec, field
        if spec["better"] is not None:
            assert spec.get("rel_tol", 0) > 0 or spec.get("abs_tol", 0) > 0, \
                f"{field}: gated field needs at least one tolerance"


def test_parse_derived():
    assert parse_derived("a=1.5;b=2;note=fast") == {
        "a": "1.5", "b": "2", "note": "fast"}
    assert parse_derived("no-equals-sign") == {}
    assert parse_derived("") == {}


def test_lift_headlines_pulls_fields_from_rows():
    rows = [
        {"name": "engine/speculative", "us_per_call": 10.0,
         "derived": "accepted_per_call=3.2"},
        {"name": "engine/decode_split_128", "us_per_call": 20.0,
         "derived": "split_speedup=1.4;splits=4"},
        {"name": "engine/observability", "us_per_call": 5.0,
         "derived": "pool_occupancy_peak=12;ttft_p50=not-a-number"},
    ]
    out = lift_headlines(rows)
    assert out["accepted_per_call"] == 3.2
    assert out["decode_split_speedup"] == 1.4
    assert out["pool_occupancy_peak"] == 12      # int cast
    # unparsable value or absent row -> schema default, never an exception
    assert out["ttft_p50"] == HEADLINE_FIELDS["ttft_p50"]["default"]
    assert out["overlap_efficiency"] == \
        HEADLINE_FIELDS["overlap_efficiency"]["default"]


# ---------------------------------------------------------------------------
# check_field: the compare logic, direction by direction
# ---------------------------------------------------------------------------

def _spec_for(better, rel=0.10, abs_=0.10):
    """Pick a real schema field with the wanted direction so the test
    exercises the production table, not a synthetic one."""
    for field, spec in HEADLINE_FIELDS.items():
        if spec["better"] == better:
            return field, spec
    pytest.skip(f"no field with better={better!r}")


def test_higher_is_better_directions():
    field, spec = _spec_for("higher")
    ok, _ = check_field(field, 2.0, 2.0)        # equal
    assert ok
    ok, _ = check_field(field, 2.0, 3.0)        # improvement
    assert ok
    # within rel tolerance of the bad direction
    ok, _ = check_field(field, 2.0, 2.0 * (1 - spec["rel_tol"] * 0.5))
    assert ok
    # past BOTH tolerances
    bad = 2.0 - max(2.0 * spec["rel_tol"], spec["abs_tol"]) * 2
    ok, line = check_field(field, 2.0, bad)
    assert not ok and "FAIL" in line


def test_lower_is_better_directions():
    field, spec = _spec_for("lower")
    ok, _ = check_field(field, 5.0, 4.0)        # improvement (down)
    assert ok
    ok, _ = check_field(field, 5.0, 5.0 + spec["abs_tol"] * 0.5)
    assert ok
    bad = 5.0 + max(5.0 * spec["rel_tol"], spec["abs_tol"]) * 2
    ok, line = check_field(field, 5.0, bad)
    assert not ok and "FAIL" in line


def test_informational_fields_never_gate():
    field, _ = _spec_for(None)
    for got in (-100.0, 0.0, 100.0):
        ok, line = check_field(field, 1.0, got)
        assert ok and "info" in line


def test_abs_tolerance_rescues_tiny_baselines():
    # rel_tol of a near-zero baseline is meaningless; abs_tol must carry it
    field, spec = _spec_for("higher")
    if spec.get("abs_tol", 0) <= 0:
        pytest.skip("field has no abs tolerance")
    ok, _ = check_field(field, 0.0, -spec["abs_tol"] * 0.5)
    assert ok


# ---------------------------------------------------------------------------
# main(): end-to-end through temp files
# ---------------------------------------------------------------------------

def _bench_doc():
    return {f: spec["default"] + (1.0 if spec["better"] else 0.0)
            for f, spec in HEADLINE_FIELDS.items()}


def test_gate_roundtrip_update_then_pass(tmp_path, capsys):
    pr = tmp_path / "BENCH_pr.json"
    base = tmp_path / "baseline.json"
    write_json(_bench_doc(), str(pr))
    assert gate_main(["--pr", str(pr), "--baseline", str(base),
                      "--update-baseline"]) == 0
    doc = json.loads(base.read_text())
    assert doc["schema"] == BASELINE_SCHEMA
    assert set(doc["fields"]) == set(HEADLINE_FIELDS)
    # identical PR vs its own baseline: all ok
    assert gate_main(["--pr", str(pr), "--baseline", str(base)]) == 0
    assert "within tolerance" in capsys.readouterr().out


def test_gate_fails_on_regression(tmp_path, capsys):
    field, spec = _spec_for("higher")
    base_doc = _bench_doc()
    pr_doc = dict(base_doc)
    pr_doc[field] = base_doc[field] - max(
        abs(base_doc[field]) * spec["rel_tol"], spec["abs_tol"]) * 3
    pr = tmp_path / "BENCH_pr.json"
    base = tmp_path / "baseline.json"
    write_json(pr_doc, str(pr))
    write_json({"schema": BASELINE_SCHEMA, "source_env": {},
                "fields": base_doc}, str(base))
    assert gate_main(["--pr", str(pr), "--baseline", str(base)]) == 1
    assert field in capsys.readouterr().out


def test_gate_passes_without_baseline(tmp_path, capsys):
    pr = tmp_path / "BENCH_pr.json"
    write_json(_bench_doc(), str(pr))
    assert gate_main(["--pr", str(pr),
                      "--baseline", str(tmp_path / "missing.json")]) == 0
    assert "no baseline" in capsys.readouterr().out


def test_gate_rejects_wrong_baseline_schema(tmp_path):
    pr = tmp_path / "BENCH_pr.json"
    base = tmp_path / "baseline.json"
    write_json(_bench_doc(), str(pr))
    write_json({"schema": "bench-baseline-v999", "fields": {}}, str(base))
    assert gate_main(["--pr", str(pr), "--baseline", str(base)]) == 1


def test_committed_baseline_is_loadable():
    """The repo's own baseline must stay schema-valid — the bench-smoke CI
    lane gates every PR against it."""
    from benchmarks.check_regression import DEFAULT_BASELINE
    with open(DEFAULT_BASELINE) as f:
        doc = json.load(f)
    assert doc["schema"] == BASELINE_SCHEMA
    for field in HEADLINE_FIELDS:
        assert field in doc["fields"], f"baseline missing {field}"
        float(doc["fields"][field])

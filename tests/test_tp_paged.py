"""Multi-device (8 host CPUs, subprocess) paged TP serving: the shard_map
PagedEngine — flash-decode kernel over block tables, batch-split ISO decode
overlap, CoW prefix sharing — must emit token-identical greedy streams to the
single-device DENSE engine on a mixed-length batch.  Subprocess because XLA
locks the device count at first init (the main pytest process keeps 1 device).

Kept out of the slow lane: CI runs this in the dedicated multi-device job
(.github/workflows/ci.yml) with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import subprocess
import sys

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, jax.numpy as jnp, numpy as np
from repro.config import (Config, ISOConfig, ModelConfig, ParallelConfig,
                          ServingConfig)
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.serving import Engine, PagedEngine, Request
from repro.serving.requests import SamplingParams

key = jax.random.PRNGKey(0)
iso = ISOConfig(enabled=True, num_chunks=2, min_chunk_tokens=8, chunk_align=8)
cfg = ModelConfig(name="t-dense", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                  qk_norm=True)
sp = lambda n=5: SamplingParams(max_new_tokens=n, eos_id=-1)
rng = np.random.default_rng(3)
prompts = [rng.integers(2, 64, n).astype(np.int32) for n in (70, 12, 33, 7)]

# ---- single-device dense reference ----------------------------------------
config1 = Config(model=cfg, parallel=ParallelConfig(data=1, model=1), iso=iso)
params1 = api.init_params(key, cfg, tp=1, dtype=jnp.float32)
dense = Engine(config1, params1, mesh=None, max_batch=2, max_len=160,
               bucket=16)
d_rids = [dense.add_request(Request(prompt=p.copy(), sampling=sp()))
          for p in prompts]
d_out = dense.run_until_complete()

# ---- TP=8 paged engine (shard_map + flash decode + overlap) ---------------
pc = ParallelConfig(data=1, model=8)
mesh = make_mesh(pc)
params8 = api.init_params(key, cfg, tp=8, dtype=jnp.float32)
sv = ServingConfig(page_size=8, max_batch=2, max_len=160,
                   prefill_token_budget=16)
eng = PagedEngine(Config(model=cfg, parallel=pc, iso=iso, serving=sv),
                  params8, mesh=mesh)
assert eng._decode_overlap, "TP decode must use the batch-split ISO schedule"
p_rids = [eng.add_request(Request(prompt=p.copy(), sampling=sp()))
          for p in prompts]
p_out = eng.run_until_complete()
for dr, pr in zip(d_rids, p_rids):
    assert d_out[dr] == p_out[pr], (dr, d_out[dr], p_out[pr])
print("ok tp-paged==dense", flush=True)

# ---- prefix sharing under TP: fewer pages, identical tokens ---------------
system = rng.integers(2, 64, 40).astype(np.int32)
shared_prompts = [np.concatenate([system,
                                  rng.integers(2, 64, n).astype(np.int32)])
                  for n in (9, 13)]

def run_tp(sharing):
    svx = ServingConfig(page_size=8, max_batch=2, max_len=160,
                        prefill_token_budget=64, prefix_sharing=sharing)
    e = PagedEngine(Config(model=cfg, parallel=pc, iso=iso, serving=svx),
                    params8, mesh=mesh)
    rids = [e.add_request(Request(prompt=p.copy(), sampling=sp(6)))
            for p in shared_prompts]
    outs = e.run_until_complete()
    return [outs[r] for r in rids], e

tok_s, eng_s = run_tp(True)
tok_p, eng_p = run_tp(False)
assert tok_s == tok_p, (tok_s, tok_p)
assert eng_s.metrics["prefix_shared_tokens"] >= 40
assert eng_s.metrics["peak_used_pages"] < eng_p.metrics["peak_used_pages"], (
    eng_s.metrics["peak_used_pages"], eng_p.metrics["peak_used_pages"])
st = eng_s.page_stats()
assert "shared_pages" in st and st["free_pages"] == st["num_pages"]
print("ok tp-prefix-sharing", flush=True)
print("ALL_TP_PAGED_OK")
"""


def test_tp_paged_engine_subprocess():
    res = subprocess.run([sys.executable, "-c", _CHILD], capture_output=True,
                         text=True, timeout=540)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "ALL_TP_PAGED_OK" in res.stdout

"""Engine-external KV state (serving/kvstate.py + kvcache serialization):

  * ``PageAllocator.snapshot()/restore()`` is an exact round trip THROUGH
    JSON — tables, lengths, refcounts and the free list (order included)
    survive ``json.dumps``/``loads`` into a fresh allocator, and the
    structural invariants (``check()``) hold after restore.  Pinned both on
    a hand-built state and on random admission/grant/CoW/free walks
    (seeded always; hypothesis when installed).
  * ``PrefixCache.snapshot()/restore()`` round-trips the registered prompts
    and REBUILDS the hash index (``hash(bytes)`` is process-salted, so a
    serialized index would be garbage in the next process) — lookups after
    restore find the same donors.
  * ``KVPool.export_pages``/``import_pages`` move KV across pools:
    payloads land verbatim at remapped page ids, CoW sharing structure and
    refcounts are preserved, the source pool is untouched, the target's
    scratch page stays all-(-1), and an import that doesn't fit raises
    ``OutOfPages`` atomically (target bit-identical afterwards).
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense
from repro.serving.kvcache import OutOfPages, PageAllocator, PrefixCache
from repro.serving.kvstate import KVPool

CFG = tiny_dense(vocab_size=64)


def _alloc_state(a: PageAllocator):
    return (dict(a.tables), dict(a.lengths), dict(a.refcount),
            list(a._free), set(a._free_set))


def _walk_step(a: PageAllocator, rng) -> None:
    """One random allocator op: grow+commit / free / adopt / CoW."""
    op = rng.integers(0, 4)
    live = sorted(a.tables)
    if op == 0:
        rid = int(rng.integers(0, 6))
        try:
            want = a.tokens(rid) + int(rng.integers(1, 9))
            a.ensure(rid, want)
            a.commit(rid, want - a.tokens(rid))
        except OutOfPages:
            pass
    elif op == 1 and live:
        a.free(int(rng.choice(live)))
    elif op == 2 and live:
        donor = int(rng.choice(live))
        rid = 100 + int(rng.integers(0, 1000))
        if rid not in a.tables and a.tables[donor]:
            k = int(rng.integers(1, len(a.tables[donor]) + 1))
            a.adopt(rid, a.tables[donor][:k],
                    min(a.tokens(donor), k * a.page_size))
    elif op == 3 and live:
        rid = int(rng.choice(live))
        if a.tables[rid]:
            try:
                a.cow(rid, int(rng.integers(0, len(a.tables[rid]))))
            except OutOfPages:
                pass


# ---------------------------------------------------------------------------
# PageAllocator snapshot/restore
# ---------------------------------------------------------------------------

def test_snapshot_restore_exact_through_json():
    a = PageAllocator(num_pages=12, page_size=4)
    a.ensure(1, 7)
    a.commit(1, 7)
    a.ensure(2, 10)
    a.commit(2, 10)
    a.adopt(3, a.tables[1][:1], 4)            # shared page: refcount 2
    a.cow(3, 0)                               # ...then diverged
    a.free(2)
    snap = json.loads(json.dumps(a.snapshot()))
    b = PageAllocator(num_pages=12, page_size=4)
    b.restore(snap)
    assert _alloc_state(b) == _alloc_state(a)
    b.check()


def test_restore_preserves_free_list_order():
    """A restored allocator must hand out pages in the identical sequence —
    free-list ORDER is state, not just the free set (the differential
    batteries rely on allocation determinism)."""
    a = PageAllocator(num_pages=10, page_size=4)
    a.ensure(1, 12)
    a.commit(1, 12)
    a.free(1)                                 # free list now has history
    b = PageAllocator(num_pages=10, page_size=4)
    b.restore(json.loads(json.dumps(a.snapshot())))
    for rid in (7, 8):
        a.ensure(rid, 8)
        b.ensure(rid, 8)
        assert a.tables[rid] == b.tables[rid]


def test_restore_rejects_geometry_mismatch():
    a = PageAllocator(num_pages=8, page_size=4)
    snap = a.snapshot()
    with pytest.raises(AssertionError):
        PageAllocator(num_pages=9, page_size=4).restore(snap)
    with pytest.raises(AssertionError):
        PageAllocator(num_pages=8, page_size=8).restore(snap)


def test_random_walk_round_trip_seeded():
    """400-op random walk; after every 25 ops the snapshot restores into a
    fresh allocator exactly and the invariants hold."""
    rng = np.random.default_rng(5)
    a = PageAllocator(num_pages=12, page_size=4)
    for i in range(400):
        _walk_step(a, rng)
        if i % 25 == 0:
            a.check()
            b = PageAllocator(num_pages=12, page_size=4)
            b.restore(json.loads(json.dumps(a.snapshot())))
            assert _alloc_state(b) == _alloc_state(a)


def test_random_walk_round_trip_hypothesis():
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n_ops=st.integers(5, 80))
    def walk(seed, n_ops):
        rng = np.random.default_rng(seed)
        a = PageAllocator(num_pages=10, page_size=4)
        for _ in range(n_ops):
            _walk_step(a, rng)
        a.check()
        b = PageAllocator(num_pages=10, page_size=4)
        b.restore(json.loads(json.dumps(a.snapshot())))
        assert _alloc_state(b) == _alloc_state(a)
        b.check()
        # no double-free latent in the restored state: freeing every live
        # request drains back to a full free list
        for rid in sorted(b.tables):
            b.free(rid)
        assert b.used_pages == 0 and b.free_pages == b.num_pages

    walk()


# ---------------------------------------------------------------------------
# PrefixCache snapshot/restore
# ---------------------------------------------------------------------------

def test_prefix_cache_round_trip_rebuilds_index():
    ps = 4
    a = PageAllocator(num_pages=12, page_size=ps)
    pc = PrefixCache(ps)
    prompt = np.arange(2, 14, dtype=np.int32)          # 12 tokens = 3 pages
    a.ensure(1, len(prompt))
    a.commit(1, len(prompt))
    pc.register(1, prompt)
    pc2 = PrefixCache(ps)
    pc2.restore(json.loads(json.dumps(pc.snapshot())))
    probe = np.concatenate([prompt[:8], np.asarray([50, 51], np.int32)])
    hit = pc.lookup(probe, a, exclude=2)
    hit2 = pc2.lookup(probe, a, exclude=2)
    assert hit is not None and hit2 is not None
    assert hit[0] == hit2[0] == 1 and hit[1] == hit2[1]


# ---------------------------------------------------------------------------
# KVPool export/import
# ---------------------------------------------------------------------------

def _pool(num_pages=8, ps=4):
    return KVPool.create(CFG, num_pages, ps, dtype=jnp.float32)


def _fill(pool, rid, n_tokens, rng):
    """Allocate + commit and write recognizable payloads into rid's pages."""
    pool.alloc.ensure(rid, n_tokens)
    pool.alloc.commit(rid, n_tokens)
    arrays = dict(pool.kv.arrays)
    pgs = jnp.asarray(pool.alloc.tables[rid], jnp.int32)
    arrays["k"] = tuple(
        k.at[:, pgs].set(jnp.asarray(
            rng.standard_normal((k.shape[0], len(pool.alloc.tables[rid]))
                                + k.shape[2:]), k.dtype))
        for k in arrays["k"])
    arrays["v"] = tuple(
        v.at[:, pgs].set(jnp.asarray(
            rng.standard_normal((v.shape[0], len(pool.alloc.tables[rid]))
                                + v.shape[2:]), v.dtype))
        for v in arrays["v"])
    pos = np.full((len(pool.alloc.tables[rid]), pool.page_size), -1, np.int32)
    flat = np.arange(n_tokens)
    pos[flat // pool.page_size, flat % pool.page_size] = flat
    arrays["pos"] = arrays["pos"].at[pgs].set(jnp.asarray(pos))
    pool.kv.arrays = arrays


def _rid_payload(pool, rid):
    """(k, v, pos) host arrays gathered through rid's block table."""
    pgs = np.asarray(pool.alloc.tables[rid])
    return ([np.asarray(k[:, pgs]) for k in pool.kv.arrays["k"]],
            [np.asarray(v[:, pgs]) for v in pool.kv.arrays["v"]],
            np.asarray(pool.kv.arrays["pos"])[pgs])


def test_export_import_round_trip_payloads_and_sharing():
    rng = np.random.default_rng(3)
    src = _pool()
    _fill(src, 1, 7, rng)
    # rid 2 shares rid 1's first page (CoW prefix sharing), then has its own
    src.alloc.adopt(2, src.alloc.tables[1][:1], 4)
    src.alloc.ensure(2, 6)
    src.alloc.commit(2, 2)
    before = _alloc_state(src.alloc)
    kv_before = [np.asarray(k) for k in src.kv.arrays["k"]]

    blob = src.export_pages([1, 2])
    # shared page exported ONCE: 2 (rid1) + 1 extra (rid2) distinct pages
    assert blob["n_pages"] == len({*src.alloc.tables[1],
                                   *src.alloc.tables[2]})
    # source untouched by export
    assert _alloc_state(src.alloc) == before
    for k0, k1 in zip(kv_before, src.kv.arrays["k"]):
        assert np.array_equal(k0, np.asarray(k1))

    dst = _pool()
    dst.import_pages(blob)
    # sharing preserved: same page object backs both tables' first block
    assert dst.alloc.tables[1][0] == dst.alloc.tables[2][0]
    assert dst.alloc.refcount[dst.alloc.tables[1][0]] == 2
    assert dst.alloc.tokens(1) == 7 and dst.alloc.tokens(2) == 6
    dst.alloc.check()
    # payloads land verbatim at the remapped ids
    for rid in (1, 2):
        sk, sv, sp = _rid_payload(src, rid)
        dk, dv, dp = _rid_payload(dst, rid)
        for a, b in zip(sk, dk):
            assert np.array_equal(a, b)
        for a, b in zip(sv, dv):
            assert np.array_equal(a, b)
        assert np.array_equal(sp, dp)
    # scratch page still fully invalid on the target
    assert np.all(np.asarray(dst.kv.arrays["pos"])[dst.kv.scratch_page] == -1)


def test_import_out_of_pages_is_atomic():
    rng = np.random.default_rng(4)
    src = _pool(num_pages=8)
    _fill(src, 1, 13, rng)                     # 4 pages
    blob = src.export_pages([1])

    dst = _pool(num_pages=8)
    _fill(dst, 9, 21, rng)                     # 6 pages -> only 2 free
    before = _alloc_state(dst.alloc)
    pos_before = np.asarray(dst.kv.arrays["pos"])
    with pytest.raises(OutOfPages):
        dst.import_pages(blob)
    assert _alloc_state(dst.alloc) == before
    assert np.array_equal(np.asarray(dst.kv.arrays["pos"]), pos_before)
    # after the blocker clears, the SAME transfer imports cleanly
    dst.scrub(dst.alloc.free(9))
    dst.import_pages(blob)
    assert dst.alloc.tokens(1) == 13
    dst.alloc.check()


def test_import_rejects_live_rid_and_page_size_mismatch():
    rng = np.random.default_rng(6)
    src = _pool()
    _fill(src, 1, 5, rng)
    blob = src.export_pages([1])
    dst = _pool()
    _fill(dst, 1, 5, rng)                      # rid 1 already live
    with pytest.raises(AssertionError):
        dst.import_pages(blob)
    other = KVPool.create(CFG, 8, 8, dtype=jnp.float32)
    with pytest.raises(AssertionError):
        other.import_pages(blob)

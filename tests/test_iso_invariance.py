"""THE paper invariant: ISO-chunked prefill logits == full-sequence prefill
logits, for every architecture family, any chunk count, any split policy."""
import jax
import jax.numpy as jnp
import pytest

from conftest import ALL_TINY, ISO_OFF, iso_cfg
from repro.core.overlap import AxisCtx
from repro.models import api

CTX = AxisCtx()


def _logits(cfg, iso, batch, params):
    out = api.prefill(params, cfg, CTX, iso, batch)
    return out["logits_local"].astype(jnp.float32)


@pytest.mark.parametrize("make_cfg", ALL_TINY, ids=lambda f: f.__name__)
@pytest.mark.parametrize("n_chunks", [2, 3])
def test_iso_matches_full_prefill(make_cfg, n_chunks, key):
    cfg = make_cfg()
    params = api.init_params(key, cfg, tp=1, dtype=jnp.float32)
    batch = api.make_inputs(cfg, 24, 2, key=key, dtype=jnp.float32)
    ref = _logits(cfg, ISO_OFF, batch, params)
    got = _logits(cfg, iso_cfg(n_chunks), batch, params)
    assert not bool(jnp.any(jnp.isnan(got)))
    assert float(jnp.max(jnp.abs(ref - got))) < 2e-4


@pytest.mark.parametrize("policy", ["even", "asymmetric", "adaptive"])
def test_split_policies_exact(policy, key):
    cfg = ALL_TINY[0]()
    params = api.init_params(key, cfg, tp=1, dtype=jnp.float32)
    batch = api.make_inputs(cfg, 40, 2, key=key, dtype=jnp.float32)
    ref = _logits(cfg, ISO_OFF, batch, params)
    got = _logits(cfg, iso_cfg(2, split_policy=policy), batch, params)
    assert float(jnp.max(jnp.abs(ref - got))) < 2e-4


def test_iso_cache_matches_baseline_cache(key):
    """Serving continuity: the KV cache assembled from ISO chunks must equal the
    baseline prefill cache."""
    cfg = ALL_TINY[0]()
    params = api.init_params(key, cfg, tp=1, dtype=jnp.float32)
    batch = api.make_inputs(cfg, 24, 2, key=key, dtype=jnp.float32)
    c0 = api.prefill(params, cfg, CTX, ISO_OFF, batch, return_cache=True,
                     cache_len=32)["caches"]
    c1 = api.prefill(params, cfg, CTX, iso_cfg(2), batch, return_cache=True,
                     cache_len=32)["caches"]
    for a, b in zip(jax.tree_util.tree_leaves(c0), jax.tree_util.tree_leaves(c1)):
        assert float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))) < 2e-4


def test_blockwise_attention_matches_dense(key):
    """The §Perf memory-term lever must be numerically invisible (incl. with
    ISO chunking and sliding windows)."""
    import dataclasses
    cfg = ALL_TINY[0]()
    cfg_b = dataclasses.replace(cfg, attn_impl="blockwise", attn_block_k=8)
    params = api.init_params(key, cfg, tp=1, dtype=jnp.float32)
    batch = api.make_inputs(cfg, 40, 2, key=key, dtype=jnp.float32)
    ref = _logits(cfg, iso_cfg(2), batch, params)
    got = _logits(cfg_b, iso_cfg(2), batch, params)
    assert float(jnp.max(jnp.abs(ref - got))) < 2e-4
    cfg_w = dataclasses.replace(cfg_b, sliding_window=16)
    cfg_w_ref = dataclasses.replace(cfg, sliding_window=16)
    ref_w = _logits(cfg_w_ref, ISO_OFF, batch, params)
    got_w = _logits(cfg_w, iso_cfg(2), batch, params)
    assert float(jnp.max(jnp.abs(ref_w - got_w))) < 2e-4


def test_unrolled_layers_match_scan(key):
    """The dry-run cost-probe path (unroll_layers) is mathematically identical."""
    cfg = ALL_TINY[0]()
    params = api.init_params(key, cfg, tp=1, dtype=jnp.float32)
    batch = api.make_inputs(cfg, 24, 2, key=key, dtype=jnp.float32)
    ref = api.prefill(params, cfg, CTX, iso_cfg(2), batch)["logits_local"]
    got = api.prefill(params, cfg, CTX, iso_cfg(2), batch,
                      unroll=True)["logits_local"]
    assert float(jnp.max(jnp.abs(ref - got))) < 1e-6


def test_min_chunk_tokens_disables_iso(key):
    cfg = ALL_TINY[0]()
    params = api.init_params(key, cfg, tp=1, dtype=jnp.float32)
    batch = api.make_inputs(cfg, 8, 1, key=key, dtype=jnp.float32)
    out = api.prefill(params, cfg, CTX, iso_cfg(2, min_chunk_tokens=64), batch)
    assert out["num_chunks"] == 1

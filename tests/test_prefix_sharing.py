"""Copy-on-write prefix/page sharing: allocator-level semantics and the
engine-level regression grid (identical tokens, fewer pages, sharer survives
donor eviction)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense, tiny_hybrid, iso_cfg
from repro.config import Config, ParallelConfig, ServingConfig
from repro.models import api
from repro.serving import PagedEngine, Request
from repro.serving.kvcache import (OutOfPages, PageAllocator, PrefixCache,
                                   pages_for)
from repro.serving.requests import SamplingParams


# ---------------------------------------------------------------------------
# allocator: refcounts, adopt, cow (pure python)
# ---------------------------------------------------------------------------

def test_adopt_shares_pages_and_free_keeps_sharer():
    a = PageAllocator(num_pages=8, page_size=4)
    a.ensure(1, 10)                            # 3 pages
    a.commit(1, 10)
    donor_pages = list(a.tables[1])
    a.adopt(2, donor_pages[:2], 8)
    assert a.used_pages == 3                   # nothing new allocated
    assert a.shared_pages() == 2
    assert a.tokens(2) == 8
    # donor eviction releases only its exclusive page
    released = a.free(1)
    assert released == [donor_pages[2]]
    assert a.tables[2] == donor_pages[:2]      # sharer untouched
    assert a.shared_pages() == 0               # now exclusively the sharer's
    assert a.free(2) == donor_pages[:2]
    assert a.free_pages == a.num_pages


def test_cow_detaches_shared_page():
    a = PageAllocator(num_pages=8, page_size=4)
    a.ensure(1, 8)
    a.commit(1, 8)
    a.adopt(2, list(a.tables[1]), 7)
    old = a.tables[2][1]
    pair = a.cow(2, 1)
    assert pair is not None and pair[0] == old
    new = pair[1]
    assert a.tables[2][1] == new and a.tables[1][1] == old
    assert a.refcount[old] == 1 and a.refcount[new] == 1
    assert a.cow(2, 1) is None                 # already exclusive
    # second sharer of page 0 still refcounted correctly
    assert a.refcount[a.tables[1][0]] == 2


def test_cow_out_of_pages_mutates_nothing():
    a = PageAllocator(num_pages=2, page_size=4)
    a.ensure(1, 8)
    a.commit(1, 8)
    a.adopt(2, list(a.tables[1]), 7)
    before = (list(a.tables[2]), dict(a.refcount))
    with pytest.raises(OutOfPages):
        a.cow(2, 0)
    assert (list(a.tables[2]), dict(a.refcount)) == before


def test_prefix_cache_hash_lookup_verifies_tokens():
    a = PageAllocator(num_pages=16, page_size=4)
    pc = PrefixCache(page_size=4)
    donor = np.arange(2, 14, dtype=np.int32)   # 12 tokens = 3 pages
    pc.register(1, donor)
    a.ensure(1, 12)
    a.commit(1, 12)
    # full aligned match + token-wise extension into the partial page
    hit = pc.lookup(np.concatenate([donor, [99, 98]]).astype(np.int32), a)
    assert hit is not None
    rid, t, pages = hit
    assert rid == 1 and t == 12 and pages == a.tables[1][:3]
    # diverging mid-page: only the aligned prefix + LCP shares
    q = donor.copy()
    q[9] = 77                                  # diverge inside page 2
    hit = pc.lookup(q, a)
    assert hit is not None and hit[1] == 9 and len(hit[2]) == 3
    # identical prompt: capped at len - 1 so one token is always prefilled
    hit = pc.lookup(donor, a)
    assert hit is not None and hit[1] == 11
    # dead donor stops matching, no eager invalidation needed
    a.free(1)
    assert pc.lookup(np.concatenate([donor, [99]]).astype(np.int32), a) is None


# ---------------------------------------------------------------------------
# engine regression: shared-prompt workload
# ---------------------------------------------------------------------------

def _engine(cfg, iso, params, *, sharing, num_pages=0, max_batch=2,
            max_len=96, budget=64):
    sv = ServingConfig(page_size=8, max_batch=max_batch, max_len=max_len,
                       prefill_token_budget=budget, num_pages=num_pages,
                       prefix_sharing=sharing)
    return PagedEngine(Config(model=cfg, parallel=ParallelConfig(data=1,
                                                                 model=1),
                              iso=iso, serving=sv), params)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    return cfg, iso, params


def _run(eng, prompts, new=6):
    rids = [eng.add_request(Request(
        prompt=p.copy(), sampling=SamplingParams(max_new_tokens=new,
                                                 eos_id=-1)))
            for p in prompts]
    outs = eng.run_until_complete()
    return [outs[r] for r in rids]


def test_shared_prompt_identical_tokens_fewer_pages(dense_setup):
    cfg, iso, params = dense_setup
    rng = np.random.default_rng(11)
    system = rng.integers(2, 64, 40).astype(np.int32)
    prompts = [np.concatenate([system,
                               rng.integers(2, 64, n).astype(np.int32)])
               for n in (9, 13)]

    shared_eng = _engine(cfg, iso, params, sharing=True)
    shared = _run(shared_eng, prompts)
    plain_eng = _engine(cfg, iso, params, sharing=False)
    plain = _run(plain_eng, prompts)

    assert shared == plain
    m = shared_eng.metrics
    assert m["prefix_shared_tokens"] >= 40
    assert m["peak_used_pages"] < plain_eng.metrics["peak_used_pages"]
    # all refcounts unwound after completion
    assert shared_eng.alloc.free_pages == shared_eng.alloc.num_pages
    assert shared_eng.alloc.shared_pages() == 0


def test_identical_prompts_trigger_cow(dense_setup):
    """An identical prompt shares through the donor's partial last page; the
    sharer's first write must copy-on-write, never corrupt the donor."""
    cfg, iso, params = dense_setup
    rng = np.random.default_rng(12)
    p = rng.integers(2, 64, 37).astype(np.int32)   # NOT page-aligned

    shared_eng = _engine(cfg, iso, params, sharing=True)
    shared = _run(shared_eng, [p, p])
    plain = _run(_engine(cfg, iso, params, sharing=False), [p, p])
    assert shared == plain
    assert shared[0] == shared[1]                  # greedy: same stream
    m = shared_eng.metrics
    assert m["prefix_shared_tokens"] > 0
    assert m["cow_copies"] > 0


def test_eviction_of_one_sharer_preserves_the_other(dense_setup):
    """Freeing one sharer's pages must not invalidate the survivor's KV."""
    cfg, iso, params = dense_setup
    rng = np.random.default_rng(13)
    system = rng.integers(2, 64, 32).astype(np.int32)
    pa = np.concatenate([system, rng.integers(2, 64, 5).astype(np.int32)])
    pb = np.concatenate([system, rng.integers(2, 64, 7).astype(np.int32)])

    eng = _engine(cfg, iso, params, sharing=True, max_batch=2)
    ra = eng.add_request(Request(prompt=pa.copy(),
                                 sampling=SamplingParams(max_new_tokens=3,
                                                         eos_id=-1)))
    rb = eng.add_request(Request(prompt=pb.copy(),
                                 sampling=SamplingParams(max_new_tokens=12,
                                                         eos_id=-1)))
    outs = eng.run_until_complete()   # A finishes (and frees) well before B
    assert eng.metrics["prefix_shared_tokens"] > 0

    # unshared reference with the same per-request sampling budgets
    eng2 = _engine(cfg, iso, params, sharing=False, max_batch=2)
    ra2 = eng2.add_request(Request(prompt=pa.copy(),
                                   sampling=SamplingParams(max_new_tokens=3,
                                                           eos_id=-1)))
    rb2 = eng2.add_request(Request(prompt=pb.copy(),
                                   sampling=SamplingParams(max_new_tokens=12,
                                                           eos_id=-1)))
    ref = eng2.run_until_complete()
    assert outs[ra] == ref[ra2]
    assert outs[rb] == ref[rb2]


def test_sharing_disabled_for_recurrent_archs():
    """Hybrid (SSM-carrying) stacks must not share pages: per-slot recurrent
    state cannot be adopted."""
    cfg = tiny_hybrid(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    eng = _engine(cfg, iso, params, sharing=True)
    assert eng.prefix_cache is None
    rng = np.random.default_rng(14)
    p = rng.integers(2, 64, 24).astype(np.int32)
    outs = _run(eng, [p, p], new=3)
    assert eng.metrics["prefix_shared_tokens"] == 0
    assert outs[0] == outs[1]

"""Split-policy properties (hypothesis) + quantized-collective numerics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from conftest import tiny_dense
from repro.config import ISOConfig
from repro.core.chunking import adaptive_split, even_split, split_chunks
from repro.core.quantized_collectives import dequantize_int8, quantize_int8


@given(seq=st.integers(16, 100_000), n=st.integers(2, 4),
       align=st.sampled_from([4, 64, 128]))
@settings(max_examples=200, deadline=None)
def test_split_partitions_sequence(seq, n, align):
    iso = ISOConfig(enabled=True, num_chunks=n, min_chunk_tokens=4,
                    chunk_align=align)
    lengths = split_chunks(seq, iso, tiny_dense())
    assert sum(lengths) == seq
    assert all(l > 0 for l in lengths)
    if len(lengths) > 1 and seq >= n * align:
        assert all(l % align == 0 for l in lengths[:-1])


@given(seq=st.integers(1, 512))
@settings(max_examples=100, deadline=None)
def test_split_disabled_below_threshold(seq):
    iso = ISOConfig(enabled=True, num_chunks=2, min_chunk_tokens=256)
    lengths = split_chunks(seq, iso, tiny_dense())
    if seq < 512:
        assert lengths == (seq,)


def test_adaptive_split_balances_quadratic_cost():
    """The adaptive boundary must be PAST the midpoint (the second chunk's
    attention is costlier — paper §6), approaching it as the linear term grows."""
    cfg = tiny_dense(d_model=1024, num_heads=16, num_kv_heads=16, d_ff=64)
    s = 32768
    lengths = adaptive_split(s, 2, cfg, align=128)
    assert lengths[0] > s // 2, lengths
    even = even_split(s, 2, 128)
    assert even == (s // 2, s // 2)


@given(shape=st.sampled_from([(4, 64), (2, 8, 32)]),
       scale=st.floats(0.01, 100.0))
@settings(max_examples=50, deadline=None)
def test_int8_roundtrip_error_bound(shape, scale):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, shape, jnp.float32) * scale
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    bound = np.abs(np.asarray(x)).max(axis=-1, keepdims=True) / 127.0 + 1e-6
    assert np.all(np.abs(np.asarray(back - x)) <= bound)

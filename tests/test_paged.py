"""Paged KV-cache + token-budget scheduler: allocator invariants, scheduler
budget/fairness properties, and end-to-end paged-vs-dense token equality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense, tiny_hybrid, tiny_vlm, iso_cfg
from repro.config import Config, ParallelConfig, ServingConfig
from repro.models import api
from repro.serving import Engine, PagedEngine, Request
from repro.serving.kvcache import OutOfPages, PageAllocator, pages_for
from repro.serving.requests import SamplingParams
from repro.serving.scheduler import TokenBudgetScheduler


# ---------------------------------------------------------------------------
# page allocator invariants (pure python, no JAX)
# ---------------------------------------------------------------------------

def _check_invariants(a: PageAllocator):
    allocated = [pg for t in a.tables.values() for pg in t]
    assert len(allocated) == len(set(allocated)), "page aliased to two requests"
    assert len(allocated) + a.free_pages == a.num_pages, "page leak"
    for rid, table in a.tables.items():
        assert a.tokens(rid) <= len(table) * a.page_size


def test_allocator_exact_accounting_random_walk():
    rng = np.random.default_rng(0)
    a = PageAllocator(num_pages=13, page_size=4)
    live = {}
    for step in range(500):
        op = rng.integers(0, 3)
        if op == 0:                                   # grow some request
            rid = int(rng.integers(0, 8))
            want = live.get(rid, 0) + int(rng.integers(1, 9))
            try:
                a.ensure(rid, want)
                a.commit(rid, want - live.get(rid, 0))
                live[rid] = want
            except OutOfPages:
                # failed ensure must not leak pages
                pass
        elif op == 1 and live:                        # free one
            rid = rng.choice(list(live))
            a.free(int(rid))
            live.pop(int(rid))
        _check_invariants(a)
    assert sum(a.lengths.values()) == sum(live.values())


def test_allocator_block_table_covers_tokens():
    a = PageAllocator(num_pages=10, page_size=4)
    a.ensure(1, 9)
    a.commit(1, 9)
    assert len(a.tables[1]) == pages_for(9, 4) == 3
    row = a.block_table(1, max_blocks=5)
    assert list(row[:3]) == a.tables[1] and all(row[3:] == -1)
    assert a.fragmentation() == 3 * 4 - 9
    assert 0 < a.utilization() <= 1


def test_allocator_double_free_rejected():
    a = PageAllocator(num_pages=4, page_size=2)
    a.ensure(1, 4)
    pages = list(a.tables[1])
    a.free(1)
    # sneak the freed table back in — the second free must trip the assert
    a.tables[1] = pages
    with pytest.raises(AssertionError):
        a.free(1)


def test_allocator_out_of_pages_allocates_nothing():
    a = PageAllocator(num_pages=3, page_size=2)
    a.ensure(1, 4)                                    # 2 pages
    free_before = a.free_pages
    with pytest.raises(OutOfPages):
        a.ensure(2, 6)                                # needs 3, only 1 free
    assert a.free_pages == free_before
    assert 2 not in a.tables


# ---------------------------------------------------------------------------
# scheduler properties (pure python)
# ---------------------------------------------------------------------------

def test_scheduler_budget_respected_and_whole_chunks():
    s = TokenBudgetScheduler("fcfs", prefill_token_budget=20)
    for rid in (1, 2, 3):
        s.add(rid)
    states = [(1, 0, (8, 8)), (2, 0, (8, 8, 8)), (3, 8, (8, 8))]
    grants = s.grant_prefill(states)
    total = sum(g.n_tokens for g in grants)
    assert total <= 20
    # grants land on chunk boundaries
    plans = {1: (8, 8), 2: (8, 8, 8), 3: (8, 8)}
    starts = {1: 0, 2: 0, 3: 8}
    for g in grants:
        ends = np.cumsum(plans[g.rid])
        assert g.start == starts[g.rid]
        assert (g.start + g.n_tokens) in ends
    # FCFS: rid 1 first, fully granted
    assert grants[0].rid == 1 and grants[0].n_tokens == 16 and grants[0].last


def test_scheduler_head_of_line_always_progresses():
    s = TokenBudgetScheduler("fcfs", prefill_token_budget=4)
    s.add(1)
    grants = s.grant_prefill([(1, 0, (16, 16))])
    assert len(grants) == 1 and grants[0].n_tokens == 16  # one whole chunk


def test_scheduler_priority_policy_orders_and_evicts():
    s = TokenBudgetScheduler("priority", prefill_token_budget=8)
    s.add(1, priority=0)
    s.add(2, priority=5)
    s.add(3, priority=5)
    assert s.pop_waiting() == 2                       # high prio, earliest
    grants = s.grant_prefill([(1, 0, (8,)), (3, 0, (8,))])
    assert grants[0].rid == 3                         # prio beats arrival
    # victim = lowest priority, youngest within class
    assert s.pick_victim([1, 3]) == 1
    assert s.pick_victim([1, 3], protect=[1]) == 3
    assert s.pick_victim([], protect=[]) is None


def test_scheduler_grant_bucketing_rounds_padded():
    """With buckets, every grant's forward-call length (``padded``) is the
    smallest bucket >= n_tokens; without, padded == n_tokens."""
    s = TokenBudgetScheduler("fcfs", prefill_token_budget=64,
                             grant_buckets=(16, 32, 64))
    for rid in (1, 2):
        s.add(rid)
    grants = s.grant_prefill([(1, 0, (8, 9)), (2, 0, (24,))])
    by_rid = {g.rid: g for g in grants}
    assert by_rid[1].n_tokens == 17 and by_rid[1].padded == 32
    assert by_rid[2].n_tokens == 24 and by_rid[2].padded == 32
    plain = TokenBudgetScheduler("fcfs", prefill_token_budget=64)
    plain.add(1)
    (g,) = plain.grant_prefill([(1, 0, (8, 9))])
    assert g.padded == g.n_tokens == 17


def test_scheduler_cancel_while_waiting_forgets_queue_entry():
    """Regression: ``forget`` on a still-waiting rid must also drop it from
    the waiting queue — it used to leave the rid behind with no ``_arrival``,
    so the next ``pop_waiting`` KeyError'd inside ``_key``."""
    s = TokenBudgetScheduler("fcfs", prefill_token_budget=8)
    for rid in (1, 2, 3):
        s.add(rid)
    s.forget(2)                                   # cancel before admission
    assert 2 not in s.waiting
    assert s.pop_waiting() == 1                   # no KeyError
    assert s.pop_waiting() == 3
    assert s.pop_waiting() is None
    # forgetting a never-seen or already-popped rid stays a no-op
    s.forget(2)
    s.forget(99)


def test_scheduler_requeue_front_is_idempotent():
    """Regression: double-preemption bookkeeping (or a requeue racing an
    un-popped rid) must not enqueue a duplicate — a duplicate entry survives
    the single ``waiting.remove`` in ``pop_waiting`` and would be admitted
    twice."""
    s = TokenBudgetScheduler("fcfs", prefill_token_budget=8)
    s.add(1)
    s.add(2)
    rid = s.pop_waiting()
    assert rid == 1
    s.requeue_front(1)
    s.requeue_front(1)                            # double requeue
    assert s.waiting.count(1) == 1
    s.requeue_front(2)                            # already waiting, un-popped
    assert s.waiting.count(2) == 1
    # arrival preserved: 1 still beats 2
    assert s.pop_waiting() == 1
    assert s.pop_waiting() == 2
    assert s.pop_waiting() is None


def test_scheduler_pick_victim_protect_semantics():
    """pick_victim honours ``protect`` for any iterable (the hoisted-set fix
    must not change semantics) and still evicts in reverse policy order."""
    s = TokenBudgetScheduler("fcfs", prefill_token_budget=8)
    for rid in (1, 2, 3, 4):
        s.add(rid)
    assert s.pick_victim([1, 2, 3, 4]) == 4              # youngest
    assert s.pick_victim([1, 2, 3, 4], protect=(4,)) == 3
    assert s.pick_victim([1, 2, 3, 4], protect=iter([3, 4])) == 2
    assert s.pick_victim([1], protect=[1]) is None


def test_scheduler_fcfs_fairness_across_steps():
    """Every waiting request is eventually granted (no starvation)."""
    s = TokenBudgetScheduler("fcfs", prefill_token_budget=8)
    plans = {rid: (8, 8) for rid in range(4)}
    for rid in plans:
        s.add(rid)
    done = {rid: 0 for rid in plans}
    for _ in range(20):
        states = [(r, d, plans[r]) for r, d in done.items() if d < 16]
        if not states:
            break
        for g in s.grant_prefill(states):
            done[g.rid] += g.n_tokens
    assert all(d == 16 for d in done.values())


# ---------------------------------------------------------------------------
# end-to-end: paged engine == dense engine, token for token
# ---------------------------------------------------------------------------

def _dense_engine(cfg, iso, max_batch=2, max_len=160):
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    iso=iso)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    return Engine(config, params, mesh=None, max_batch=max_batch,
                  max_len=max_len, bucket=16), params


def _paged_engine(cfg, iso, params, *, budget=16, page_size=8, max_len=160,
                  num_pages=0, policy="fcfs", max_batch=2):
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    iso=iso,
                    serving=ServingConfig(page_size=page_size,
                                          max_batch=max_batch, max_len=max_len,
                                          prefill_token_budget=budget,
                                          num_pages=num_pages,
                                          scheduler_policy=policy))
    return PagedEngine(config, params)


def _mixed_requests(rng, lengths, new=5):
    return [Request(prompt=rng.integers(2, 64, n).astype(np.int32),
                    sampling=SamplingParams(max_new_tokens=new, eos_id=-1))
            for n in lengths]


def test_paged_matches_dense_mixed_lengths():
    """Chunked-prefill paged engine must reproduce the dense engine's greedy
    stream on a mixed-length workload that forces resumed prefill."""
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    dense, params = _dense_engine(cfg, iso)
    rng = np.random.default_rng(3)
    reqs = _mixed_requests(rng, (70, 12, 33, 7))
    d_rids = [dense.add_request(r) for r in reqs]
    d_out = dense.run_until_complete()

    paged = _paged_engine(cfg, iso, params, budget=16)
    reqs2 = [Request(prompt=r.prompt, sampling=r.sampling) for r in reqs]
    p_rids = [paged.add_request(r) for r in reqs2]
    p_out = paged.run_until_complete()
    for dr, pr in zip(d_rids, p_rids):
        assert d_out[dr] == p_out[pr], (dr, d_out[dr], p_out[pr])
    # chunked prefill really happened (the 70-token prompt needs >1 call)
    assert paged.metrics["prefill_calls"] > len(reqs)


def test_paged_matches_dense_hybrid_window():
    """SSM state resume + sliding-window attention through the page pool.

    Prompt lengths are multiples of the dense engine's bucket (16): the dense
    engine pads prompts up to the bucket and its SSM prefill state absorbs the
    pad tokens, so only pad-free shapes are exactly comparable (the paged
    engine never pads — it matches the incremental reference everywhere)."""
    cfg = tiny_hybrid(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    dense, params = _dense_engine(cfg, iso, max_len=96)
    rng = np.random.default_rng(4)
    reqs = _mixed_requests(rng, (32, 16), new=4)
    d_rids = [dense.add_request(r) for r in reqs]
    d_out = dense.run_until_complete()

    paged = _paged_engine(cfg, iso, params, budget=16, max_len=96)
    reqs2 = [Request(prompt=r.prompt, sampling=r.sampling) for r in reqs]
    p_rids = [paged.add_request(r) for r in reqs2]
    p_out = paged.run_until_complete()
    for dr, pr in zip(d_rids, p_rids):
        assert d_out[dr] == p_out[pr]


def test_paged_vlm_matches_dense():
    cfg = tiny_vlm(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    dense, params = _dense_engine(cfg, iso)
    rng = np.random.default_rng(5)
    patches = (rng.standard_normal((cfg.num_patches, cfg.d_model)) * 0.1
               ).astype(np.float32)
    prompt = rng.integers(2, 64, 14).astype(np.int32)
    sp = SamplingParams(max_new_tokens=4, eos_id=-1)
    dr = dense.add_request(Request(prompt=prompt, patches=patches, sampling=sp))
    d_out = dense.run_until_complete()
    paged = _paged_engine(cfg, iso, params)
    pr = paged.add_request(Request(prompt=prompt, patches=patches, sampling=sp))
    p_out = paged.run_until_complete()
    assert d_out[dr] == p_out[pr]


def test_paged_preemption_recompute_exact():
    """A pool too small for both requests forces eviction + recompute; the
    evicted request's stream must still match the unpressured engine."""
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    rng = np.random.default_rng(6)
    prompts = [rng.integers(2, 64, 40).astype(np.int32) for _ in range(2)]

    def run(num_pages):
        eng = _paged_engine(cfg, iso, params, budget=64, page_size=8,
                            max_len=64, num_pages=num_pages)
        rids = [eng.add_request(Request(
            prompt=p.copy(), sampling=SamplingParams(max_new_tokens=8,
                                                     eos_id=-1)))
                for p in prompts]
        outs = eng.run_until_complete()
        return [outs[r] for r in rids], eng.metrics

    roomy, m_roomy = run(num_pages=0)          # default: fits both
    tight, m_tight = run(num_pages=8)          # 64 tokens: forces eviction
    assert m_tight["preemptions"] > 0
    assert m_roomy["preemptions"] == 0
    assert roomy == tight


def test_paged_page_accounting_end_to_end():
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    eng = _paged_engine(cfg, iso, params, budget=16)
    rng = np.random.default_rng(7)
    for r in _mixed_requests(rng, (30, 11), new=3):
        eng.add_request(r)
    # mid-flight: pages in use, stats coherent
    eng.step()
    stats = eng.page_stats()
    assert stats["used_pages"] > 0
    assert stats["kv_bytes_live"] > 0
    assert 0 < stats["utilization"] <= 1
    eng.run_until_complete()
    # all pages returned after completion
    assert eng.alloc.free_pages == eng.alloc.num_pages
    assert eng.page_stats()["kv_bytes_live"] == 0


def test_paged_page_reuse_no_stale_kv():
    """Freed pages must not leak the dead request's KV: a later request whose
    final partial block only partly overwrites a reused page would otherwise
    attend the old tenant's tail positions (pos entries still >= 0).
    Prompt lengths are deliberately NOT page multiples."""
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    rng = np.random.default_rng(9)
    p_a = rng.integers(2, 64, 37).astype(np.int32)
    p_b = rng.integers(2, 64, 21).astype(np.int32)
    sp = lambda: SamplingParams(max_new_tokens=5, eos_id=-1)

    eng = _paged_engine(cfg, iso, params, budget=64, page_size=8, max_len=64,
                        num_pages=8, max_batch=1)
    eng.add_request(Request(prompt=p_a, sampling=sp()))
    eng.run_until_complete()
    rb = eng.add_request(Request(prompt=p_b, sampling=sp()))  # reuses A's pages
    out_reused = eng.run_until_complete()[rb]

    fresh = _paged_engine(cfg, iso, params, budget=64, page_size=8, max_len=64,
                          num_pages=8, max_batch=1)
    rf = fresh.add_request(Request(prompt=p_b, sampling=sp()))
    assert out_reused == fresh.run_until_complete()[rf]


def test_paged_rejects_request_exceeding_pool():
    """A request that cannot fit even with every other request evicted must be
    rejected at admission, not spin the engine forever."""
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    eng = _paged_engine(cfg, iso, params, page_size=8, max_len=96, num_pages=4)
    rng = np.random.default_rng(10)
    with pytest.raises(ValueError, match="num_pages"):
        eng.add_request(Request(prompt=rng.integers(2, 64, 60).astype(np.int32),
                                sampling=SamplingParams(max_new_tokens=8)))


def test_paged_rejects_oversized_request():
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    eng = _paged_engine(cfg, iso, params, max_len=32)
    rng = np.random.default_rng(8)
    with pytest.raises(ValueError):
        eng.add_request(Request(prompt=rng.integers(2, 64, 40).astype(np.int32),
                                sampling=SamplingParams(max_new_tokens=8)))

"""Serving engine: continuous batching correctness vs an incremental reference,
window-cache decode, multi-family requests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense, tiny_hybrid, tiny_vlm, iso_cfg, ISO_OFF
from repro.config import Config, ParallelConfig
from repro.core.overlap import AxisCtx
from repro.models import api
from repro.serving import Engine, Request
from repro.serving.requests import SamplingParams

CTX = AxisCtx()


def _engine(cfg, iso=None, max_batch=2, max_len=128):
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    iso=iso or iso_cfg(2, min_chunk_tokens=16, chunk_align=8))
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    return Engine(config, params, mesh=None, max_batch=max_batch,
                  max_len=max_len, bucket=16), params, config


def _ref_generate(params, cfg, prompt, n_new, extra=None):
    toks = list(prompt)
    out = []
    for _ in range(n_new):
        batch = {"tokens": jnp.asarray(np.array(toks, np.int32)[None])}
        if extra:
            batch.update(extra)
        o = api.prefill(params, cfg, CTX, ISO_OFF, batch, logits_mode="last")
        nxt = int(jnp.argmax(o["logits_local"][0, -1, :cfg.vocab_size]))
        toks.append(nxt)
        out.append(nxt)
    return out


def test_engine_matches_incremental_reference():
    cfg = tiny_dense(vocab_size=64)
    eng, params, _ = _engine(cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, 64, n).astype(np.int32) for n in (10, 23, 7)]
    rids = [eng.add_request(Request(prompt=p, sampling=SamplingParams(
        max_new_tokens=5, eos_id=-1))) for p in prompts]
    outs = eng.run_until_complete()
    for rid, p in zip(rids, prompts):
        assert outs[rid] == _ref_generate(params, cfg, p, 5)


def test_engine_continuous_batching_slots_reused():
    cfg = tiny_dense(vocab_size=64)
    eng, _, _ = _engine(cfg, max_batch=2)
    rng = np.random.default_rng(1)
    for i in range(5):                    # more requests than slots
        eng.add_request(Request(prompt=rng.integers(2, 64, 8).astype(np.int32),
                                sampling=SamplingParams(max_new_tokens=3,
                                                        eos_id=-1)))
    outs = eng.run_until_complete()
    assert len(outs) == 5
    assert all(len(v) == 3 for v in outs.values())
    assert eng.metrics["completed"] == 5


def test_engine_window_cache_hybrid():
    """Sliding-window arch: generation must work past the window size."""
    cfg = tiny_hybrid(sliding_window=16, vocab_size=64)
    eng, params, _ = _engine(cfg, max_len=64)
    rng = np.random.default_rng(2)
    prompt = rng.integers(2, 64, 30).astype(np.int32)   # prompt > window
    rid = eng.add_request(Request(prompt=prompt, sampling=SamplingParams(
        max_new_tokens=4, eos_id=-1)))
    outs = eng.run_until_complete()
    assert len(outs[rid]) == 4
    assert all(0 <= t < 64 for t in outs[rid])


def test_engine_vlm_request():
    cfg = tiny_vlm(vocab_size=64)
    eng, params, _ = _engine(cfg)
    rng = np.random.default_rng(3)
    patches = (rng.standard_normal((cfg.num_patches, cfg.d_model)) * 0.1
               ).astype(np.float32)
    prompt = rng.integers(2, 64, 12).astype(np.int32)
    rid = eng.add_request(Request(prompt=prompt, patches=patches,
                                  sampling=SamplingParams(max_new_tokens=4,
                                                          eos_id=-1)))
    outs = eng.run_until_complete()
    ref = _ref_generate(params, cfg, prompt, 4,
                        extra={"patches": jnp.asarray(patches)[None]})
    assert outs[rid] == ref


def test_speculative_decode_matches_greedy():
    """Self-speculative verify (paper §Discussion) must be output-invariant:
    exactly the plain greedy stream, just fewer model calls when drafts hit."""
    cfg = tiny_dense(vocab_size=64)
    rng = np.random.default_rng(5)
    # repetitive prompt so the bigram draft gets real acceptances
    base = rng.integers(2, 64, 6).astype(np.int32)
    prompt = np.tile(base, 5)
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    iso=iso_cfg(2, min_chunk_tokens=16, chunk_align=8))
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)

    def gen(spec_k):
        eng = Engine(config, params, mesh=None, max_batch=2, max_len=128,
                     bucket=16, spec_k=spec_k)
        rid = eng.add_request(Request(prompt=prompt.copy(),
                                      sampling=SamplingParams(
                                          max_new_tokens=10, eos_id=-1)))
        outs = eng.run_until_complete()
        return outs[rid], eng.metrics

    plain, m_plain = gen(0)
    spec, m_spec = gen(3)
    assert spec == plain, (spec, plain)
    assert len(spec) == 10
    # the draft must have amortised at least one call
    assert m_spec["decode_calls"] <= m_plain["decode_calls"]


def test_multi_token_decode_matches_sequential(key):
    """K-token verify forward == K sequential single-token decodes."""
    cfg = tiny_dense(vocab_size=64)
    params = api.init_params(key, cfg, tp=1, dtype=jnp.float32)
    batch = api.make_inputs(cfg, 16, 2, key=key, dtype=jnp.float32)
    out = api.prefill(params, cfg, CTX, ISO_OFF, batch, return_cache=True,
                      cache_len=32)
    toks = jax.random.randint(jax.random.fold_in(key, 9), (2, 3), 2, 64)
    lengths = jnp.full((2,), 16, jnp.int32)
    # multi-token
    lg_multi, _ = api.decode_step(params, cfg, CTX, toks, out["caches"],
                                  lengths)
    # sequential
    caches = out["caches"]
    lgs = []
    for j in range(3):
        lg, caches = api.decode_step(params, cfg, CTX, toks[:, j:j + 1], caches,
                                     lengths + j)
        lgs.append(lg)
    lg_seq = jnp.concatenate(lgs, axis=1)
    assert float(jnp.max(jnp.abs(lg_multi - lg_seq))) < 2e-4


def test_engine_eos_stops_early():
    cfg = tiny_dense(vocab_size=64)
    eng, params, _ = _engine(cfg)
    rng = np.random.default_rng(0)
    prompt = rng.integers(2, 64, 10).astype(np.int32)
    ref = _ref_generate(params, cfg, prompt, 8)
    eos = ref[2]                            # force stop at the 3rd token
    rid = eng.add_request(Request(prompt=prompt, sampling=SamplingParams(
        max_new_tokens=8, eos_id=eos)))
    outs = eng.run_until_complete()
    assert outs[rid] == ref[:3]

"""Paged speculative decoding (K-token verify through the flash-decode
kernel) end-to-end, plus the serving-bookkeeping regressions that rode along:

  * PagedEngine(spec_k>0) greedy streams are token-identical to the plain
    engine on mixed traffic — including prefix sharing and forced recompute
    preemption — with a measured accept rate > 1 on repetitive prompts;
  * the scratch page's ``pos`` entries stay -1 across a whole serving trace
    (pad-tail prefill scatters, inactive decode slots, rejected verify
    positions all route there);
  * decode-token accounting: ``decode_tokens`` counts exactly the decode-step
    tokens — total events minus prefill-sampled ones — for both engines;
  * the dense Engine clears per-slot lengths/last_tokens/drafts on finish
    (a stale length used to disable the speculative gate for the rest of the
    batch once one long request completed).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense, iso_cfg
from repro.config import Config, ParallelConfig, ServingConfig
from repro.models import api
from repro.serving import Engine, PagedEngine, Request
from repro.serving.requests import SamplingParams

CFG = tiny_dense(vocab_size=64)
ISO = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)


@pytest.fixture(scope="module")
def params():
    return api.init_params(jax.random.PRNGKey(0), CFG, tp=1,
                           dtype=jnp.float32)


def _paged(params, *, spec_k=0, budget=16, page_size=8, max_len=160,
           num_pages=0, max_batch=2, prefix_sharing=True):
    config = Config(model=CFG, parallel=ParallelConfig(data=1, model=1),
                    iso=ISO,
                    serving=ServingConfig(page_size=page_size,
                                          max_batch=max_batch,
                                          max_len=max_len,
                                          prefill_token_budget=budget,
                                          num_pages=num_pages,
                                          prefix_sharing=prefix_sharing,
                                          spec_k=spec_k))
    return PagedEngine(config, params)


def _repetitive(rng, n, period=6):
    base = rng.integers(2, 64, period).astype(np.int32)
    return np.tile(base, -(-n // period))[:n]


def _submit(eng, prompts, new=8):
    return [eng.add_request(Request(
        prompt=p.copy(),
        sampling=SamplingParams(max_new_tokens=new, eos_id=-1)))
        for p in prompts]


def _drain(eng):
    """run_until_complete that also collects per-step events and checks the
    scratch-page pos invariant after every step."""
    events = []
    scratch = eng.kv.scratch_page
    for _ in range(10_000):
        events += eng.step()
        pos_scr = np.asarray(eng.kv.arrays["pos"])[scratch]
        assert np.all(pos_scr == -1), \
            f"scratch page leaked real positions: {pos_scr}"
        if not eng.scheduler.waiting and all(s is None for s in eng.slots):
            break
    outs = {st.request.rid: st.generated for st in eng._finished}
    return outs, events


def _mixed_prompts(rng):
    """Repetitive (draft-friendly), random, and a prefix-sharing pair."""
    shared = rng.integers(2, 64, 24).astype(np.int32)
    return [
        _repetitive(rng, 30),
        rng.integers(2, 64, 33).astype(np.int32),
        np.concatenate([shared, rng.integers(2, 64, 9).astype(np.int32)]),
        np.concatenate([shared, rng.integers(2, 64, 5).astype(np.int32)]),
    ]


@pytest.mark.parametrize("spec_k", [2, 4])
def test_spec_matches_plain_mixed_traffic(params, spec_k):
    """Speculation must be output-invariant on mixed traffic with chunked
    prefill and CoW prefix sharing, and actually accept on the repetitive
    prompt."""
    rng = np.random.default_rng(11)
    prompts = _mixed_prompts(rng)

    plain = _paged(params)
    p_rids = _submit(plain, prompts)
    p_outs, _ = _drain(plain)
    assert len(plain._decode_fns) == 1, \
        "plain decode must compile exactly one (K=1) closure"

    spec = _paged(params, spec_k=spec_k)
    s_rids = _submit(spec, prompts)
    s_outs, _ = _drain(spec)
    for pr, sr in zip(p_rids, s_rids):
        assert p_outs[pr] == s_outs[sr], (pr, p_outs[pr], s_outs[sr])
    m = spec.metrics
    assert m["spec_calls"] > 0
    assert spec.accepted_per_call() > 1.0, m
    # sharing still happened under speculation
    assert m["prefix_shared_tokens"] > 0
    assert plain.metrics["prefix_shared_tokens"] > 0
    # one K=1 closure + one verify closure, nothing per-step
    assert len(spec._decode_fns) <= 2


def test_spec_with_forced_preemption(params):
    """A pool too small for both requests forces eviction + recompute; the
    speculative engine must still reproduce the unpressured plain stream
    (accepted tokens fold into the re-prefill prompt)."""
    rng = np.random.default_rng(12)
    prompts = [_repetitive(rng, 40, period=5), _repetitive(rng, 40, period=7)]

    def run(spec_k, num_pages):
        eng = _paged(params, spec_k=spec_k, budget=64, max_len=64,
                     num_pages=num_pages)
        rids = _submit(eng, prompts, new=8)
        outs, _ = _drain(eng)
        return [outs[r] for r in rids], eng.metrics

    roomy, m_roomy = run(0, num_pages=0)
    tight, m_tight = run(2, num_pages=8)       # 64 tokens: forces eviction
    assert m_tight["preemptions"] > 0
    assert m_roomy["preemptions"] == 0
    assert roomy == tight


def test_spec_decode_phase_eviction_mid_batch(params):
    """Regression: decode-phase capacity growth can evict a victim that sits
    LATER in the active list (both requests cross a page boundary with zero
    free pages; the youngest is evicted while an earlier active entry
    exists) — dropping the victim must not compare RequestStates
    (numpy-prompt __eq__ is ambiguous), and the pressured speculative stream
    must equal the unpressured plain one.  Sharing is off so page
    consumption is deterministic; the spec engine's headroom fallback
    degrades the window to K=1 near the boundary, which is exactly the
    crashing path."""
    rng = np.random.default_rng(16)
    base = rng.integers(2, 64, 5).astype(np.int32)
    prompts = [np.tile(base, 4), np.tile(base, 4)]   # 20 tokens = 2.5 pages

    def run(spec_k, num_pages):
        eng = _paged(params, spec_k=spec_k, budget=64, max_len=64,
                     num_pages=num_pages, prefix_sharing=False)
        rids = _submit(eng, prompts, new=8)
        outs, _ = _drain(eng)
        return [outs[r] for r in rids], eng.metrics

    # 6 pages: both prompts prefill (3 pages each), decode fills the page
    # tails, and the first request to cross the boundary evicts the other
    # MID-DECODE (the youngest — second in the active list)
    tight, m_tight = run(2, num_pages=6)
    roomy, _ = run(0, num_pages=0)
    assert m_tight["preemptions"] > 0
    assert tight == roomy


def test_spec_draft_stays_fresh_across_fallback(params):
    """While any slot samples stochastically the whole batch falls back to
    plain K=1 steps; drafts must keep observing those tokens so speculation
    re-engages with a fresh anchor once the stochastic request leaves —
    a stale anchor would verify the wrong successors and collapse the
    accept rate to ~1."""
    rng = np.random.default_rng(17)
    rep = _repetitive(rng, 30)
    rand = rng.integers(2, 64, 12).astype(np.int32)

    def run(spec_k):
        eng = _paged(params, spec_k=spec_k)
        r_greedy = eng.add_request(Request(
            prompt=rep.copy(),
            sampling=SamplingParams(max_new_tokens=20, eos_id=-1)))
        r_hot = eng.add_request(Request(
            prompt=rand.copy(),
            sampling=SamplingParams(max_new_tokens=4, eos_id=-1,
                                    temperature=0.8, seed=7)))
        outs, _ = _drain(eng)
        return outs[r_greedy], outs[r_hot], eng

    g0, h0, _ = run(0)
    g2, h2, eng = run(2)
    assert (g2, h2) == (g0, h0)            # incl. the stochastic stream
    m = eng.metrics
    assert m["spec_calls"] > 0, "speculation never re-engaged"
    assert eng.accepted_per_call() > 1.0, \
        "draft went stale across the plain-decode fallback stretch"


def test_paged_decode_tokens_accounting(params):
    """decode_tokens must count exactly the decode-produced tokens: every
    event minus the prefill-sampled ones (incl. re-prefills after
    preemption), with nothing dropped for in-flight or finished requests."""
    rng = np.random.default_rng(13)
    eng = _paged(params, spec_k=2)
    _submit(eng, _mixed_prompts(rng), new=6)
    _, events = _drain(eng)
    m = eng.metrics
    assert m["decode_tokens"] == len(events) - m["prefill_samples"]
    assert m["prefill_samples"] > 0 and m["decode_tokens"] > 0


def test_dense_decode_tokens_accounting_counts_in_flight():
    """Dense engine: the identity must hold even when the engine is drained
    mid-flight (the old code only tallied on finish)."""
    config = Config(model=CFG, parallel=ParallelConfig(data=1, model=1),
                    iso=ISO)
    params = api.init_params(jax.random.PRNGKey(0), CFG, tp=1,
                             dtype=jnp.float32)
    eng = Engine(config, params, mesh=None, max_batch=2, max_len=96,
                 bucket=16)
    rng = np.random.default_rng(14)
    for n in (20, 33):
        eng.add_request(Request(prompt=rng.integers(2, 64, n).astype(np.int32),
                                sampling=SamplingParams(max_new_tokens=12,
                                                        eos_id=-1)))
    events = []
    for _ in range(5):                         # stop mid-flight on purpose
        events += eng.step()
    m = eng.metrics
    assert any(s is not None for s in eng.slots), "drain too late for test"
    assert m["decode_tokens"] == len(events) - m["prefill_samples"]


def test_dense_finish_clears_slot_state():
    """Regression: a finished long request must not leave its stale length
    behind — the speculative gate reads max(lengths), so one completed long
    request used to disable speculation for the rest of the batch."""
    config = Config(model=CFG, parallel=ParallelConfig(data=1, model=1),
                    iso=ISO)
    params = api.init_params(jax.random.PRNGKey(0), CFG, tp=1,
                             dtype=jnp.float32)
    rng = np.random.default_rng(15)
    long_p = rng.integers(2, 64, 90).astype(np.int32)
    rep_p = _repetitive(rng, 24)

    def run(spec_k):
        # max_len chosen so the gate FAILS while the long request is alive
        # (90+1 resident + window 4 > 93) and passes once it leaves — unless
        # its stale length lingers
        eng = Engine(config, params, mesh=None, max_batch=2, max_len=93,
                     bucket=16, spec_k=spec_k)
        ra = eng.add_request(Request(prompt=long_p, sampling=SamplingParams(
            max_new_tokens=2, eos_id=-1)))
        rb = eng.add_request(Request(prompt=rep_p, sampling=SamplingParams(
            max_new_tokens=24, eos_id=-1)))
        outs = eng.run_until_complete()
        return [outs[ra], outs[rb]], eng.metrics, eng

    plain, _, _ = run(0)
    spec, m, eng = run(3)
    assert spec == plain
    assert m["spec_accepted"] > 0, \
        "speculation never re-engaged after the long request finished"
    # per-slot state fully cleared at drain
    assert np.all(eng.lengths == 0) and np.all(eng.last_tokens == 0)
    assert all(d is None for d in eng._drafts)


# ---------------------------------------------------------------------------
# hypothesis: arbitrary mixed workloads, spec on == spec off
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:                                     # pragma: no cover
    HAVE_HYP = False

if HAVE_HYP:
    @settings(max_examples=6, deadline=None)
    @given(st.lists(st.integers(min_value=4, max_value=40), min_size=1,
                    max_size=3),
           st.integers(min_value=0, max_value=2 ** 31 - 1))
    def test_random_walk_spec_equals_plain(lengths, seed):
        """Property: for ANY mixed-length workload (alternating repetitive
        and random prompts), the speculative paged engine emits token streams
        identical to the plain paged engine."""
        params = _WALK_PARAMS[0]
        rng = np.random.default_rng(seed)
        prompts = [_repetitive(rng, n) if i % 2 == 0
                   else rng.integers(2, 64, n).astype(np.int32)
                   for i, n in enumerate(lengths)]
        outs = []
        for spec_k in (0, 2):
            eng = _paged(params, spec_k=spec_k, max_len=80)
            rids = _submit(eng, prompts, new=4)
            o, _ = _drain(eng)
            outs.append([o[r] for r in rids])
        assert outs[0] == outs[1]

    # module-scope params reused across hypothesis examples (fixtures and
    # @given do not compose)
    _WALK_PARAMS = [api.init_params(jax.random.PRNGKey(0), CFG, tp=1,
                                    dtype=jnp.float32)]
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_random_walk_spec_equals_plain():
        pass

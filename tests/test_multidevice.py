"""Multi-device (8 host CPUs, subprocess) distributed-correctness tests:
TP-sharded prefill/decode must match single-device outputs exactly; int8
collectives within quantization tolerance.  Subprocesses because XLA locks the
device count at first init (the main pytest process must keep 1 device)."""
import subprocess
import sys

import pytest

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.config import Config, ModelConfig, ParallelConfig, ISOConfig, MoEConfig, SSMConfig
from repro.core.overlap import AxisCtx
from repro.launch.mesh import make_mesh
from repro.launch import runner
from repro.models import api

key = jax.random.PRNGKey(0)
iso = ISOConfig(enabled=True, num_chunks=2, min_chunk_tokens=2, chunk_align=4)
pc = ParallelConfig(data=2, model=4)
mesh = make_mesh(pc)

def compare(cfg, tol=2e-4, quant=False):
    params1 = api.init_params(key, cfg, tp=1, dtype=jnp.float32)
    batch = api.make_inputs(cfg, 32, 4, key=key, dtype=jnp.float32)
    ref = api.prefill(params1, cfg, AxisCtx(), iso, batch,
                      logits_mode="last")["logits_local"]
    myiso = iso if not quant else ISOConfig(enabled=True, num_chunks=2,
                                            min_chunk_tokens=2, chunk_align=4,
                                            quantized_comm=True)
    config = Config(model=cfg, parallel=pc, iso=myiso)
    params4 = api.init_params(key, cfg, tp=4, dtype=jnp.float32)
    build = runner.make_prefill_fn(config, mesh,
                                   jax.eval_shape(lambda: params4),
                                   logits_mode="last", global_batch=4)
    with mesh:
        out = build(batch)(params4, batch)
    d = float(jnp.max(jnp.abs(ref - out["logits_local"][..., :ref.shape[-1]])))
    assert d < tol, (cfg.name, d)
    print("ok", cfg.name, d)

dense = ModelConfig(name="dense", family="dense", num_layers=2, d_model=64,
                    num_heads=8, num_kv_heads=2, d_ff=256, vocab_size=256,
                    qk_norm=True)
compare(dense)
compare(dense, tol=0.15, quant=True)
moe = ModelConfig(name="moe", family="moe", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=256,
                  block_pattern=("attn_moe",),
                  moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64,
                                capacity_factor=8.0, shared_expert_d_ff=32))
compare(moe)
hyb = ModelConfig(name="hybrid", family="hybrid", num_layers=2, d_model=64,
                  num_heads=8, num_kv_heads=2, d_ff=128, vocab_size=256,
                  block_pattern=("hybrid",), ssm=SSMConfig(state_dim=8),
                  sliding_window=16)
compare(hyb)
xl = ModelConfig(name="xlstm", family="ssm", num_layers=4, d_model=64,
                 num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=256,
                 block_pattern=("mlstm", "mlstm", "mlstm", "slstm"))
compare(xl, tol=1e-3)

# sharded decode continuity
cfg = moe
params1 = api.init_params(key, cfg, tp=1, dtype=jnp.float32)
batch = api.make_inputs(cfg, 16, 4, key=key, dtype=jnp.float32)
ref_out = api.prefill(params1, cfg, AxisCtx(), iso, batch, return_cache=True,
                      cache_len=20)
lengths = jnp.full((4,), 16, jnp.int32)
tok = jnp.ones((4, 1), jnp.int32)
ref_dec, _ = api.decode_step(params1, cfg, AxisCtx(), tok, ref_out["caches"],
                             lengths)
config = Config(model=cfg, parallel=pc, iso=iso)
params4 = api.init_params(key, cfg, tp=4, dtype=jnp.float32)
pshape = jax.eval_shape(lambda: params4)
build = runner.make_prefill_fn(config, mesh, pshape, logits_mode="last",
                               return_cache=True, cache_len=20, global_batch=4)
with mesh:
    out4 = build(batch)(params4, batch)
    cshape = jax.eval_shape(lambda: out4["caches"])
    dec = runner.make_decode_fn(config, mesh, pshape, cshape, global_batch=4)
    log4, _ = dec(params4, tok, out4["caches"], lengths)
d = float(jnp.max(jnp.abs(ref_dec - log4[..., :ref_dec.shape[-1]])))
assert d < 2e-4, d
print("ok decode", d)
print("ALL_MULTIDEVICE_OK")
"""


@pytest.mark.slow
def test_tp_consistency_subprocess():
    res = subprocess.run([sys.executable, "-c", _CHILD], capture_output=True,
                         text=True, timeout=540)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "ALL_MULTIDEVICE_OK" in res.stdout

"""HLO analysis: collective parsing on a real compiled module + the overlap
(hideable-FLOPs) metric distinguishing ISO from baseline."""
import re

import jax
import jax.numpy as jnp
import pytest

from repro.core.analysis import overlap_metric, parse_collectives


def test_parse_collectives_counts_and_bytes():
    hlo = """
ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %a = f32[128,256] parameter(0)
  %ar = f32[128,256] all-reduce(%a), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[128,1024] all-gather(%ar), replica_groups={{0,1,2,3}}, dimensions={1}
  ROOT %r = f32[128,256] reduce-scatter(%ag), replica_groups={{0,1,2,3}}, dimensions={1}
}
"""
    st = parse_collectives(hlo)
    assert st.counts["all-reduce"] == 1
    assert st.counts["all-gather"] == 1
    assert st.counts["reduce-scatter"] == 1
    ar_bytes = 128 * 256 * 4
    assert st.buffer_bytes["all-reduce"] == ar_bytes
    assert st.wire_bytes > ar_bytes          # ring factors applied


def _synthetic_hlo(iso: bool) -> str:
    """Hand-written HLO for a two-chunk TP layer.  Baseline: every dot depends
    on the previous all-reduce.  ISO: chunk1's dot is independent of AR(c0)."""
    if iso:
        body = """
  %a0 = f32[8,32] dot(%x0, %w1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %a1 = f32[8,32] dot(%x1, %w1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %r0 = f32[8,32] all-reduce(%a0), replica_groups={{0,1}}, to_apply=%add
  %s0 = f32[8,32] add(%x0, %r0)
  %r1 = f32[8,32] all-reduce(%a1), replica_groups={{0,1}}, to_apply=%add
  %b0 = f32[8,32] dot(%s0, %w2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %s1 = f32[8,32] add(%x1, %r1)
  %b1 = f32[8,32] dot(%s1, %w2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %out = f32[8,32] add(%b0, %b1)
"""
    else:
        body = """
  %a0 = f32[8,32] dot(%x0, %w1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %r0 = f32[8,32] all-reduce(%a0), replica_groups={{0,1}}, to_apply=%add
  %s0 = f32[8,32] add(%x0, %r0)
  %b0 = f32[8,32] dot(%s0, %w2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %rb0 = f32[8,32] all-reduce(%b0), replica_groups={{0,1}}, to_apply=%add
  %a1 = f32[8,32] dot(%rb0, %w1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %r1 = f32[8,32] all-reduce(%a1), replica_groups={{0,1}}, to_apply=%add
  %b1 = f32[8,32] dot(%r1, %w2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %out = f32[8,32] add(%b1, %b1)
"""
    return ("ENTRY %main (x0: f32[8,32], x1: f32[8,32], w1: f32[32,32], "
            "w2: f32[32,32]) -> f32[8,32] {\n"
            "  %x0 = f32[8,32] parameter(0)\n"
            "  %x1 = f32[8,32] parameter(1)\n"
            "  %w1 = f32[32,32] parameter(2)\n"
            "  %w2 = f32[32,32] parameter(3)\n"
            + body + "}\n")


def test_overlap_metric_iso_exceeds_baseline():
    m_iso = overlap_metric(_synthetic_hlo(iso=True))
    m_base = overlap_metric(_synthetic_hlo(iso=False))
    assert m_iso["collectives"] == 2
    assert m_base["collectives"] == 3
    # baseline: every dot is an ancestor or descendant of every AR -> 0 hideable
    assert m_base["avg_hideable_dots"] == 0.0
    # ISO: AR(c0) can hide behind chunk1's dots and vice versa
    assert m_iso["avg_hideable_dots"] >= 1.5


def test_parse_real_lowered_module():
    """End-to-end: parse collectives out of an actual lowered tiny model."""
    from conftest import tiny_dense, iso_cfg
    from repro.config import Config, ParallelConfig
    from repro.launch.mesh import local_test_mesh
    from repro.launch import runner
    from repro.models import api

    cfg = tiny_dense()
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    iso=iso_cfg(2, min_chunk_tokens=2, chunk_align=4))
    mesh = local_test_mesh(1, 1)
    params_shape = jax.eval_shape(
        lambda: api.init_params(jax.random.PRNGKey(0), cfg, tp=1))
    batch = api.make_inputs(cfg, 32, 2, abstract=True)
    build = runner.make_prefill_fn(config, mesh, params_shape,
                                   logits_mode="last", global_batch=2)
    with mesh:
        hlo = build(batch).lower(params_shape, batch).as_text()
    st = parse_collectives(hlo)
    # mesh size 1: XLA may fold collectives away; the parse must not crash and
    # bytes must be non-negative
    assert st.wire_bytes >= 0.0

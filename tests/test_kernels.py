"""Per-kernel shape/dtype sweeps, assert_allclose vs the pure-jnp oracles
(interpret mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("B,Hq,Hkv,Sq,Sk,hd", [
    (1, 2, 2, 16, 16, 32),     # MHA, no prefix
    (2, 4, 2, 48, 80, 64),     # GQA with prefix
    (1, 8, 1, 33, 70, 128),    # MQA, ragged lengths
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_sweep(B, Hq, Hkv, Sq, Sk, hd, dtype):
    key = jax.random.PRNGKey(42)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, Hq, Sq, hd), dtype)
    k = jax.random.normal(kk, (B, Hkv, Sk, hd), dtype)
    v = jax.random.normal(kv, (B, Hkv, Sk, hd), dtype)
    q_start = Sk - Sq
    out = ops.flash_attention(q, k, v, q_start=q_start, block_q=16, block_k=32,
                              interpret=True)
    expect = ref.flash_prefill_ref(q, k, v, q_start=q_start)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [8, 24])
def test_flash_prefill_window(window):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 2, 32, 32), jnp.float32)
    k = jax.random.normal(key, (1, 2, 64, 32), jnp.float32)
    v = jax.random.normal(key, (1, 2, 64, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, q_start=32, window=window, block_q=16,
                              block_k=16, interpret=True)
    expect = ref.flash_prefill_ref(q, k, v, q_start=32, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)


def test_flash_prefill_equals_chunked_composition():
    """flash(chunk0) + flash(chunk1 w/ prefix) == flash(full) — the kernel-level
    ISO property."""
    key = jax.random.PRNGKey(7)
    kq, kk, kv = jax.random.split(key, 3)
    B, H, S, hd = 1, 2, 64, 32
    q = jax.random.normal(kq, (B, H, S, hd), jnp.float32)
    k = jax.random.normal(kk, (B, H, S, hd), jnp.float32)
    v = jax.random.normal(kv, (B, H, S, hd), jnp.float32)
    full = ops.flash_attention(q, k, v, block_q=16, block_k=16, interpret=True)
    half = S // 2
    c0 = ops.flash_attention(q[:, :, :half], k[:, :, :half], v[:, :, :half],
                             block_q=16, block_k=16, interpret=True)
    c1 = ops.flash_attention(q[:, :, half:], k, v, q_start=half,
                             block_q=16, block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([c0, c1], axis=2)),
                               np.asarray(full), atol=1e-5)


@pytest.mark.parametrize("shape", [(7, 64), (3, 37, 96), (1, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_int8_quant_sweep(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(1), shape, dtype) * 5
    q, s = ops.quantize_int8(x, interpret=True)
    qr, sr = ref.quantize_int8_ref(x)
    assert q.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    # roundtrip error bound: one quantization step
    x32 = np.asarray(x, np.float32)
    back = np.asarray(q, np.float32) * np.asarray(s)
    bound = np.abs(x32).max(axis=-1, keepdims=True) / 127.0 + 1e-6
    assert np.all(np.abs(back - x32) <= bound)


@pytest.mark.parametrize("shape", [(5, 128), (2, 33, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, shape, dtype)
    g = jax.random.normal(key, (shape[-1],), jnp.float32)
    out = ops.rms_norm(x, g, interpret=True)
    expect = ref.rms_norm_ref(x, g)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol)


@pytest.mark.parametrize("shape", [(4, 512), (2, 17, 300)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swiglu_sweep(shape, dtype):
    key = jax.random.PRNGKey(3)
    g = jax.random.normal(key, shape, dtype)
    u = jax.random.normal(jax.random.fold_in(key, 1), shape, dtype)
    out = ops.swiglu(g, u, interpret=True)
    expect = ref.swiglu_ref(g, u)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol)

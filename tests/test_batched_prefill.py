"""Batched multi-request prefill grants — the differential battery.

The tentpole claim: packing compatible prefill grants (same bucket-padded
length) into ONE forward call per scheduler tick is OUTPUT-INVARIANT — the
packed engine emits token streams byte-identical to the batch-1 engine
(``prefill_batching=False``), while the prefill forward-call count drops.

Layers of checking:
  * mixed traffic with prompt lengths straddling bucket edges, prefix sharing
    on: byte-identical streams, >= 2x fewer prefill calls on a packed trace,
    and the (length bucket x row bucket) compile bound holds;
  * forced recompute preemption mid-prefill and speculative decoding
    (spec_k > 0) both compose with packing;
  * a hypothesis random walk over arbitrary workloads asserting, EVERY step,
    the scratch-page ``pos == -1`` invariant and page-refcount conservation
    (free + live == pool; refcounts == block-table references);
  * scheduler-level packing determinism: fcfs and priority produce stable,
    documented pack compositions independent of the iteration order of
    ``prefill_states`` (the satellite fix: packs follow the policy key).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import iso_cfg, tiny_dense
from repro.config import Config, ParallelConfig, ServingConfig
from repro.models import api
from repro.serving import PagedEngine, Request
from repro.serving.requests import SamplingParams
from repro.serving.scheduler import TokenBudgetScheduler

CFG = tiny_dense(vocab_size=64)
ISO = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)


@pytest.fixture(scope="module")
def params():
    return api.init_params(jax.random.PRNGKey(0), CFG, tp=1,
                           dtype=jnp.float32)


def _engine(params, *, batched, budget=256, max_batch=8, max_len=160,
            num_pages=0, page_size=8, prefix_sharing=True, spec_k=0,
            policy="fcfs"):
    config = Config(model=CFG, parallel=ParallelConfig(data=1, model=1),
                    iso=ISO,
                    serving=ServingConfig(page_size=page_size,
                                          max_batch=max_batch,
                                          max_len=max_len,
                                          prefill_token_budget=budget,
                                          num_pages=num_pages,
                                          prefix_sharing=prefix_sharing,
                                          prefill_batching=batched,
                                          scheduler_policy=policy,
                                          spec_k=spec_k))
    return PagedEngine(config, params)


def _submit(eng, prompts, new=6, priorities=None):
    return [eng.add_request(Request(
        prompt=p.copy(),
        sampling=SamplingParams(max_new_tokens=new, eos_id=-1),
        priority=0 if priorities is None else priorities[i]))
        for i, p in enumerate(prompts)]


def _alloc_invariants(alloc):
    """Page-refcount conservation: every page is free XOR live; refcounts
    equal the number of block-table references; committed tokens never
    exceed capacity."""
    refs = {}
    for table in alloc.tables.values():
        for pg in table:
            refs[pg] = refs.get(pg, 0) + 1
    assert refs == alloc.refcount, "refcounts drifted from table references"
    live, free = set(refs), set(alloc._free)
    assert not (live & free), f"pages both free and live: {live & free}"
    assert len(live) + len(free) == alloc.num_pages, \
        f"page leak: {alloc.num_pages - len(live) - len(free)} lost"
    for rid in alloc.tables:
        assert alloc.lengths.get(rid, 0) <= alloc.capacity(rid), rid


def _drain_checked(eng):
    """run_until_complete asserting the scratch-pos and allocator invariants
    after EVERY step."""
    scratch = eng.kv.scratch_page
    events = []
    for _ in range(10_000):
        events += eng.step()
        pos_scr = np.asarray(eng.kv.arrays["pos"])[scratch]
        assert np.all(pos_scr == -1), \
            f"scratch page leaked real positions: {pos_scr}"
        _alloc_invariants(eng.alloc)
        if not eng.scheduler.waiting and all(s is None for s in eng.slots):
            break
    return {st.request.rid: st.generated for st in eng._finished}, events


def _run(params, prompts, *, batched, new=6, **kw):
    eng = _engine(params, batched=batched, **kw)
    rids = _submit(eng, prompts, new=new)
    outs, _ = _drain_checked(eng)
    return [outs[r] for r in rids], eng


# ---------------------------------------------------------------------------
# tentpole: packed == batch-1, byte-identical, with fewer forward calls
# ---------------------------------------------------------------------------

def test_packed_equals_batch1_four_requests():
    """Acceptance: a 4-request same-bucket workload packs into single calls —
    byte-identical streams, >= 2x fewer prefill calls, compile bound holds."""
    params = api.init_params(jax.random.PRNGKey(0), CFG, tp=1,
                             dtype=jnp.float32)
    rng = np.random.default_rng(30)
    prompts = [rng.integers(2, 64, 32).astype(np.int32) for _ in range(4)]
    ref, e1 = _run(params, prompts, batched=False, max_batch=4,
                   prefix_sharing=False)
    got, e2 = _run(params, prompts, batched=True, max_batch=4,
                   prefix_sharing=False)
    assert got == ref, "packed prefill changed generated tokens"
    assert e1.metrics["prefill_grants"] == e2.metrics["prefill_grants"]
    assert e2.metrics["prefill_calls"] * 2 <= e1.metrics["prefill_calls"], \
        (e2.metrics["prefill_calls"], e1.metrics["prefill_calls"])
    assert e2.prefill_compile_count() <= e2.max_prefill_compiles()


def test_packed_equals_batch1_boundary_lengths_with_sharing(params):
    """Mixed lengths straddling bucket edges (15/16/17, 31/33), a
    prefix-sharing pair, and a prompt long enough to force resumed grants."""
    rng = np.random.default_rng(31)
    shared = rng.integers(2, 64, 24).astype(np.int32)
    prompts = [
        rng.integers(2, 64, 15).astype(np.int32),
        rng.integers(2, 64, 16).astype(np.int32),
        rng.integers(2, 64, 17).astype(np.int32),
        rng.integers(2, 64, 31).astype(np.int32),
        rng.integers(2, 64, 33).astype(np.int32),
        np.concatenate([shared, rng.integers(2, 64, 9).astype(np.int32)]),
        np.concatenate([shared, rng.integers(2, 64, 5).astype(np.int32)]),
        rng.integers(2, 64, 70).astype(np.int32),      # resumed under budget
    ]
    ref, e1 = _run(params, prompts, batched=False, budget=48)
    got, e2 = _run(params, prompts, batched=True, budget=48)
    assert got == ref, "packed prefill changed generated tokens"
    assert e2.metrics["prefill_calls"] < e1.metrics["prefill_calls"]
    assert e2.metrics["resumed_grants"] > 0
    assert e2.metrics["prefix_shared_tokens"] > 0
    assert e2.prefill_compile_count() <= e2.max_prefill_compiles()
    # fresh rows really rode next to resumed ones in one call: packing
    # happened (fewer calls than grants) while resumes were in flight
    assert e2.metrics["prefill_calls"] < e2.metrics["prefill_grants"]


def test_packed_with_forced_preemption(params):
    """A pool too small for the whole workload forces recompute preemption
    MID-PREFILL; the packed engine must reproduce the unpressured batch-1
    stream (evicted packmates drop out of their pack, re-prefill re-packs)."""
    rng = np.random.default_rng(32)
    prompts = [rng.integers(2, 64, 40).astype(np.int32) for _ in range(3)]

    roomy, e_roomy = _run(params, prompts, batched=False, max_len=64,
                          budget=64, prefix_sharing=False)
    tight, e_tight = _run(params, prompts, batched=True, max_len=64,
                          budget=64, num_pages=12, prefix_sharing=False)
    assert e_tight.metrics["preemptions"] > 0, "pressure never materialised"
    assert e_roomy.metrics["preemptions"] == 0
    assert tight == roomy, "preemption under packing changed tokens"


def test_packed_with_speculation(params):
    """spec_k > 0 composes with packed prefill: the post-prefill self-draft
    anchors on each packed row's own sampled token."""
    rng = np.random.default_rng(33)
    base = rng.integers(2, 64, 6).astype(np.int32)
    prompts = [np.tile(base, 5)[:n] for n in (30, 30, 24, 17)]
    ref, e1 = _run(params, prompts, batched=False, new=10)
    got, e2 = _run(params, prompts, batched=True, new=10, spec_k=2)
    assert got == ref, "speculation + packing changed tokens"
    assert e2.metrics["spec_calls"] > 0
    assert e2.accepted_per_call() > 1.0
    assert e2.metrics["prefill_calls"] < e1.metrics["prefill_calls"]


def test_row_bucketing_pads_odd_packs(params):
    """A 3-grant pack pads to the next row bucket (4): the closure key space
    stays (length bucket, row bucket) and pad rows are accounted."""
    rng = np.random.default_rng(34)
    prompts = [rng.integers(2, 64, 16).astype(np.int32) for _ in range(3)]
    got, eng = _run(params, prompts, batched=True, max_batch=4,
                    prefix_sharing=False)
    assert eng.metrics["prefill_pad_rows"] > 0, "row padding never happened"
    assert all(len(k) == 3 for k in eng._prefill_fns), \
        f"unexpected closure keys: {list(eng._prefill_fns)}"
    assert (16, 4, True) in eng._prefill_fns, list(eng._prefill_fns)


def test_same_pack_fresh_sharers_still_share(params):
    """Regression: two identical fresh prompts granted in the SAME tick land
    in the same pack — sharing can only adopt committed tokens, so running
    them in one call would silently lose the share the sequential path gets.
    The engine defers the sharee to a follow-up sub-pack instead: both
    engines must share, and streams must stay identical."""
    rng = np.random.default_rng(36)
    prompt = rng.integers(2, 64, 32).astype(np.int32)
    prompts = [prompt, prompt.copy(), prompt.copy()]
    ref, e1 = _run(params, prompts, batched=False, max_batch=4)
    got, e2 = _run(params, prompts, batched=True, max_batch=4)
    assert got == ref
    assert e1.metrics["prefix_shared_tokens"] > 0
    assert e2.metrics["prefix_shared_tokens"] == \
        e1.metrics["prefix_shared_tokens"], \
        (e2.metrics["prefix_shared_tokens"], e1.metrics["prefix_shared_tokens"])


def test_packed_priority_policy_equals_batch1(params):
    """Priority scheduling reorders grants before packing; streams must stay
    byte-identical to the batch-1 priority engine."""
    rng = np.random.default_rng(35)
    prompts = [rng.integers(2, 64, n).astype(np.int32)
               for n in (16, 16, 32, 32)]
    prios = [0, 5, 5, 0]

    def run(batched):
        eng = _engine(params, batched=batched, policy="priority",
                      max_batch=4, prefix_sharing=False)
        rids = _submit(eng, prompts, priorities=prios)
        outs, _ = _drain_checked(eng)
        return [outs[r] for r in rids]

    assert run(True) == run(False)


# ---------------------------------------------------------------------------
# scheduler-level packing determinism (the satellite fix)
# ---------------------------------------------------------------------------

def _grants_for(sched, states):
    return sched.grant_prefill(states)


def test_pack_grants_deterministic_fcfs():
    """fcfs: packs form in arrival order, grouped by padded length —
    documented composition, independent of prefill_states iteration order."""
    sched = TokenBudgetScheduler("fcfs", prefill_token_budget=64,
                                 grant_buckets=(8, 16, 32, 64))
    for rid in (1, 2, 3, 4):
        sched.add(rid)
    states = [(1, 0, (16,)), (2, 0, (8,)), (3, 0, (16,)), (4, 0, (8,))]
    for perm in (states, states[::-1], [states[2], states[0], states[3],
                                        states[1]]):
        grants = _grants_for(sched, perm)
        packs = sched.pack_grants(grants, max_rows=4)
        comp = [[g.rid for g in p] for p in packs]
        assert comp == [[1, 3], [2, 4]], comp


def test_pack_grants_deterministic_priority():
    """priority: the pack order follows (-priority, arrival); high-priority
    grants pack together ahead of the rest — stable across input orders."""
    sched = TokenBudgetScheduler("priority", prefill_token_budget=64,
                                 grant_buckets=(8, 16, 32, 64))
    for rid, prio in ((1, 0), (2, 5), (3, 0), (4, 5)):
        sched.add(rid, priority=prio)
    states = [(1, 0, (16,)), (2, 0, (16,)), (3, 0, (8,)), (4, 0, (16,))]
    for perm in (states, states[::-1]):
        grants = _grants_for(sched, perm)
        packs = sched.pack_grants(grants, max_rows=4)
        comp = [[g.rid for g in p] for p in packs]
        # 2 and 4 (prio 5) lead and share the 16-bucket with 1; 3 is alone
        assert comp == [[2, 4, 1], [3]], comp


def test_pack_grants_respects_max_rows():
    sched = TokenBudgetScheduler("fcfs", prefill_token_budget=256,
                                 grant_buckets=(16,))
    for rid in range(5):
        sched.add(rid)
    grants = _grants_for(sched, [(rid, 0, (16,)) for rid in range(5)])
    packs = sched.pack_grants(grants, max_rows=2)
    assert [[g.rid for g in p] for p in packs] == [[0, 1], [2, 3], [4]]
    # max_rows <= 1 disables packing entirely (the batch-1 reference)
    singles = sched.pack_grants(grants, max_rows=1)
    assert [[g.rid for g in p] for p in singles] == [[r] for r in range(5)]


# ---------------------------------------------------------------------------
# hypothesis: arbitrary workloads, packed == batch-1 + invariants every step
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:                                     # pragma: no cover
    HAVE_HYP = False

if HAVE_HYP:
    @settings(max_examples=6, deadline=None)
    @given(st.lists(st.integers(min_value=3, max_value=70), min_size=1,
                    max_size=4),
           st.integers(min_value=0, max_value=2 ** 31 - 1))
    def test_random_walk_packed_equals_batch1(lengths, seed):
        """Property: for ANY mixed-length workload the packed engine emits
        token streams identical to the batch-1 engine, and every step
        preserves the scratch-pos and page-refcount invariants (checked
        inside _drain_checked for BOTH engines)."""
        params = _WALK_PARAMS[0]
        rng = np.random.default_rng(seed)
        prompts = [rng.integers(2, 64, n).astype(np.int32) for n in lengths]
        ref, _ = _run(params, prompts, batched=False, new=4, budget=48,
                      max_batch=4)
        got, _ = _run(params, prompts, batched=True, new=4, budget=48,
                      max_batch=4)
        assert got == ref

    # module-scope params reused across hypothesis examples (fixtures and
    # @given do not compose)
    _WALK_PARAMS = [api.init_params(jax.random.PRNGKey(0), CFG, tp=1,
                                    dtype=jnp.float32)]
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_random_walk_packed_equals_batch1():
        pass

"""CI trace-schema lane: a mixed serving workload (chunked + packed prefill,
prefix sharing, speculative decode) must export a schema-valid Chrome trace
whose replay reproduces the engine's final counters — the
narration-is-complete contract for the whole stack (preemption replay is
covered separately in test_obs.py)."""
import json

import jax
import jax.numpy as jnp
import numpy as np

from conftest import tiny_dense, iso_cfg
from repro.config import Config, ParallelConfig, ServingConfig
from repro.models import api
from repro.obs import (replay_counters, validate_chrome_trace,
                       write_chrome_trace)
from repro.obs.replay import REPLAYABLE
from repro.serving import PagedEngine, Request
from repro.serving.requests import SamplingParams


def _mixed_engine_run():
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    config = Config(
        model=cfg, parallel=ParallelConfig(data=1, model=1), iso=iso,
        serving=ServingConfig(page_size=8, max_batch=3, max_len=160,
                              prefill_token_budget=24, num_pages=30,
                              prefix_sharing=True, spec_k=2))
    eng = PagedEngine(config, params)
    rng = np.random.default_rng(13)
    prefix = np.tile(np.arange(4, 12), 2).astype(np.int32)   # 16 tokens
    for n in (30, 22, 18, 9):
        body = np.tile(np.arange(4, 10), (n // 6) + 1)[:n].astype(np.int32)
        eng.add_request(Request(
            prompt=np.concatenate([prefix, body]),
            sampling=SamplingParams(max_new_tokens=6, eos_id=-1)))
    outs = eng.run_until_complete()
    return eng, outs


def test_mixed_workload_trace_roundtrip(tmp_path):
    eng, outs = _mixed_engine_run()
    assert eng.trace.dropped == 0

    # workload actually exercised the interesting paths
    kinds = {e.kind for e in eng.trace.events()}
    assert {"grant", "grant_commit", "prefill_call", "decode_call", "sample",
            "accept", "alloc", "free", "pool", "admit", "finish",
            "adopt"} <= kinds, kinds
    assert eng.metrics["spec_calls"] > 0
    assert eng.metrics["prefix_shared_tokens"] > 0
    assert eng.metrics["resumed_grants"] > 0        # chunked prefill resumed

    # export -> reload -> schema-validate (what the CI lane gates on)
    path = tmp_path / "trace.json"
    n = write_chrome_trace(eng.trace.events(), str(path))
    assert n == len(eng.trace.events())
    with open(path) as f:
        doc = json.load(f)
    assert validate_chrome_trace(doc) == []
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] != "M"}
    assert "prefill_call" in names and "pool" in names

    # replay(trace) == registry, key for key
    rep = replay_counters(eng.trace.events())
    for name in REPLAYABLE:
        assert rep[name] == eng.metrics[name], \
            (name, rep[name], eng.metrics[name])
    assert rep["pages_allocated"] - rep["pages_freed"] == \
        eng.alloc.used_pages == 0
    total = sum(len(v) for v in outs.values())
    assert eng.metrics["decode_tokens"] + eng.metrics["prefill_samples"] \
        == total


def test_trace_spans_have_positive_wall_durations():
    eng, _ = _mixed_engine_run()
    spans = [e for e in eng.trace.events()
             if e.kind in ("prefill_call", "decode_call")]
    assert spans and all(e.dur > 0 for e in spans)
    # spans account for the registry's fenced phase timers
    prefill_dur = sum(e.dur for e in spans if e.kind == "prefill_call")
    assert abs(prefill_dur - eng.metrics["prefill_s"]) < 1e-6
    decode_dur = sum(e.dur for e in spans if e.kind == "decode_call")
    assert abs(decode_dur - eng.metrics["decode_s"]) < 1e-6

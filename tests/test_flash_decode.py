"""Paged flash-decode kernel parity vs the pure-jnp oracle.

Grid: page_size in {8, 16}; lengths straddling page boundaries (1, ps-1, ps,
ps+1, multi-page); fp32 and bf16 pools; GQA grouping; sliding window; and the
merge with the current decode token (the layer-level contract).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import flash_decode
from repro.kernels.ref import paged_decode_ref
from repro.layers.attention import merge_softmax_states


def _make_paged(rng, lengths, page_size, hkv, hd, num_pages, dtype):
    """Build a random page pool + block tables holding `lengths[b]` tokens."""
    B = len(lengths)
    max_blocks = -(-max(max(lengths), 1) // page_size)
    k_pages = np.zeros((num_pages + 1, page_size, hkv, hd), np.float32)
    v_pages = np.zeros_like(k_pages)
    bt = np.full((B, max_blocks), -1, np.int32)
    free = list(range(num_pages))
    for b, L in enumerate(lengths):
        for blk in range(-(-L // page_size)):
            pg = free.pop()
            bt[b, blk] = pg
            n = min(page_size, L - blk * page_size)
            k_pages[pg, :n] = rng.standard_normal((n, hkv, hd))
            v_pages[pg, :n] = rng.standard_normal((n, hkv, hd))
    # poison unreferenced tail slots: masking must hide them
    k_pages[:, :, :, :] += 0.0
    return (jnp.asarray(k_pages, dtype), jnp.asarray(v_pages, dtype),
            jnp.asarray(bt), jnp.asarray(np.asarray(lengths, np.int32)))


@pytest.mark.parametrize("page_size", [8, 16])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_flash_decode_page_boundary_grid(page_size, dtype, tol):
    rng = np.random.default_rng(0)
    ps = page_size
    lengths = [1, ps - 1, ps, ps + 1, 3 * ps - 2, 2 * ps]
    hq, hkv, hd = 4, 2, 16
    k_pages, v_pages, bt, lens = _make_paged(rng, lengths, ps, hkv, hd,
                                             num_pages=32, dtype=dtype)
    q = jnp.asarray(rng.standard_normal((len(lengths), hq, hd)), dtype)
    out, m, l = flash_decode(q, k_pages, v_pages, bt, lens)
    ref = paged_decode_ref(q, k_pages, v_pages, bt, lens)
    assert float(jnp.max(jnp.abs(out - ref))) < tol
    # softmax state is self-consistent: l > 0 wherever tokens are resident
    assert bool(jnp.all(l[:, :, 0] > 0))


@pytest.mark.parametrize("window", [4, 16])
def test_flash_decode_sliding_window(window):
    rng = np.random.default_rng(1)
    ps, hq, hkv, hd = 8, 4, 4, 16
    lengths = [3, 11, 24, 17]
    k_pages, v_pages, bt, lens = _make_paged(rng, lengths, ps, hkv, hd,
                                             num_pages=24, dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((len(lengths), hq, hd)), jnp.float32)
    out, _, _ = flash_decode(q, k_pages, v_pages, bt, lens, window=window)
    ref = paged_decode_ref(q, k_pages, v_pages, bt, lens, window=window)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


def test_flash_decode_zero_length_rows_are_benign():
    """Inactive slots (length 0, all-pad tables) must not poison the batch."""
    rng = np.random.default_rng(2)
    ps, hq, hkv, hd = 8, 2, 2, 16
    k_pages, v_pages, bt, lens = _make_paged(rng, [12, 0], ps, hkv, hd,
                                             num_pages=8, dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((2, hq, hd)), jnp.float32)
    out, m, l = flash_decode(q, k_pages, v_pages, bt, lens)
    ref = paged_decode_ref(q, k_pages, v_pages, bt, lens)
    assert float(jnp.max(jnp.abs(out[0] - ref[0]))) < 1e-5
    assert float(jnp.max(jnp.abs(out[1]))) == 0.0          # empty row -> 0
    assert float(l[1].max()) == 0.0

    # merging the current token (a one-key partial state: out=v, m=score,
    # l=1 — exactly what the layer's intra-window sdpa_partial produces)
    # gives the empty row weight 1 on itself
    v_new = jnp.asarray(rng.standard_normal((2, hq, hd)), jnp.float32)
    s_new = jnp.zeros((2, hq, 1), jnp.float32)
    merged = merge_softmax_states(out, m, l, v_new, s_new,
                                  jnp.ones_like(s_new))
    assert float(jnp.max(jnp.abs(merged[1] - v_new[1]))) < 1e-6


def test_flash_decode_merge_matches_full_softmax():
    """Kernel partial + current-token merge == softmax over [pages, self]."""
    rng = np.random.default_rng(3)
    ps, hq, hkv, hd = 8, 4, 2, 16
    lengths = [9, 15]
    k_pages, v_pages, bt, lens = _make_paged(rng, lengths, ps, hkv, hd,
                                             num_pages=16, dtype=jnp.float32)
    B = len(lengths)
    q = jnp.asarray(rng.standard_normal((B, hq, hd)), jnp.float32)
    k_new = jnp.asarray(rng.standard_normal((B, hq, hd)), jnp.float32)
    v_new = jnp.asarray(rng.standard_normal((B, hq, hd)), jnp.float32)

    out, m, l = flash_decode(q, k_pages, v_pages, bt, lens)
    s_new = jnp.sum(q * k_new, -1, keepdims=True) * (hd ** -0.5)
    # the self token as a one-key partial state (out=v, m=score, l=1)
    got = merge_softmax_states(out, m, l, v_new, s_new,
                               jnp.ones_like(s_new))

    # oracle: dense gather with the self key appended at position L
    group = hq // hkv
    idx = jnp.clip(bt, 0, k_pages.shape[0] - 1)
    kd = jnp.repeat(k_pages[idx].reshape(B, -1, hkv, hd), group, 2)
    vd = jnp.repeat(v_pages[idx].reshape(B, -1, hkv, hd), group, 2)
    kk = jnp.concatenate([kd, k_new[:, None]], axis=1)
    vv = jnp.concatenate([vd, v_new[:, None]], axis=1)
    s = jnp.einsum("bhd,bshd->bhs", q, kk) * (hd ** -0.5)
    mask = jnp.concatenate(
        [jnp.arange(kd.shape[1])[None] < lens[:, None],   # paged: pos < L
         jnp.ones((B, 1), bool)], axis=1)                 # self: pos == L
    s = jnp.where(mask[:, None], s, -jnp.inf)
    ref = jnp.einsum("bhs,bshd->bhd", jax.nn.softmax(s, -1), vv)
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-5


# ---------------------------------------------------------------------------
# K-token speculative verify mode (q (B, K, Hq, hd))
# ---------------------------------------------------------------------------

from conftest import tiny_dense                              # noqa: E402
from repro.kernels.ref import paged_verify_ref               # noqa: E402
from repro.layers import attention as attn_lib               # noqa: E402
from repro.layers.heads import head_layout                   # noqa: E402
from repro.serving.kvcache import gather_pages               # noqa: E402


@pytest.mark.parametrize("K", [1, 2, 4])
@pytest.mark.parametrize("page_size", [8, 16])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_flash_verify_window_grid(K, page_size, dtype, tol):
    """K-token verify parity vs the oracle on page-boundary lengths."""
    rng = np.random.default_rng(7)
    ps = page_size
    lengths = [1, ps - 1, ps, ps + 1, 3 * ps - 2, 2 * ps]
    hq, hkv, hd = 4, 2, 16
    k_pages, v_pages, bt, lens = _make_paged(rng, lengths, ps, hkv, hd,
                                             num_pages=32, dtype=dtype)
    q = jnp.asarray(rng.standard_normal((len(lengths), K, hq, hd)), dtype)
    out, m, l = flash_decode(q, k_pages, v_pages, bt, lens)
    ro, rm, rl = paged_verify_ref(q, k_pages, v_pages, bt, lens)
    assert out.shape == (len(lengths), K, hq, hd)
    assert float(jnp.max(jnp.abs(out - ro))) < tol
    assert float(jnp.max(jnp.abs(l - rl))) < tol
    # position 0 of the window IS plain single-token decode
    o1, m1, l1 = flash_decode(q[:, 0], k_pages, v_pages, bt, lens)
    assert float(jnp.max(jnp.abs(out[:, 0] - o1))) < 1e-6
    assert float(jnp.max(jnp.abs(l[:, 0] - l1))) < 1e-6


@pytest.mark.parametrize("window", [4, 16])
def test_flash_verify_sliding_window_shifts_per_position(window):
    """The sliding-window lower bound must advance with the window position:
    token qi at absolute position L + qi sees keys > L + qi - window."""
    rng = np.random.default_rng(8)
    ps, K, hq, hkv, hd = 8, 3, 4, 4, 16
    lengths = [3, 11, 24, 17]
    k_pages, v_pages, bt, lens = _make_paged(rng, lengths, ps, hkv, hd,
                                             num_pages=24, dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((len(lengths), K, hq, hd)), jnp.float32)
    out, _, l = flash_decode(q, k_pages, v_pages, bt, lens, window=window)
    ro, _, rl = paged_verify_ref(q, k_pages, v_pages, bt, lens, window=window)
    assert float(jnp.max(jnp.abs(out - ro))) < 1e-5
    assert float(jnp.max(jnp.abs(l - rl))) < 1e-5
    # the shift is real: for a short window the denominators differ across qi
    if window < min(lengths) + K:
        assert not bool(jnp.all(jnp.abs(l[:, 0] - l[:, -1]) < 1e-12))


@pytest.mark.parametrize("K,window", [(2, 0), (4, 0), (3, 12)])
def test_verify_layer_matches_dense_cache(K, window):
    """attn_decode_paged_partial with a K-token window == the dense K-token
    decode (attn_decode_partial) over the gathered cache."""
    rng = np.random.default_rng(9)
    cfg = tiny_dense(vocab_size=32, sliding_window=window)
    group = cfg.num_heads // cfg.num_kv_heads
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    ps = 8
    lengths = [13, 9, 16]
    B = len(lengths)
    k_pages, v_pages, bt, lens = _make_paged(rng, lengths, ps, hkv, hd,
                                             num_pages=16, dtype=jnp.float32)
    p = attn_lib.init_attention(
        jax.random.PRNGKey(0), cfg,
        head_layout(cfg.num_heads, cfg.num_kv_heads, 1), dtype=jnp.float32)
    x = jnp.asarray(rng.standard_normal((B, K, cfg.d_model)) * 0.2,
                    jnp.float32)

    paged, kv_paged = attn_lib.attn_decode_paged_partial(
        p, x, cfg, group, k_pages=k_pages, v_pages=v_pages,
        block_tables=bt, lengths=lens, window=window)

    # oracle: gather pages dense, slot == position, validity from lengths
    kd = gather_pages(k_pages[None], bt)[0]
    vd = gather_pages(v_pages[None], bt)[0]
    dense, kv_dense = attn_lib.attn_decode_partial(
        p, x, cfg, group, cache_k=kd, cache_v=vd, lengths=lens,
        window=window)
    assert float(jnp.max(jnp.abs(paged - dense))) < 1e-4
    assert float(jnp.max(jnp.abs(kv_paged[0] - kv_dense[0]))) < 1e-5
    assert float(jnp.max(jnp.abs(kv_paged[1] - kv_dense[1]))) < 1e-5


# ---------------------------------------------------------------------------
# split-KV (sequence-parallel) mode: kv_splits > 1 partial + reduce
# ---------------------------------------------------------------------------

from repro.kernels.flash_decode import NEG_INF               # noqa: E402
from repro.kernels.ref import paged_decode_split_ref         # noqa: E402


@pytest.mark.parametrize("kv_splits", [1, 2, 4])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("K", [1, 2, 4])
@pytest.mark.parametrize("window", [0, 12])
def test_flash_decode_split_parity_grid(kv_splits, dtype, tol, K, window):
    """Split-KV decode matches BOTH the sequential walk (kv_splits=1) and the
    span-folding oracle, for every (S, dtype, K, window) combination — the
    reduce step must be invisible to every downstream consumer."""
    rng = np.random.default_rng(21)
    ps, hq, hkv, hd = 8, 4, 2, 16
    lengths = [1, ps - 1, ps + 1, 5 * ps - 3, 3 * ps]
    k_pages, v_pages, bt, lens = _make_paged(rng, lengths, ps, hkv, hd,
                                             num_pages=32, dtype=dtype)
    q = jnp.asarray(rng.standard_normal((len(lengths), K, hq, hd)), dtype)
    out, m, l = flash_decode(q, k_pages, v_pages, bt, lens, window=window,
                             kv_splits=kv_splits)
    seq_o, seq_m, seq_l = flash_decode(q, k_pages, v_pages, bt, lens,
                                       window=window, kv_splits=1)
    assert out.shape == seq_o.shape
    assert float(jnp.max(jnp.abs(out - seq_o))) < tol
    assert float(jnp.max(jnp.abs(l - seq_l))) < tol
    # the reduced running max is the true global max — merge contract intact
    assert float(jnp.max(jnp.abs(m - seq_m))) < tol
    ro, rm, rl = paged_decode_split_ref(q, k_pages, v_pages, bt, lens,
                                        kv_splits=kv_splits, window=window)
    assert float(jnp.max(jnp.abs(out - ro))) < tol


@pytest.mark.parametrize("kv_splits", [2, 3, 16])
def test_flash_decode_split_matches_sequential_oracle(kv_splits):
    """Every split count collapses to the ONE sequential oracle — including
    S > resident pages, where the surplus spans are empty and must come back
    as the neutral partial (0, NEG_INF, 0) that vanishes in the reduce."""
    rng = np.random.default_rng(22)
    ps, hq, hkv, hd = 8, 4, 4, 16
    lengths = [3, 11, 24, 17]
    k_pages, v_pages, bt, lens = _make_paged(rng, lengths, ps, hkv, hd,
                                             num_pages=24, dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((len(lengths), hq, hd)), jnp.float32)
    out, m, l = flash_decode(q, k_pages, v_pages, bt, lens,
                             kv_splits=kv_splits)
    ref = paged_decode_ref(q, k_pages, v_pages, bt, lens)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5
    assert bool(jnp.all(l[:, :, 0] > 0))


def test_flash_decode_split_edge_rows():
    """Zero-length rows and single-page rows under aggressive splitting:
    the empty row's reduced state stays exactly (0, NEG_INF, 0)."""
    rng = np.random.default_rng(23)
    ps, hq, hkv, hd = 8, 2, 2, 16
    k_pages, v_pages, bt, lens = _make_paged(rng, [12, 0, 5], ps, hkv, hd,
                                             num_pages=8, dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((3, hq, hd)), jnp.float32)
    out, m, l = flash_decode(q, k_pages, v_pages, bt, lens, kv_splits=4)
    ref = paged_decode_ref(q, k_pages, v_pages, bt, lens)
    assert float(jnp.max(jnp.abs(out[0] - ref[0]))) < 1e-5
    assert float(jnp.max(jnp.abs(out[2] - ref[2]))) < 1e-5   # single page
    assert float(jnp.max(jnp.abs(out[1]))) == 0.0            # empty row
    assert float(l[1].max()) == 0.0
    assert float(m[1].max()) == float(np.float32(NEG_INF))

    # the merge with a fresh self token still gives the empty row weight 1
    # on itself — the layer contract is split-count independent
    v_new = jnp.asarray(rng.standard_normal((3, hq, hd)), jnp.float32)
    s_new = jnp.zeros((3, hq, 1), jnp.float32)
    merged = merge_softmax_states(out, m, l, v_new, s_new,
                                  jnp.ones_like(s_new))
    assert float(jnp.max(jnp.abs(merged[1] - v_new[1]))) < 1e-6


def test_flash_decode_split_boundaries_vs_ref():
    """Lengths landing exactly ON span boundaries (and one token either
    side): the span mask must neither drop nor double-count the boundary
    page."""
    rng = np.random.default_rng(24)
    ps, S, hq, hkv, hd = 8, 2, 4, 2, 16
    # with MB=6 pages and S=2, the span boundary sits at page 3 = token 24
    lengths = [23, 24, 25, 48]
    k_pages, v_pages, bt, lens = _make_paged(rng, lengths, ps, hkv, hd,
                                             num_pages=32, dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((len(lengths), hq, hd)), jnp.float32)
    out, _, _ = flash_decode(q, k_pages, v_pages, bt, lens, kv_splits=S)
    ref = paged_decode_ref(q, k_pages, v_pages, bt, lens)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


@pytest.mark.parametrize("K,window,kv_splits", [(1, 0, 2), (3, 0, 4),
                                                (2, 12, 3)])
def test_split_layer_matches_dense_cache(K, window, kv_splits):
    """Layer-level: attn_decode_paged_partial with kv_splits > 1 still equals
    the dense K-token decode over the gathered cache — the reduce step is
    invisible through the intra-window merge."""
    rng = np.random.default_rng(26)
    cfg = tiny_dense(vocab_size=32, sliding_window=window)
    group = cfg.num_heads // cfg.num_kv_heads
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    ps = 8
    lengths = [13, 9, 16, 29]
    B = len(lengths)
    k_pages, v_pages, bt, lens = _make_paged(rng, lengths, ps, hkv, hd,
                                             num_pages=16, dtype=jnp.float32)
    p = attn_lib.init_attention(
        jax.random.PRNGKey(0), cfg,
        head_layout(cfg.num_heads, cfg.num_kv_heads, 1), dtype=jnp.float32)
    x = jnp.asarray(rng.standard_normal((B, K, cfg.d_model)) * 0.2,
                    jnp.float32)

    split, kv_split = attn_lib.attn_decode_paged_partial(
        p, x, cfg, group, k_pages=k_pages, v_pages=v_pages,
        block_tables=bt, lengths=lens, window=window, kv_splits=kv_splits)
    kd = gather_pages(k_pages[None], bt)[0]
    vd = gather_pages(v_pages[None], bt)[0]
    dense, kv_dense = attn_lib.attn_decode_partial(
        p, x, cfg, group, cache_k=kd, cache_v=vd, lengths=lens,
        window=window)
    assert float(jnp.max(jnp.abs(split - dense))) < 1e-4
    assert float(jnp.max(jnp.abs(kv_split[0] - kv_dense[0]))) < 1e-5
    assert float(jnp.max(jnp.abs(kv_split[1] - kv_dense[1]))) < 1e-5


@pytest.mark.parametrize("kv_splits", [1, 4])
def test_flash_decode_dead_page_guard_is_byte_identical(kv_splits):
    """The pl.when guard that skips pages past ceil(L/ps) must be pure
    compute savings: (alpha=exp(0)=1, p=0) leaves the running state bit-for-
    bit unchanged, so guarded == unguarded EXACTLY — in both walk modes."""
    rng = np.random.default_rng(25)
    ps, hq, hkv, hd = 8, 4, 2, 16
    lengths = [1, 9, 40, 0]                  # deep tables, shallow lengths
    k_pages, v_pages, bt, lens = _make_paged(rng, lengths, ps, hkv, hd,
                                             num_pages=32, dtype=jnp.float32)
    # widen the tables so every row carries dead trailing pages
    bt = jnp.pad(bt, ((0, 0), (0, 3)), constant_values=-1)
    q = jnp.asarray(rng.standard_normal((len(lengths), 2, hq, hd)),
                    jnp.float32)
    guarded = flash_decode(q, k_pages, v_pages, bt, lens,
                           kv_splits=kv_splits, guard_dead_pages=True)
    unguarded = flash_decode(q, k_pages, v_pages, bt, lens,
                             kv_splits=kv_splits, guard_dead_pages=False)
    for g, u in zip(guarded, unguarded):
        assert bool(jnp.all(g == u)), "guard changed the numerics"

"""Paged flash-decode kernel parity vs the pure-jnp oracle.

Grid: page_size in {8, 16}; lengths straddling page boundaries (1, ps-1, ps,
ps+1, multi-page); fp32 and bf16 pools; GQA grouping; sliding window; and the
merge with the current decode token (the layer-level contract).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import flash_decode, merge_partial_softmax
from repro.kernels.ref import paged_decode_ref


def _make_paged(rng, lengths, page_size, hkv, hd, num_pages, dtype):
    """Build a random page pool + block tables holding `lengths[b]` tokens."""
    B = len(lengths)
    max_blocks = -(-max(max(lengths), 1) // page_size)
    k_pages = np.zeros((num_pages + 1, page_size, hkv, hd), np.float32)
    v_pages = np.zeros_like(k_pages)
    bt = np.full((B, max_blocks), -1, np.int32)
    free = list(range(num_pages))
    for b, L in enumerate(lengths):
        for blk in range(-(-L // page_size)):
            pg = free.pop()
            bt[b, blk] = pg
            n = min(page_size, L - blk * page_size)
            k_pages[pg, :n] = rng.standard_normal((n, hkv, hd))
            v_pages[pg, :n] = rng.standard_normal((n, hkv, hd))
    # poison unreferenced tail slots: masking must hide them
    k_pages[:, :, :, :] += 0.0
    return (jnp.asarray(k_pages, dtype), jnp.asarray(v_pages, dtype),
            jnp.asarray(bt), jnp.asarray(np.asarray(lengths, np.int32)))


@pytest.mark.parametrize("page_size", [8, 16])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_flash_decode_page_boundary_grid(page_size, dtype, tol):
    rng = np.random.default_rng(0)
    ps = page_size
    lengths = [1, ps - 1, ps, ps + 1, 3 * ps - 2, 2 * ps]
    hq, hkv, hd = 4, 2, 16
    k_pages, v_pages, bt, lens = _make_paged(rng, lengths, ps, hkv, hd,
                                             num_pages=32, dtype=dtype)
    q = jnp.asarray(rng.standard_normal((len(lengths), hq, hd)), dtype)
    out, m, l = flash_decode(q, k_pages, v_pages, bt, lens)
    ref = paged_decode_ref(q, k_pages, v_pages, bt, lens)
    assert float(jnp.max(jnp.abs(out - ref))) < tol
    # softmax state is self-consistent: l > 0 wherever tokens are resident
    assert bool(jnp.all(l[:, :, 0] > 0))


@pytest.mark.parametrize("window", [4, 16])
def test_flash_decode_sliding_window(window):
    rng = np.random.default_rng(1)
    ps, hq, hkv, hd = 8, 4, 4, 16
    lengths = [3, 11, 24, 17]
    k_pages, v_pages, bt, lens = _make_paged(rng, lengths, ps, hkv, hd,
                                             num_pages=24, dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((len(lengths), hq, hd)), jnp.float32)
    out, _, _ = flash_decode(q, k_pages, v_pages, bt, lens, window=window)
    ref = paged_decode_ref(q, k_pages, v_pages, bt, lens, window=window)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


def test_flash_decode_zero_length_rows_are_benign():
    """Inactive slots (length 0, all-pad tables) must not poison the batch."""
    rng = np.random.default_rng(2)
    ps, hq, hkv, hd = 8, 2, 2, 16
    k_pages, v_pages, bt, lens = _make_paged(rng, [12, 0], ps, hkv, hd,
                                             num_pages=8, dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((2, hq, hd)), jnp.float32)
    out, m, l = flash_decode(q, k_pages, v_pages, bt, lens)
    ref = paged_decode_ref(q, k_pages, v_pages, bt, lens)
    assert float(jnp.max(jnp.abs(out[0] - ref[0]))) < 1e-5
    assert float(jnp.max(jnp.abs(out[1]))) == 0.0          # empty row -> 0
    assert float(l[1].max()) == 0.0

    # merging the current token gives the empty row weight 1 on itself
    v_new = jnp.asarray(rng.standard_normal((2, hq, 1, hd)), jnp.float32)
    s_new = jnp.zeros((2, hq, 1), jnp.float32)
    merged = merge_partial_softmax(out, m, l, s_new, v_new)
    assert float(jnp.max(jnp.abs(merged[1] - v_new[1, :, 0]))) < 1e-6


def test_flash_decode_merge_matches_full_softmax():
    """Kernel partial + current-token merge == softmax over [pages, self]."""
    rng = np.random.default_rng(3)
    ps, hq, hkv, hd = 8, 4, 2, 16
    lengths = [9, 15]
    k_pages, v_pages, bt, lens = _make_paged(rng, lengths, ps, hkv, hd,
                                             num_pages=16, dtype=jnp.float32)
    B = len(lengths)
    q = jnp.asarray(rng.standard_normal((B, hq, hd)), jnp.float32)
    k_new = jnp.asarray(rng.standard_normal((B, hq, hd)), jnp.float32)
    v_new = jnp.asarray(rng.standard_normal((B, hq, hd)), jnp.float32)

    out, m, l = flash_decode(q, k_pages, v_pages, bt, lens)
    s_new = jnp.sum(q * k_new, -1, keepdims=True) * (hd ** -0.5)
    got = merge_partial_softmax(out, m, l, s_new, v_new[:, :, None])

    # oracle: dense gather with the self key appended at position L
    group = hq // hkv
    idx = jnp.clip(bt, 0, k_pages.shape[0] - 1)
    kd = jnp.repeat(k_pages[idx].reshape(B, -1, hkv, hd), group, 2)
    vd = jnp.repeat(v_pages[idx].reshape(B, -1, hkv, hd), group, 2)
    kk = jnp.concatenate([kd, k_new[:, None]], axis=1)
    vv = jnp.concatenate([vd, v_new[:, None]], axis=1)
    s = jnp.einsum("bhd,bshd->bhs", q, kk) * (hd ** -0.5)
    mask = jnp.concatenate(
        [jnp.arange(kd.shape[1])[None] < lens[:, None],   # paged: pos < L
         jnp.ones((B, 1), bool)], axis=1)                 # self: pos == L
    s = jnp.where(mask[:, None], s, -jnp.inf)
    ref = jnp.einsum("bhs,bshd->bhd", jax.nn.softmax(s, -1), vv)
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-5

"""Ladder-residual wiring (configs/ladder.py + core/iso.py ladder drivers).

The ladder variant REWIRES the residual stream (stage k reads the stream as
of stage k-2) so each stage's all-reduce completes behind the next stage's
compute.  That is a different model function from the standard wiring —
so the correctness contract here is a SCHEDULE differential: the deferred-
collective ladder drivers must be token-equal at fp32 to their immediate-
collective twins (``ladder_seq`` / ``run_layer`` post-compute resolve) of
the SAME ladder function, across prefill chunking, preemption-recompute,
prefix sharing, speculation, paged vs dense caches, and tp=1 vs tp=4
(subprocess lane).  Runs in the CI multi-device job alongside
tests/test_tp_paged.py."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import iso_cfg, tiny_dense, tiny_xlstm
from repro.config import Config, ISOConfig, ParallelConfig, ServingConfig, \
    get_model_config, ladder_variant
from repro.models import api
from repro.serving import Engine, PagedEngine, Request
from repro.serving.requests import SamplingParams


def _ladder_tiny(**kw):
    return ladder_variant(tiny_dense(vocab_size=64, **kw))


def _params(cfg, tp=1):
    return api.init_params(jax.random.PRNGKey(0), cfg, tp=tp,
                           dtype=jnp.float32)


def _paged(cfg, iso, params, *, max_batch=3, num_pages=0, decode_overlap=True,
           max_len=96, budget=48, spec_k=0, prefix_sharing=True):
    sv = ServingConfig(page_size=8, max_batch=max_batch, max_len=max_len,
                       prefill_token_budget=budget, num_pages=num_pages,
                       decode_overlap=decode_overlap, spec_k=spec_k,
                       prefix_sharing=prefix_sharing)
    return PagedEngine(Config(model=cfg,
                              parallel=ParallelConfig(data=1, model=1),
                              iso=iso, serving=sv), params, mesh=None)


def _serve(eng, prompts, max_new=8):
    rids = [eng.add_request(Request(
        prompt=p.copy(),
        sampling=SamplingParams(max_new_tokens=max_new, eos_id=-1)))
        for p in prompts]
    outs = eng.run_until_complete()
    return [outs[r] for r in rids]


# ---------------------------------------------------------------------------
# config surface
# ---------------------------------------------------------------------------

def test_ladder_configs_registered():
    for name in ("ladder-qwen3-4b", "ladder-qwen3-8b", "ladder-paper-30b"):
        cfg = get_model_config(name)
        assert cfg.residual_wiring == "ladder"
        twin = get_model_config(name[len("ladder-"):])
        assert twin.residual_wiring == "standard"
        assert cfg.block_pattern == twin.block_pattern
        assert cfg.num_layers == twin.num_layers


def test_ladder_variant_guards():
    lad = _ladder_tiny()
    assert lad.residual_wiring == "ladder"
    assert lad.name == "ladder-t-dense"
    with pytest.raises(AssertionError):
        ladder_variant(lad)                     # already ladder-wired
    with pytest.raises(AssertionError):
        ladder_variant(tiny_xlstm())            # sLSTM stage never reduces


# ---------------------------------------------------------------------------
# model-function level
# ---------------------------------------------------------------------------

def test_ladder_prefill_forces_single_chunk():
    """ISO chunking would restore the standard wiring per chunk, so the
    ladder prefill runs single-chunk regardless of ISOConfig — a chunked
    call must produce bit-identical logits to an unchunked one (and not
    trip run_layer's single-chunk assert)."""
    cfg = _ladder_tiny()
    params = _params(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 2, 64,
                              jnp.int32)
    from repro.core.overlap import AxisCtx
    ctx = AxisCtx()
    out_chunked = api.prefill(params, cfg, ctx,
                              iso_cfg(4, min_chunk_tokens=2, chunk_align=4),
                              {"tokens": toks})
    out_plain = api.prefill(params, cfg, ctx, ISOConfig(enabled=False),
                            {"tokens": toks})
    assert jnp.array_equal(out_chunked["logits_local"],
                           out_plain["logits_local"])


def test_ladder_decode_defer_equals_immediate_stack():
    """run_stack_decode_ladder(defer=True) vs its psum_now twin: bit-equal
    at fp32 on dense ring caches, K=1 and a K=3 speculative window."""
    cfg = _ladder_tiny()
    params = _params(cfg)
    from repro.core.overlap import AxisCtx
    ctx = AxisCtx()
    caches = api.init_caches(cfg, 2, 32, 1, dtype=jnp.float32)
    lens = jnp.array([4, 9], jnp.int32)
    for K in (1, 3):
        toks = jnp.arange(2 * K, dtype=jnp.int32).reshape(2, K) + 2
        l_d, c_d = api.decode_step(params, cfg, ctx, toks, caches, lens,
                                   schedule="ladder")
        l_i, c_i = api.decode_step(params, cfg, ctx, toks, caches, lens,
                                   schedule="ladder_seq")
        assert jnp.array_equal(l_d, l_i), K
        assert jax.tree_util.tree_all(jax.tree_util.tree_map(
            jnp.array_equal, c_d, c_i))


def test_ladder_differs_from_standard_function():
    """Sanity that the ladder variant is really a different function — the
    differential above would pass trivially if the rewiring were a no-op."""
    std = tiny_dense(vocab_size=64)
    lad = ladder_variant(std)
    params = _params(std)                 # same param pytree shape
    from repro.core.overlap import AxisCtx
    ctx = AxisCtx()
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 16), 2, 64,
                              jnp.int32)
    o_std = api.prefill(params, std, ctx, ISOConfig(enabled=False),
                        {"tokens": toks})
    o_lad = api.prefill(params, lad, ctx, ISOConfig(enabled=False),
                        {"tokens": toks})
    assert not jnp.allclose(o_std["logits_local"], o_lad["logits_local"])


# ---------------------------------------------------------------------------
# engine level (tp=1, fp32)
# ---------------------------------------------------------------------------

def test_ladder_engine_defer_equals_immediate_mixed_traffic():
    """The full serving differential: ladder engine with deferred
    collectives (decode_overlap=True -> "ladder") vs immediate
    ("ladder_seq"), under prefix sharing + a pool tight enough to force
    preemption-recompute.  Token streams must match exactly — this is what
    guarantees ladder prefill and ladder decode are the same function (a
    recomputed prompt replays through prefill, then decode resumes)."""
    cfg = _ladder_tiny()
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = _params(cfg)
    rng = np.random.default_rng(11)
    system = rng.integers(2, 64, 16).astype(np.int32)
    prompts = [np.concatenate([system,
                               rng.integers(2, 64, n).astype(np.int32)])
               for n in (20, 6, 13)]

    def run(decode_overlap, num_pages):
        eng = _paged(cfg, iso, params, max_batch=2, num_pages=num_pages,
                     decode_overlap=decode_overlap, max_len=64, budget=32)
        toks = _serve(eng, prompts, max_new=8)
        return toks, eng

    tight = 7                                   # forces eviction+recompute
    t_defer, e_defer = run(True, tight)
    t_imm, e_imm = run(False, tight)
    t_roomy, e_roomy = run(True, 0)
    assert e_defer._decode_schedule == "ladder"
    assert e_imm._decode_schedule == "ladder_seq"
    assert e_defer.metrics["preemptions"] > 0
    assert e_roomy.metrics["preemptions"] == 0
    assert t_defer == t_imm, "deferred vs immediate ladder twins diverged"
    assert t_defer == t_roomy, "preemption-recompute diverged"
    assert e_defer.metrics["prefix_shared_tokens"] > 0


def test_ladder_paged_equals_dense_engine():
    """Paged ladder serving (deferred) vs the dense Engine on the same
    ladder config (immediate collectives through the default sequential
    schedule): same tokens at fp32."""
    cfg = _ladder_tiny()
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = _params(cfg)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(2, 64, n).astype(np.int32) for n in (18, 7, 25)]
    dense = Engine(Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                          iso=iso), params, mesh=None, max_batch=2,
                   max_len=96, bucket=16)
    d = _serve(dense, prompts, max_new=6)
    p = _serve(_paged(cfg, iso, params, max_batch=2), prompts, max_new=6)
    assert d == p


def test_ladder_engine_single_request_b1():
    """Ladder needs no second batch half: a max_batch=1 engine (decode
    B=1) must serve, deferred == immediate."""
    cfg = _ladder_tiny()
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = _params(cfg)
    prompt = np.random.default_rng(9).integers(2, 64, 14).astype(np.int32)
    t1 = _serve(_paged(cfg, iso, params, max_batch=1), [prompt], max_new=10)
    t2 = _serve(_paged(cfg, iso, params, max_batch=1, decode_overlap=False),
                [prompt], max_new=10)
    assert t1 == t2 and len(t1[0]) == 10


def test_ladder_engine_speculative_twin():
    """spec_k=2 verify windows ride the ladder driver (K=3 decode calls);
    deferred vs immediate must accept identical windows."""
    cfg = _ladder_tiny()
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = _params(cfg)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(2, 8, 20).astype(np.int32) for _ in range(2)]

    def run(decode_overlap):
        eng = _paged(cfg, iso, params, max_batch=2, spec_k=2, max_len=96,
                     decode_overlap=decode_overlap)
        toks = _serve(eng, prompts, max_new=10)
        return toks, eng

    t_d, e_d = run(True)
    t_i, e_i = run(False)
    assert t_d == t_i
    assert e_d.metrics["spec_calls"] > 0
    assert (3, 1) in e_d._decode_fns          # K = spec_k + 1 ladder closure


# ---------------------------------------------------------------------------
# tp=4 subprocess differential (CI multi-device lane, 8 forced host devices)
# ---------------------------------------------------------------------------

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, jax.numpy as jnp, numpy as np
from repro.config import (Config, ISOConfig, ModelConfig, ParallelConfig,
                          ServingConfig, ladder_variant)
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.serving import PagedEngine, Request
from repro.serving.requests import SamplingParams

cfg = ladder_variant(ModelConfig(
    name="t-dense", family="dense", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=64, qk_norm=True))
iso = ISOConfig(enabled=True, num_chunks=2, min_chunk_tokens=8, chunk_align=8)
pc = ParallelConfig(data=1, model=4)
mesh = make_mesh(pc)
params = api.init_params(jax.random.PRNGKey(0), cfg, tp=4, dtype=jnp.float32)

rng = np.random.default_rng(3)
system = rng.integers(2, 64, 16).astype(np.int32)
prompts = [np.concatenate([system, rng.integers(2, 8, n).astype(np.int32)])
           for n in (30, 9, 17)]

def run(decode_overlap, num_pages):
    sv = ServingConfig(page_size=8, max_batch=2, max_len=96,
                       prefill_token_budget=32, num_pages=num_pages,
                       decode_overlap=decode_overlap, spec_k=2)
    eng = PagedEngine(Config(model=cfg, parallel=pc, iso=iso, serving=sv),
                      params, mesh=mesh)
    rids = [eng.add_request(Request(prompt=p.copy(),
            sampling=SamplingParams(max_new_tokens=8, eos_id=-1)))
            for p in prompts]
    outs = eng.run_until_complete()
    return [outs[r] for r in rids], eng

# mixed traffic: prefix sharing on by default, spec_k=2 verify windows, and
# a tight pool forcing preemption-recompute — deferred vs immediate ladder
# collectives must be token-equal at fp32 under real tp=4 psums
t_defer, e_defer = run(True, 8)
t_imm, e_imm = run(False, 8)
assert e_defer._decode_schedule == "ladder" and \
    e_imm._decode_schedule == "ladder_seq"
assert e_defer.metrics["preemptions"] > 0, "pool was meant to force eviction"
assert e_defer.metrics["prefix_shared_tokens"] > 0
assert e_defer.metrics["spec_calls"] > 0
assert t_defer == t_imm, (t_defer, t_imm)
print("ok ladder-tp4-defer==immediate", flush=True)

t_roomy, _ = run(True, 0)
assert t_roomy == t_defer, "preemption-recompute diverged under tp=4"
print("ALL_LADDER_TP_OK")
"""


def test_ladder_tp4_subprocess():
    res = subprocess.run([sys.executable, "-c", _CHILD], capture_output=True,
                         text=True, timeout=540)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "ALL_LADDER_TP_OK" in res.stdout

"""Observability layer: typed registry units, trace-ring bounds, replay
conservation on real engine workloads, allocator trace conservation walks
(seeded + hypothesis), and overlap-probe isolation from serving state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense, iso_cfg
from repro.config import Config, ParallelConfig, ServingConfig
from repro.models import api
from repro.obs import (ACCEPT_LEN_BUCKETS, TTFT_BUCKETS_S, Counter, Gauge,
                       Histogram, MetricsRegistry, TraceRing, chrome_trace,
                       replay_counters, validate_chrome_trace)
from repro.obs.replay import REPLAYABLE
from repro.serving import Engine, PagedEngine, Request
from repro.serving.kvcache import OutOfPages, PageAllocator
from repro.serving.requests import SamplingParams


# ---------------------------------------------------------------------------
# registry units
# ---------------------------------------------------------------------------

def test_counter_gauge_basics():
    c = Counter("x")
    c.inc()
    c.inc(4)
    assert c.value == 5
    g = Gauge("pool")
    g.set(3)
    g.set(7)
    g.set(2)
    assert g.value == 2 and g.peak == 7


def test_histogram_percentiles_bracket_observations():
    h = Histogram("ttft", TTFT_BUCKETS_S)
    vals = [0.003, 0.004, 0.011, 0.012, 0.040, 0.041, 0.150, 0.900]
    for v in vals:
        h.observe(v)
    assert h.n == len(vals)
    assert h.sum == pytest.approx(sum(vals))
    assert h.min == min(vals) and h.max == max(vals)
    # percentiles are bucket-interpolated but must stay inside [min, max]
    # and be monotone in q
    last = h.min
    for q in (0.0, 0.25, 0.5, 0.9, 0.99, 1.0):
        p = h.percentile(q)
        assert h.min <= p <= h.max, (q, p)
        assert p >= last - 1e-12
        last = p
    # the median of this sample sits in the (0.01, 0.02] bucket
    assert 0.01 <= h.percentile(0.5) <= 0.02


def test_histogram_single_observation_all_percentiles_equal():
    h = Histogram("a", ACCEPT_LEN_BUCKETS)
    h.observe(3)
    for q in (0.0, 0.5, 0.99, 1.0):
        assert h.percentile(q) == 3.0
    assert h.percentile(0.5) == h.mean == 3.0


def test_histogram_empty_and_overflow():
    h = Histogram("t", (1.0, 2.0))
    assert h.percentile(0.5) == 0.0 and h.mean == 0.0
    h.observe(50.0)                       # overflow bucket
    assert h.counts[-1] == 1
    assert h.percentile(0.99) == 50.0
    snap = h.snapshot()
    assert snap["n"] == 1 and snap["max"] == 50.0


def test_metrics_view_dict_idiom():
    r = MetricsRegistry()
    r.counters(["decode_tokens", "steps"])
    m = r.view()
    assert m["decode_tokens"] == 0
    m["decode_tokens"] += 7                      # the engines' hot-path idiom
    m["steps"] = max(m["steps"], 3)
    assert m["decode_tokens"] == 7 and m["steps"] == 3
    with pytest.raises(KeyError):
        m["typo_metric"]                         # reads of unknown keys fail
    m["late_key"] = 2                            # writes create a counter
    assert m["late_key"] == 2 and "late_key" in m
    assert r.counter("decode_tokens").value == 7
    # gauges share the scalar namespace and surface through the view too
    r.gauge("pool_occupancy").set(5)
    assert m["pool_occupancy"] == 5
    assert r.snapshot()["pool_occupancy_peak"] == 5


def test_registry_snapshot_histogram_stats():
    r = MetricsRegistry()
    h = r.histogram("ttft", TTFT_BUCKETS_S)
    h.observe(0.01)
    h.observe(0.03)
    snap = r.snapshot()
    assert snap["ttft_n"] == 2
    assert snap["ttft_min"] == 0.01 and snap["ttft_max"] == 0.03
    assert 0.01 <= snap["ttft_p50"] <= 0.03


def test_registry_type_confusion_rejected():
    r = MetricsRegistry()
    r.counter("x")
    with pytest.raises(AssertionError):
        r.gauge("x")


# ---------------------------------------------------------------------------
# trace ring
# ---------------------------------------------------------------------------

def test_trace_ring_bounded_and_counts_drops():
    ring = TraceRing(capacity=4)
    for i in range(10):
        ring.emit("accept", rid=i, n=1)
    assert len(ring) == 4 and ring.dropped == 6
    assert [e.rid for e in ring.events()] == [6, 7, 8, 9]
    ring.clear()
    assert len(ring) == 0 and ring.dropped == 0


def test_trace_ring_disabled_is_silent():
    ring = TraceRing(capacity=4, enabled=False)
    ring.emit("accept", n=1)
    assert len(ring) == 0 and ring.dropped == 0


def test_trace_timestamps_monotone_and_spans_carry_dur():
    ring = TraceRing()
    ring.emit("prefill_call", dur=0.25, ts=1.0, tokens=16)
    ring.emit("decode_call", dur=0.5, ts=2.0, k=1)
    evs = ring.events()
    assert evs[0].dur == 0.25 and evs[0].payload["tokens"] == 16
    assert evs[0].ts < evs[1].ts


# ---------------------------------------------------------------------------
# chrome-trace export + validation
# ---------------------------------------------------------------------------

def _synthetic_ring():
    ring = TraceRing()
    ring.emit("grant", rid=0, ts=0.0, start=0, n=16, padded=16, last=True)
    ring.emit("alloc", rid=0, ts=0.001, n=2, free=6, used=2)
    ring.emit("grant_commit", rid=0, slot=0, ts=0.0015, start=0, n=16,
              last=True)
    ring.emit("prefill_call", rid=0, slot=0, ts=0.002, dur=0.01, tokens=16,
              pad=0, rows=1)
    ring.emit("sample", rid=0, slot=0, ts=0.013, first=True)
    ring.emit("decode_call", ts=0.02, dur=0.005, k=1, active=1)
    ring.emit("accept", rid=0, slot=0, ts=0.025, n=1, spec=False)
    ring.emit("pool", ts=0.03, used=2, free=6, frag=3)
    ring.emit("free", rid=0, ts=0.04, n=2, free=8, used=0)
    ring.emit("finish", rid=0, slot=0, ts=0.04)
    return ring


def test_chrome_trace_schema_valid_and_typed():
    doc = chrome_trace(_synthetic_ring().events())
    assert validate_chrome_trace(doc) == []
    by_ph = {}
    for e in doc["traceEvents"]:
        by_ph.setdefault(e["ph"], []).append(e)
    assert {"M", "X", "i", "C"} <= set(by_ph)     # all four record types
    # spans: dur>0 events become complete slices in microseconds
    x = [e for e in by_ph["X"] if e["name"] == "prefill_call"][0]
    assert x["dur"] == pytest.approx(0.01 * 1e6)
    # counters carry numeric-only args
    c = by_ph["C"][0]
    assert c["name"] == "pool"
    assert all(isinstance(v, (int, float)) for v in c["args"].values())
    # slot events land on per-slot threads, allocator events on track 2
    assert x["tid"] == 10
    assert [e for e in by_ph["i"] if e["name"] == "free"][0]["tid"] == 2
    # rebased: first non-metadata event starts at ts 0
    assert min(e["ts"] for e in doc["traceEvents"] if e["ph"] != "M") == 0


def test_chrome_trace_validator_flags_bad_docs():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({"traceEvents": [{"ph": "Z"}]})
    bad_ts = {"traceEvents": [
        {"name": "a", "ph": "i", "pid": 1, "tid": 0, "ts": 5.0, "s": "t"},
        {"name": "b", "ph": "i", "pid": 1, "tid": 0, "ts": 1.0, "s": "t"}]}
    assert any("monotonic" in p for p in validate_chrome_trace(bad_ts))
    bad_counter = {"traceEvents": [
        {"name": "pool", "ph": "C", "pid": 1, "tid": 2, "ts": 0.0,
         "args": {"used": "three"}}]}
    assert any("numeric" in p for p in validate_chrome_trace(bad_counter))


def test_replay_reconstructs_synthetic_stream():
    c = replay_counters(_synthetic_ring().events())
    assert c["prefill_grants"] == 1 and c["resumed_grants"] == 0
    assert c["prefill_calls"] == 1 and c["prefill_tokens"] == 16
    assert c["decode_calls"] == 1 and c["decode_tokens"] == 1
    assert c["prefill_samples"] == 1 and c["ttft_n"] == 1
    assert c["completed"] == 1
    assert c["pages_allocated"] - c["pages_freed"] == 0


# ---------------------------------------------------------------------------
# allocator trace conservation: alloc - free == occupancy, every step
# ---------------------------------------------------------------------------

def _alloc_walk_step(a, rng):
    op = rng.integers(0, 4)
    live = sorted(a.tables)
    if op == 0:
        rid = int(rng.integers(0, 6))
        try:
            want = a.tokens(rid) + int(rng.integers(1, 9))
            a.ensure(rid, want)
            a.commit(rid, want - a.tokens(rid))
        except OutOfPages:
            pass
    elif op == 1 and live:
        a.free(int(rng.choice(live)))
    elif op == 2 and live:
        donor = int(rng.choice(live))
        rid = 100 + int(rng.integers(0, 1000))
        if rid not in a.tables and a.tables[donor]:
            k = int(rng.integers(1, len(a.tables[donor]) + 1))
            a.adopt(rid, a.tables[donor][:k],
                    min(a.tokens(donor), k * a.page_size))
    elif op == 3 and live:
        rid = int(rng.choice(live))
        if a.tables[rid]:
            try:
                a.cow(rid, int(rng.integers(0, len(a.tables[rid]))))
            except OutOfPages:
                pass


def test_allocator_trace_conserves_pool_random_walk():
    """pages_allocated - pages_freed replayed from the trace must equal the
    allocator's physical occupancy after every operation, through grow /
    free / adopt (refcount, no alloc) / CoW (alloc of the copy target)."""
    rng = np.random.default_rng(11)
    ring = TraceRing()
    a = PageAllocator(num_pages=12, page_size=4, trace=ring)
    for _ in range(400):
        _alloc_walk_step(a, rng)
        c = replay_counters(ring.events())
        assert c["pages_allocated"] - c["pages_freed"] == a.used_pages
        assert c["cow_copies"] == sum(
            1 for e in ring.events() if e.kind == "cow")
    for rid in sorted(a.tables):
        a.free(rid)
    c = replay_counters(ring.events())
    assert c["pages_allocated"] - c["pages_freed"] == 0 == a.used_pages


def test_allocator_trace_conservation_hypothesis_walk():
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n_ops=st.integers(5, 60))
    def walk(seed, n_ops):
        rng = np.random.default_rng(seed)
        ring = TraceRing()
        a = PageAllocator(num_pages=10, page_size=4, trace=ring)
        for _ in range(n_ops):
            _alloc_walk_step(a, rng)
        c = replay_counters(ring.events())
        assert c["pages_allocated"] - c["pages_freed"] == a.used_pages

    walk()


# ---------------------------------------------------------------------------
# engine conservation: replay(trace) == registry, end to end
# ---------------------------------------------------------------------------

def _paged_engine(cfg, iso, params, **sv):
    kw = dict(page_size=8, max_batch=2, max_len=160, prefill_token_budget=16)
    kw.update(sv)
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    iso=iso, serving=ServingConfig(**kw))
    return PagedEngine(config, params)


def _requests(rng, lengths, new=5, prefix=None):
    out = []
    for n in lengths:
        p = rng.integers(2, 64, n).astype(np.int32)
        if prefix is not None:
            p = np.concatenate([prefix, p])
        out.append(Request(prompt=p,
                           sampling=SamplingParams(max_new_tokens=new,
                                                   eos_id=-1)))
    return out


def _assert_replay_matches(eng, outs):
    assert eng.trace.dropped == 0
    rep = replay_counters(eng.trace.events())
    m = eng.metrics
    for name in REPLAYABLE:
        if name in m:
            assert rep[name] == m[name], \
                (name, rep[name], m[name])
    # token conservation through the registry
    total = sum(len(v) for v in outs.values())
    assert m["decode_tokens"] + m["prefill_samples"] == total
    # trace exports schema-valid
    assert validate_chrome_trace(chrome_trace(eng.trace.events())) == []
    return rep


def test_paged_engine_trace_replay_matches_registry():
    """Mixed-length chunked-prefill workload: replaying the trace must land
    on exactly the registry's counters, page conservation must close, and
    the typed histograms must have seen every request/grant."""
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    eng = _paged_engine(cfg, iso, params)
    rng = np.random.default_rng(7)
    for r in _requests(rng, (40, 12, 25, 7)):
        eng.add_request(r)
    outs = eng.run_until_complete()
    rep = _assert_replay_matches(eng, outs)
    # all requests done -> every page returned; gauge tracked the peak
    assert rep["pages_allocated"] - rep["pages_freed"] == 0
    assert eng.alloc.used_pages == 0
    assert eng.registry.gauge("pool_occupancy").value == 0
    assert eng.registry.gauge("pool_occupancy").peak == \
        eng.metrics["peak_used_pages"] > 0
    # typed distributions populated: one TTFT per request, one grant-size
    # observation per grant
    assert eng.registry.histogram("ttft").n == 4 == eng.metrics["ttft_n"]
    assert eng.registry.histogram("grant_size").n == \
        eng.metrics["prefill_grants"] > 4          # 40-tok prompt resumes
    assert eng.registry.histogram("tpot").n == eng.metrics["decode_tokens"]


def test_paged_engine_replay_with_preemption_and_sharing():
    """Preemption (evict events) and CoW prefix sharing (adopt/cow events)
    must keep the replay and the page conservation exact."""
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    # tight pool forces eviction; shared prefix forces adopt + cow
    eng = _paged_engine(cfg, iso, params, num_pages=14,
                        prefix_sharing=True)
    rng = np.random.default_rng(9)
    prefix = rng.integers(2, 64, 16).astype(np.int32)
    for r in _requests(rng, (24, 20, 18), new=6, prefix=prefix):
        eng.add_request(r)
    outs = eng.run_until_complete()
    rep = _assert_replay_matches(eng, outs)
    assert rep["prefix_shared_tokens"] == eng.metrics["prefix_shared_tokens"] > 0
    assert rep["pages_allocated"] - rep["pages_freed"] == 0


def test_paged_engine_spec_replay_matches_registry():
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    eng = _paged_engine(cfg, iso, params, spec_k=2)
    rng = np.random.default_rng(5)
    reqs = [Request(prompt=np.tile(np.arange(4, 10), 6).astype(np.int32),
                    sampling=SamplingParams(max_new_tokens=8, eos_id=-1))
            for _ in range(2)]
    for r in reqs:
        eng.add_request(r)
    outs = eng.run_until_complete()
    rep = _assert_replay_matches(eng, outs)
    assert rep["spec_calls"] == eng.metrics["spec_calls"] > 0
    assert rep["spec_tokens"] == eng.metrics["spec_tokens"]
    # one accept-length observation per slot per verify call
    spec_accepts = sum(1 for e in eng.trace.events()
                       if e.kind == "accept" and e.payload.get("spec"))
    assert eng.registry.histogram("accept_len").n == spec_accepts > 0


def test_observability_flag_silences_trace_not_registry():
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    eng = _paged_engine(cfg, iso, params, observability=False)
    rng = np.random.default_rng(2)
    for r in _requests(rng, (12, 9), new=3):
        eng.add_request(r)
    outs = eng.run_until_complete()
    assert len(eng.trace.events()) == 0            # ring silenced
    total = sum(len(v) for v in outs.values())
    assert eng.metrics["decode_tokens"] + eng.metrics["prefill_samples"] \
        == total                                   # registry still on


# ---------------------------------------------------------------------------
# dense engine parity
# ---------------------------------------------------------------------------

def test_dense_engine_registry_parity_and_replay():
    """The dense Engine now reports the same shape of metrics as the paged
    one (ttft_sum/ttft_n, typed histograms, trace replay)."""
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    iso=iso)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    eng = Engine(config, params, mesh=None, max_batch=2, max_len=96,
                 bucket=16)
    rng = np.random.default_rng(1)
    for r in _requests(rng, (20, 11, 15), new=4):
        eng.add_request(r)
    outs = eng.run_until_complete()
    m = eng.metrics
    assert m["ttft_n"] == 3 and m["ttft_sum"] > 0
    assert m["preemptions"] == 0                   # key exists for diffing
    assert eng.registry.histogram("ttft").n == 3
    assert eng.registry.histogram("tpot").n == m["decode_tokens"]
    rep = _assert_replay_matches(eng, outs)
    assert rep["completed"] == 3


def test_dense_and_paged_share_replayable_key_set():
    """Every replayable counter must exist in both engines' registries so a
    dashboard can diff them key-for-key."""
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    paged = _paged_engine(cfg, iso, params)
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    iso=iso)
    dense = Engine(config, params, mesh=None, max_batch=2, max_len=96,
                   bucket=16)
    for name in ("decode_tokens", "prefill_samples", "ttft_sum", "ttft_n",
                 "preemptions", "completed", "prefill_s", "decode_s",
                 "prefill_dispatch_s", "decode_dispatch_s"):
        assert name in paged.metrics, f"paged missing {name}"
        assert name in dense.metrics, f"dense missing {name}"


# ---------------------------------------------------------------------------
# overlap probe: isolated from serving state
# ---------------------------------------------------------------------------

def test_overlap_probe_does_not_disturb_engine():
    """The probe compiles its own closures (never polluting the serving
    decode-closure cache the compile guard pins) and leaves pool/scheduler
    state untouched, so traffic after the probe still matches."""
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    eng = _paged_engine(cfg, iso, params)
    ref = _paged_engine(cfg, iso, params)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(2, 64, n).astype(np.int32) for n in (18, 9)]

    res = eng.measure_overlap_efficiency(iters=2, warmup=1)
    assert set(res) >= {"overlap_efficiency", "t_sequential_s",
                        "t_overlap_s", "exposed_comm_s", "batch", "tp"}
    assert res["t_sequential_s"] > 0 and res["t_overlap_s"] > 0
    assert set(eng._decode_fns) <= {1}, "probe polluted serving closures"
    assert eng.alloc.used_pages == 0, "probe leaked pages"

    for e in (eng, ref):
        for p in prompts:
            e.add_request(Request(prompt=p.copy(),
                                  sampling=SamplingParams(max_new_tokens=4,
                                                          eos_id=-1)))
    outs = eng.run_until_complete()
    refs = ref.run_until_complete()
    assert [outs[r] for r in sorted(outs)] == [refs[r] for r in sorted(refs)]


def test_overlap_probe_reports_unbatchable():
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    eng = _paged_engine(cfg, iso, params, max_batch=1)
    res = eng.measure_overlap_efficiency(iters=1, warmup=0)
    assert res["overlap_efficiency"] == 0.0 and res["batch"] < 2


def test_overlap_probe_reports_all_schedules():
    """The probe now sweeps sequential / batch_split / ladder / cross_block
    and derives the ladder headline numbers (a proxy on this standard-wired
    engine)."""
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    eng = _paged_engine(cfg, iso, params)
    res = eng.measure_overlap_efficiency(iters=2, warmup=1)
    assert set(res["schedules"]) == {"sequential", "batch_split", "ladder",
                                     "cross_block"}
    assert all(t > 0 for t in res["schedules"].values())
    assert res["ladder_proxy"] is True
    assert res["ladder_speedup"] > 0
    assert res["t_ladder_s"] == res["schedules"]["ladder"]
    assert res["t_cross_block_s"] == res["schedules"]["cross_block"]
    assert abs(res["overlap_efficiency_ladder"]
               - (1 - res["t_ladder_s"] / res["t_sequential_s"])) < 1e-12
    assert set(eng._probe_decode_fns) == {
        ("sequential", True), ("batch_split", True), ("ladder", True),
        ("cross_block", True), ("sequential", False)}


def test_overlap_probe_under_split_kv_engine():
    """An engine serving with decode_kv_splits > 1 keeps its probe
    closures at kv_splits=1 (the probe measures collective schedules, not
    split-KV reduces) and its serving state/closure keys untouched."""
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    eng = _paged_engine(cfg, iso, params, decode_kv_splits=2)
    ref = _paged_engine(cfg, iso, params, decode_kv_splits=2)
    rng = np.random.default_rng(17)
    reqs = _requests(rng, (30, 12), new=4)
    for e in (eng, ref):
        for r in reqs:
            e.add_request(Request(prompt=r.prompt.copy(),
                                  sampling=r.sampling))
    outs = eng.run_until_complete()
    keys_after_traffic = set(eng._decode_fns)
    assert any(k[1] > 1 for k in keys_after_traffic), \
        "traffic was meant to exercise a split-KV closure"
    pages_after_traffic = eng.alloc.used_pages
    res = eng.measure_overlap_efficiency(iters=1, warmup=1)
    assert res["t_sequential_s"] > 0
    assert set(eng._decode_fns) == keys_after_traffic, \
        "probe must not add serving decode closures"
    assert all(isinstance(k[0], str) and isinstance(k[1], bool)
               for k in eng._probe_decode_fns), \
        "probe closures are keyed (schedule, comm), apart from (K, S)"
    assert eng.alloc.used_pages == pages_after_traffic, "probe leaked pages"
    refs = ref.run_until_complete()
    assert [outs[r] for r in sorted(outs)] == [refs[r] for r in sorted(refs)]


def test_overlap_probe_on_ladder_engine():
    """On a ladder-wired engine the probe times the real schedule twins
    (no batch_split/cross_block — the ladder driver owns the overlap),
    reports ladder_proxy=False, and leaves engine state untouched."""
    from repro.config import ladder_variant
    cfg = ladder_variant(tiny_dense(vocab_size=64))
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    eng = _paged_engine(cfg, iso, params)
    ref = _paged_engine(cfg, iso, params)
    assert eng._decode_schedule == "ladder"
    res = eng.measure_overlap_efficiency(iters=2, warmup=1)
    assert set(res["schedules"]) == {"sequential", "ladder"}
    assert res["ladder_proxy"] is False
    assert res["ladder_speedup"] > 0
    assert set(eng._decode_fns) == set(), "probe polluted serving closures"
    assert set(eng._probe_decode_fns) == {
        ("sequential", True), ("ladder", True), ("sequential", False)}
    assert eng.alloc.used_pages == 0, "probe leaked pages"
    reqs = _requests(np.random.default_rng(23), (18, 9), new=4)
    for e in (eng, ref):
        for r in reqs:
            e.add_request(Request(prompt=r.prompt.copy(),
                                  sampling=r.sampling))
    outs = eng.run_until_complete()
    refs = ref.run_until_complete()
    assert [outs[r] for r in sorted(outs)] == [refs[r] for r in sorted(refs)]

"""Training substrate: loss decreases, optimizer invariants, checkpoint
round-trip, vocab-sharded xent == dense xent."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense, tiny_moe
from repro.config import Config, ParallelConfig, RuntimeConfig
from repro.core.overlap import AxisCtx
from repro.launch.mesh import local_test_mesh
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, SyntheticLM, make_training_batch
from repro.training.loss import sharded_xent
from repro.training.optimizer import adamw_init, adamw_update, warmup_cosine
from repro.training.trainer import init_train_state, make_train_step


def _run_steps(cfg, n_steps, seq=32, batch=4):
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    runtime=RuntimeConfig(mode="train", seq_len=seq,
                                          global_batch=batch, max_steps=n_steps,
                                          warmup_steps=2, remat=False))
    mesh = local_test_mesh(1, 1)
    params, opt = init_train_state(config, mesh, jax.random.PRNGKey(0),
                                   dtype=jnp.float32)
    step_fn, *_ = make_train_step(config, mesh, jax.eval_shape(lambda: params))
    losses = []
    with mesh:
        for s in range(n_steps):
            b = make_training_batch(cfg, seq, batch, s)
            b = {k: jnp.asarray(v) for k, v in b.items()}
            params, opt, loss, _ = step_fn(params, opt, b, jnp.int32(s))
            losses.append(float(loss))
    return losses


def test_loss_decreases_dense():
    losses = _run_steps(tiny_dense(), 12)
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


def test_loss_decreases_moe():
    losses = _run_steps(tiny_moe(), 8)
    assert losses[-1] < losses[0] + 0.05


def test_sharded_xent_matches_dense():
    key = jax.random.PRNGKey(0)
    B, S, V = 2, 8, 64
    logits = jax.random.normal(key, (B, S, V), jnp.float32)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0, V)
    got = sharded_xent(logits, labels, AxisCtx())
    logp = jax.nn.log_softmax(logits)
    want = -jnp.mean(jnp.take_along_axis(logp, labels[..., None], -1))
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_warmup_cosine_schedule():
    lr0 = warmup_cosine(jnp.int32(0), 1e-3, 10, 100)
    lr_w = warmup_cosine(jnp.int32(10), 1e-3, 10, 100)
    lr_end = warmup_cosine(jnp.int32(100), 1e-3, 10, 100)
    assert float(lr0) == 0.0
    np.testing.assert_allclose(float(lr_w), 1e-3, rtol=1e-5)
    assert float(lr_end) < 2e-4


def test_adamw_grad_clip_invariance():
    params = {"w": jnp.ones((4, 4))}
    big_grads = {"w": jnp.full((4, 4), 100.0)}
    st = adamw_init(params)
    p1, _ = adamw_update(params, big_grads, st, lr=0.1, weight_decay=0.0,
                         grad_clip=1.0)
    # clipped update magnitude bounded by lr * (1 + eps slack)
    assert float(jnp.max(jnp.abs(p1["w"] - params["w"]))) <= 0.11


def test_checkpoint_roundtrip():
    cfg = tiny_dense()
    from repro.models import api
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, {"params": params}, step=7)
        restored, step = ckpt.restore(d, {"params": params})
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_synthetic_data_deterministic_and_learnable():
    dc = DataConfig(seq_len=64, global_batch=2, vocab_size=128, seed=3)
    ds = SyntheticLM(dc)
    b1 = ds.batch(5)
    b2 = ds.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (2, 64)
    # markov structure: majority of next tokens follow the permutation
    toks, labs = b1["tokens"], b1["labels"]
    hit = (ds.perm[toks] == labs).mean()
    assert hit > 0.5

"""Disaggregated prefill/decode serving (serving/disagg.py): the router's
two-engine pipeline must emit token streams BYTE-IDENTICAL to single-engine
serving — under prefix sharing, speculation, batched prefill, both scheduler
policies, capped migration batches, forced decode-side preemption and a
decode pool too small to accept migrations promptly.

Also pinned here:

  * phase purity: the prefill engine never compiles a decode closure, the
    decode engine never compiles a prefill closure, and the decode-side
    closure key set stays the single-engine compile-guard shape;
  * replay conservation PER ENGINE across migration: replaying each engine's
    trace reproduces its registry (including ``migrations``/
    ``migrated_pages`` from ``migrate`` spans), page conservation holds on
    both allocators, and both pools drain to zero;
  * defer-and-retry (never preemption): a full decode pool defers migration
    — requests queue on the prefill side, nothing crashes, no tokens
    diverge, and no decode-resident request is evicted to make room;
  * decode-side eviction victims bounce BACK to the prefill engine in
    recompute mode and still finish with the exact stream.

The two-mesh variant (prefill and decode engines on disjoint 4-device
shard_map meshes) runs in the CI multi-device lane via a subprocess, like
tests/test_tp_paged.py.
"""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense, iso_cfg
from repro.config import Config, ParallelConfig, ServingConfig
from repro.models import api
from repro.obs.replay import REPLAYABLE, replay_counters
from repro.serving import PagedEngine, Request
from repro.serving.disagg import DisaggRouter
from repro.serving.requests import SamplingParams

CFG = tiny_dense(vocab_size=64)
ISO = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)


@pytest.fixture(scope="module")
def params():
    return api.init_params(jax.random.PRNGKey(0), CFG, tp=1,
                           dtype=jnp.float32)


def _config(**sv):
    kw = dict(page_size=8, max_batch=2, max_len=160, prefill_token_budget=16)
    kw.update(sv)
    return Config(model=CFG, parallel=ParallelConfig(data=1, model=1),
                  iso=ISO, serving=ServingConfig(**kw))


def _single(params, **sv):
    return PagedEngine(_config(**sv), params)


def _disagg(params, **sv):
    sv.setdefault("disagg", True)
    return DisaggRouter(_config(**sv), params)


def _repetitive(rng, n, period=6):
    base = rng.integers(2, 64, period).astype(np.int32)
    return np.tile(base, -(-n // period))[:n]


def _mixed_prompts(rng):
    """Repetitive (draft-friendly), random, and a prefix-sharing pair."""
    shared = rng.integers(2, 64, 24).astype(np.int32)
    return [
        _repetitive(rng, 30),
        rng.integers(2, 64, 33).astype(np.int32),
        np.concatenate([shared, rng.integers(2, 64, 9).astype(np.int32)]),
        np.concatenate([shared, rng.integers(2, 64, 5).astype(np.int32)]),
    ]


def _submit(eng, prompts, new=8, priorities=None):
    rids = []
    for i, p in enumerate(prompts):
        pr = priorities[i] if priorities else 0
        rids.append(eng.add_request(Request(
            prompt=p.copy(), priority=pr,
            sampling=SamplingParams(max_new_tokens=new, eos_id=-1))))
    return rids


def _assert_conserved(eng):
    """Replay the engine's trace; every replayable counter must equal the
    registry's, and allocator conservation must hold."""
    assert eng.trace.dropped == 0
    rep = replay_counters(eng.trace.events())
    m = eng.metrics
    for name in REPLAYABLE:
        if name in m:
            assert rep[name] == m[name], (name, rep[name], m[name])
    assert rep["pages_allocated"] - rep["pages_freed"] == \
        eng.alloc.used_pages


def _assert_router_invariants(router, spec_k=0):
    for eng in (router.prefill, router.decode):
        _assert_conserved(eng)
        assert eng.alloc.used_pages == 0            # both pools drained
        eng.alloc.check()
    # phase purity: no decode closure on the prefill engine, no prefill
    # closure on the decode engine, decode keys stay the pinned shape
    assert set(router.prefill._decode_fns) == set()
    assert set(router.decode._prefill_fns) == set()
    allowed = {(1, 1)} | ({(spec_k + 1, 1)} if spec_k else set())
    assert set(router.decode._decode_fns) <= allowed, \
        set(router.decode._decode_fns)
    assert router.decode._decode_fns, "decode engine never decoded"
    cap = router.prefill.max_prefill_compiles()
    if cap is not None:
        assert router.prefill.prefill_compile_count() <= cap
    assert not router._pending
    # every request that migrated is accounted: detach-side span total ==
    # attach-side import total is implied by per-engine conservation; here
    # pin the request-level books
    assert router.stats["migrated_requests"] == \
        sum(1 for e in router.prefill.trace.events() if e.kind == "detach")
    assert router.stats["migrated_requests"] == \
        sum(1 for e in router.decode.trace.events() if e.kind == "attach")


# ---------------------------------------------------------------------------
# differential battery: disagg == single engine, byte for byte
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec_k", [0, 2])
def test_disagg_matches_single_engine_mixed_traffic(params, spec_k):
    rng = np.random.default_rng(11)
    prompts = _mixed_prompts(rng)

    single = _single(params, spec_k=spec_k)
    s_rids = _submit(single, prompts)
    s_outs = single.run_until_complete()

    router = _disagg(params, spec_k=spec_k)
    d_rids = _submit(router, prompts)
    d_outs = router.run_until_complete()

    for sr, dr in zip(s_rids, d_rids):
        assert s_outs[sr] == d_outs[dr], (sr, s_outs[sr], d_outs[dr])
    assert router.stats["migrated_requests"] == len(prompts)
    assert router.prefill.metrics["migrations"] > 0
    assert router.prefill.metrics["migrated_pages"] > 0
    # prefix sharing engaged on the prefill side and survived migration
    assert router.prefill.metrics["prefix_shared_tokens"] > 0
    if spec_k:
        # the transferred draft state kept speculation alive on the decode
        # engine (without it the repetitive prompt would verify nothing)
        assert router.decode.metrics["spec_calls"] > 0
        assert router.decode.accepted_per_call() > 1.0
    _assert_router_invariants(router, spec_k=spec_k)


def test_disagg_priority_policy_and_migrate_batch(params):
    """Priority traffic under a migrate_batch=1 cap: transfers trickle one
    request per router step, in policy order, with identical tokens."""
    rng = np.random.default_rng(7)
    prompts = _mixed_prompts(rng)
    prios = [0, 2, 1, 3]

    single = _single(params, scheduler_policy="priority")
    s_rids = _submit(single, prompts, priorities=prios)
    s_outs = single.run_until_complete()

    router = _disagg(params, scheduler_policy="priority", migrate_batch=1)
    d_rids = _submit(router, prompts, priorities=prios)
    d_outs = router.run_until_complete()

    for sr, dr in zip(s_rids, d_rids):
        assert s_outs[sr] == d_outs[dr], (sr, s_outs[sr], d_outs[dr])
    # the cap really bit: one request per transfer
    n_mig = router.prefill.metrics["migrations"]
    assert n_mig == router.stats["migrated_requests"] == len(prompts)
    _assert_router_invariants(router)


def test_disagg_batched_transfer_keeps_sharing(params):
    """max_batch large enough that the sharing pair migrates in ONE
    transfer: the shared page must be exported once and still be shared
    (same physical page, refcount 2) on the decode side."""
    rng = np.random.default_rng(19)
    shared = rng.integers(2, 64, 24).astype(np.int32)
    prompts = [np.concatenate([shared, rng.integers(2, 64, 5).astype(np.int32)]),
               np.concatenate([shared, rng.integers(2, 64, 9).astype(np.int32)])]

    single = _single(params, max_batch=4, prefill_token_budget=128)
    s_rids = _submit(single, prompts)
    s_outs = single.run_until_complete()

    router = _disagg(params, max_batch=4, prefill_token_budget=128)
    shared_seen = []
    orig_attach = router.decode.attach_requests

    def spy(transfer):
        orig_attach(transfer)
        if len(transfer.records) == 2:
            t = router.decode.alloc.tables
            r0, r1 = transfer.rids
            shared_seen.append(sum(1 for a, b in zip(t[r0], t[r1])
                                   if a == b))
    router.decode.attach_requests = spy
    d_rids = _submit(router, prompts)
    d_outs = router.run_until_complete()

    for sr, dr in zip(s_rids, d_rids):
        assert s_outs[sr] == d_outs[dr]
    assert shared_seen and shared_seen[0] >= 3, shared_seen
    _assert_router_invariants(router)


# ---------------------------------------------------------------------------
# flow control: full decode pool, decode-side eviction
# ---------------------------------------------------------------------------

def test_full_decode_pool_defers_never_preempts(params):
    """Decode pool sized for ONE resident request: migration of the rest
    must DEFER (requests hold their pages on the prefill side) — no crash,
    no decode-side preemption, no token divergence."""
    rng = np.random.default_rng(23)
    prompts = [rng.integers(2, 64, n).astype(np.int32) for n in (30, 26, 21)]

    single = _single(params, max_batch=3, num_pages=24)
    s_rids = _submit(single, prompts)
    s_outs = single.run_until_complete()

    # 30 prompt + 8 new @ ps=8 -> 5 pages; 6-page decode pool fits one
    router = _disagg(params, max_batch=3, num_pages=24, decode_pool_pages=6)
    d_rids = _submit(router, prompts)
    d_outs = router.run_until_complete(max_steps=2_000)

    for sr, dr in zip(s_rids, d_rids):
        assert s_outs[sr] == d_outs[dr], (sr, s_outs[sr], d_outs[dr])
    assert router.stats["deferrals"] > 0
    assert router.decode.metrics["preemptions"] == 0, \
        "attach pressure must defer, never evict a decode-resident request"
    assert router.stats["bounce_backs"] == 0
    _assert_router_invariants(router)


def test_full_decode_pool_rejects_oversized_request(params):
    router = _disagg(params, decode_pool_pages=2)
    with pytest.raises(ValueError, match="decode pool"):
        router.add_request(Request(
            prompt=np.arange(2, 60, dtype=np.int32),
            sampling=SamplingParams(max_new_tokens=8, eos_id=-1)))


def test_decode_side_eviction_bounces_back(params):
    """A decode pool that fits both prompts but NOT both decode windows
    forces a decode-side eviction; the victim must bounce back to the
    prefill engine (recompute mode), re-migrate, and finish with the exact
    single-engine stream."""
    rng = np.random.default_rng(29)
    prompts = [rng.integers(2, 64, 16).astype(np.int32),
               rng.integers(2, 64, 16).astype(np.int32)]

    single = _single(params, page_size=4, max_len=80, num_pages=40)
    s_rids = _submit(single, prompts, new=12)
    s_outs = single.run_until_complete()

    # 16-token prompts -> 4 pages each; 12 new tokens -> up to 7 pages each.
    # 10 decode pages: both attach, growth collides mid-decode.
    router = _disagg(params, page_size=4, max_len=80, num_pages=40,
                     decode_pool_pages=10)
    d_rids = _submit(router, prompts, new=12)
    d_outs = router.run_until_complete(max_steps=2_000)

    for sr, dr in zip(s_rids, d_rids):
        assert s_outs[sr] == d_outs[dr], (sr, s_outs[sr], d_outs[dr])
    assert router.stats["bounce_backs"] > 0
    assert router.decode.metrics["preemptions"] == \
        router.stats["bounce_backs"]
    # the victim migrated at least twice: initial + after recompute
    assert router.stats["migrated_requests"] > len(prompts)
    _assert_router_invariants(router)


# ---------------------------------------------------------------------------
# preemption on the PREFILL side (pool pressure before migration)
# ---------------------------------------------------------------------------

def test_disagg_with_prefill_side_preemption(params):
    """A prefill pool too small for all requests at once forces recompute
    preemption BEFORE migration; streams still match the single engine run
    with the same tight pool."""
    rng = np.random.default_rng(31)
    # three 30-token prompts (4 pages each) against an 8-page pool with a
    # budget that grants two whole prompts in one step: the third grant's
    # page growth must evict mid-prefill, on both sides of the comparison
    prompts = [rng.integers(2, 64, 30).astype(np.int32) for _ in range(3)]

    single = _single(params, num_pages=8, max_batch=3,
                     prefill_token_budget=64)
    s_rids = _submit(single, prompts, new=6)
    s_outs = single.run_until_complete()
    assert single.metrics["preemptions"] > 0, "scenario must actually evict"

    router = _disagg(params, num_pages=8, max_batch=3,
                     prefill_token_budget=64)
    d_rids = _submit(router, prompts, new=6)
    d_outs = router.run_until_complete(max_steps=2_000)

    for sr, dr in zip(s_rids, d_rids):
        assert s_outs[sr] == d_outs[dr], (sr, s_outs[sr], d_outs[dr])
    assert router.prefill.metrics["preemptions"] > 0
    _assert_router_invariants(router)


# ---------------------------------------------------------------------------
# two-mesh variant: CI multi-device lane (subprocess, 8 forced host devices)
# ---------------------------------------------------------------------------

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, jax.numpy as jnp, numpy as np
from repro.config import (Config, ISOConfig, ModelConfig, ParallelConfig,
                          ServingConfig)
from repro.launch.mesh import disagg_meshes
from repro.models import api
from repro.serving import PagedEngine, Request
from repro.serving.disagg import DisaggRouter
from repro.serving.requests import SamplingParams

key = jax.random.PRNGKey(0)
iso = ISOConfig(enabled=True, num_chunks=2, min_chunk_tokens=8, chunk_align=8)
cfg = ModelConfig(name="t-dense", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                  qk_norm=True)
sp = lambda n=6: SamplingParams(max_new_tokens=n, eos_id=-1)
rng = np.random.default_rng(3)
shared = rng.integers(2, 64, 24).astype(np.int32)
# the sharing pair FIRST: both admit together, so the donor is still
# resident on the prefill engine when the sharee's first grant runs
# (a migrated donor's pages leave the prefill pool with it)
prompts = [np.concatenate([shared, rng.integers(2, 64, 9).astype(np.int32)]),
           np.concatenate([shared, rng.integers(2, 64, 5).astype(np.int32)]),
           rng.integers(2, 64, 33).astype(np.int32)]
# budget covers both sharers in ONE step: under disagg a finished donor
# migrates (pages and all) the same step, so cross-step sharing windows
# close — same-step packmate sharing is the one that must survive
sv = ServingConfig(page_size=8, max_batch=2, max_len=160,
                   prefill_token_budget=64, disagg=True)

# single-device paged reference
cfg1 = Config(model=cfg, parallel=ParallelConfig(data=1, model=1), iso=iso,
              serving=sv)
params1 = api.init_params(key, cfg, tp=1, dtype=jnp.float32)
ref = PagedEngine(cfg1, params1)
r_rids = [ref.add_request(Request(prompt=p.copy(), sampling=sp()))
          for p in prompts]
r_out = ref.run_until_complete()

# disaggregated: prefill engine on devices[:4], decode engine on devices[4:]
pc = ParallelConfig(data=1, model=4)
pmesh, dmesh = disagg_meshes(pc)
assert set(pmesh.devices.flat).isdisjoint(set(dmesh.devices.flat))
params4 = api.init_params(key, cfg, tp=4, dtype=jnp.float32)
router = DisaggRouter(Config(model=cfg, parallel=pc, iso=iso, serving=sv),
                      params4, prefill_mesh=pmesh, decode_mesh=dmesh)
d_rids = [router.add_request(Request(prompt=p.copy(), sampling=sp()))
          for p in prompts]
d_out = router.run_until_complete()
for rr, dr in zip(r_rids, d_rids):
    assert r_out[rr] == d_out[dr], (rr, r_out[rr], d_out[dr])
assert router.stats["migrated_requests"] == len(prompts)
assert router.prefill.metrics["prefix_shared_tokens"] > 0
assert set(router.prefill._decode_fns) == set()
assert set(router.decode._prefill_fns) == set()
print("ALL_DISAGG_TP_OK")
"""


def test_disagg_two_meshes_subprocess():
    res = subprocess.run([sys.executable, "-c", _CHILD], capture_output=True,
                         text=True, timeout=540)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "ALL_DISAGG_TP_OK" in res.stdout

# NOTE: deliberately NO XLA_FLAGS here — smoke tests and benches must see the
# real single CPU device; only launch/dryrun.py forces 512 placeholder devices.
import jax
import jax.numpy as jnp
import pytest

from repro.config import ISOConfig, ModelConfig, MoEConfig, SSMConfig


def tiny_dense(**kw):
    base = dict(name="t-dense", family="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                qk_norm=True)
    base.update(kw)
    return ModelConfig(**base)


def tiny_moe(**kw):
    base = dict(name="t-moe", family="moe", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=128,
                block_pattern=("attn_moe",),
                moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                              capacity_factor=8.0, shared_expert_d_ff=32))
    base.update(kw)
    return ModelConfig(**base)


def tiny_hybrid(**kw):
    base = dict(name="t-hybrid", family="hybrid", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                block_pattern=("hybrid",), ssm=SSMConfig(state_dim=8),
                sliding_window=16)
    base.update(kw)
    return ModelConfig(**base)


def tiny_xlstm(**kw):
    base = dict(name="t-xlstm", family="ssm", num_layers=4, d_model=64,
                num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=128,
                block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
                pos_type="none")
    base.update(kw)
    return ModelConfig(**base)


def tiny_whisper(**kw):
    base = dict(name="t-whisper", family="audio", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=128,
                norm_type="ln", mlp_type="gelu", pos_type="sinusoidal",
                block_pattern=("dec_block",), encoder_layers=2,
                encoder_frames=20)
    base.update(kw)
    return ModelConfig(**base)


def tiny_vlm(**kw):
    base = dict(name="t-vlm", family="vlm", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                num_patches=8)
    base.update(kw)
    return ModelConfig(**base)


ALL_TINY = [tiny_dense, tiny_moe, tiny_hybrid, tiny_xlstm, tiny_whisper,
            tiny_vlm]


def iso_cfg(n=2, **kw):
    base = dict(enabled=True, num_chunks=n, min_chunk_tokens=2, chunk_align=4)
    base.update(kw)
    return ISOConfig(**base)


ISO_OFF = ISOConfig(enabled=False)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)

"""Paged flash-prefill kernel parity + grant-size bucketing equivalence.

Three layers of checking:
  * kernel vs the pure-jnp oracle (kernels/ref.paged_prefill_ref) across page
    sizes, boundary prefix lengths (chunk == page, chunk straddling pages),
    pos_offset > 0, fp32/bf16 pools and sliding windows;
  * layer level: ``attn_prefill_paged_partial`` (kernel + dense intra merge)
    vs ``attn_prefill_partial`` fed the densely GATHERED prefix — including
    bucket-padded tails (``k_limit``) and intra-call chunk KV;
  * engine level: bucketed paged prefill emits token streams identical to the
    dense unbucketed engine (deterministic boundary grid + a hypothesis
    random walk), and resumed grants never touch the dense prefix gather.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import iso_cfg, tiny_dense
from repro.config import Config, ParallelConfig, ServingConfig
from repro.kernels.flash_prefill_paged import flash_prefill_paged
from repro.kernels.ref import paged_prefill_ref
from repro.layers import attention as attn_lib
from repro.layers.heads import head_layout
from repro.models import api
from repro.serving import Engine, PagedEngine, Request
from repro.serving.kvcache import gather_pages, gather_positions
from repro.serving.requests import SamplingParams


def _make_paged(rng, prefix_lens, page_size, hkv, hd, num_pages, dtype):
    """Random page pool + block tables holding ``prefix_lens[b]`` tokens."""
    B = len(prefix_lens)
    max_blocks = -(-max(max(prefix_lens), 1) // page_size) + 1
    k_pages = np.zeros((num_pages + 1, page_size, hkv, hd), np.float32)
    v_pages = np.zeros_like(k_pages)
    bt = np.full((B, max_blocks), -1, np.int32)
    free = list(range(num_pages))
    for b, L in enumerate(prefix_lens):
        for blk in range(-(-L // page_size)):
            pg = free.pop()
            bt[b, blk] = pg
            # fill the WHOLE page: tokens beyond the prefix are poison the
            # prefix_len mask must hide (the prefix-sharing donor-tail rule)
            k_pages[pg] = rng.standard_normal((page_size, hkv, hd))
            v_pages[pg] = rng.standard_normal((page_size, hkv, hd))
    return (jnp.asarray(k_pages, dtype), jnp.asarray(v_pages, dtype),
            jnp.asarray(bt), jnp.asarray(np.asarray(prefix_lens, np.int32)))


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("page_size", [8, 16])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_kernel_page_boundary_grid(page_size, dtype, tol):
    rng = np.random.default_rng(0)
    ps = page_size
    prefix_lens = [0, 1, ps - 1, ps, ps + 1, 3 * ps - 2, 2 * ps]
    hq, hkv, hd = 4, 2, 16
    k_pages, v_pages, bt, lens = _make_paged(rng, prefix_lens, ps, hkv, hd,
                                             num_pages=32, dtype=dtype)
    for Sq in (ps, ps + 3):                   # chunk == page and straddling
        q = jnp.asarray(rng.standard_normal((len(prefix_lens), hq, Sq, hd)),
                        dtype)
        out, m, l = flash_prefill_paged(q, k_pages, v_pages, bt, lens, lens,
                                        block_q=8)
        ro, rm, rl = paged_prefill_ref(q, k_pages, v_pages, bt, lens, lens)
        assert float(jnp.max(jnp.abs(out - ro))) < tol
        assert float(jnp.max(jnp.abs(l - rl))) < tol * 10
        # empty-prefix rows return the neutral state (0, NEG_INF, 0)
        assert float(jnp.max(jnp.abs(out[0]))) == 0.0
        assert float(l[0].max()) == 0.0


@pytest.mark.parametrize("window", [4, 16])
def test_kernel_sliding_window(window):
    rng = np.random.default_rng(1)
    ps, hq, hkv, hd = 8, 4, 4, 16
    prefix_lens = [3, 11, 24, 17]
    k_pages, v_pages, bt, lens = _make_paged(rng, prefix_lens, ps, hkv, hd,
                                             num_pages=24, dtype=jnp.float32)
    Sq = 6
    q = jnp.asarray(rng.standard_normal((len(prefix_lens), hq, Sq, hd)),
                    jnp.float32)
    # queries start right after the prefix (the resumed-grant layout)
    out, _, _ = flash_prefill_paged(q, k_pages, v_pages, bt, lens, lens,
                                    window=window, block_q=8)
    ro, _, _ = paged_prefill_ref(q, k_pages, v_pages, bt, lens, lens,
                                 window=window)
    assert float(jnp.max(jnp.abs(out - ro))) < 1e-5


def test_kernel_pos_offset_within_grant():
    """The second ISO chunk of a grant starts pos_offset + chunk_start tokens
    in; its window/position masking must use the true absolute positions."""
    rng = np.random.default_rng(2)
    ps, hq, hkv, hd = 8, 2, 2, 16
    prefix_lens = [13, 21]
    k_pages, v_pages, bt, lens = _make_paged(rng, prefix_lens, ps, hkv, hd,
                                             num_pages=16, dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((2, hq, 5, hd)), jnp.float32)
    q_starts = lens + 7                       # mid-call chunk offset
    out, _, _ = flash_prefill_paged(q, k_pages, v_pages, bt, lens, q_starts,
                                    window=9, block_q=8)
    ro, _, _ = paged_prefill_ref(q, k_pages, v_pages, bt, lens, q_starts,
                                 window=9)
    assert float(jnp.max(jnp.abs(out - ro))) < 1e-5


@pytest.mark.parametrize("page_size", [8, 16])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_kernel_heterogeneous_rows(page_size, dtype, tol):
    """The batched-grant layout: every row has its OWN prefix length, query
    start and block table — a fresh request (prefix 0) packed next to resumed
    ones at different depths, each row's queries starting right after its own
    prefix.  The per-row scalar prefetch must keep the rows independent."""
    rng = np.random.default_rng(20)
    ps = page_size
    prefix_lens = [0, ps + 3, 3 * ps, 2 * ps - 1]
    hq, hkv, hd = 4, 2, 16
    k_pages, v_pages, bt, lens = _make_paged(rng, prefix_lens, ps, hkv, hd,
                                             num_pages=32, dtype=dtype)
    Sq = ps + 2
    q = jnp.asarray(rng.standard_normal((len(prefix_lens), hq, Sq, hd)), dtype)
    # q_starts == prefix_lens: the packed-grant resume layout (fresh row: 0)
    out, m, l = flash_prefill_paged(q, k_pages, v_pages, bt, lens, lens,
                                    block_q=8)
    ro, rm, rl = paged_prefill_ref(q, k_pages, v_pages, bt, lens, lens)
    assert float(jnp.max(jnp.abs(out - ro))) < tol
    assert float(jnp.max(jnp.abs(l - rl))) < tol * 10
    # the fresh row is exactly the neutral partial state
    assert float(jnp.max(jnp.abs(out[0]))) == 0.0
    assert float(l[0].max()) == 0.0 and float(m[0].max()) < -1e29
    # row independence: each row equals its own single-row call bit-for-bit
    for b in range(len(prefix_lens)):
        ob, _, lb = flash_prefill_paged(q[b:b + 1], k_pages, v_pages,
                                        bt[b:b + 1], lens[b:b + 1],
                                        lens[b:b + 1], block_q=8)
        assert jnp.array_equal(ob[0], out[b]) and jnp.array_equal(lb[0], l[b])


@pytest.mark.parametrize("window", [5, 16])
def test_kernel_heterogeneous_rows_window(window):
    """Sliding window over heterogeneous rows: each row's window anchors at
    its OWN per-row q_start (mid-grant pos_offset included), so a shared
    window width must mask different key ranges per row."""
    rng = np.random.default_rng(21)
    ps, hq, hkv, hd = 8, 4, 4, 16
    prefix_lens = [0, 7, 19, 26]
    k_pages, v_pages, bt, lens = _make_paged(rng, prefix_lens, ps, hkv, hd,
                                             num_pages=24, dtype=jnp.float32)
    Sq = 6
    q = jnp.asarray(rng.standard_normal((len(prefix_lens), hq, Sq, hd)),
                    jnp.float32)
    # per-row mid-call chunk offsets on top of the per-row resume position
    q_starts = lens + jnp.asarray([0, 3, 0, 5], jnp.int32)
    out, _, _ = flash_prefill_paged(q, k_pages, v_pages, bt, lens, q_starts,
                                    window=window, block_q=8)
    ro, _, _ = paged_prefill_ref(q, k_pages, v_pages, bt, lens, q_starts,
                                 window=window)
    assert float(jnp.max(jnp.abs(out - ro))) < 1e-5


def test_layer_batched_rows_equal_single_rows():
    """attn_prefill_paged_partial with per-row start_pos/prefix_lens/k_limit
    (the packed-grant call) must reproduce each row's single-request result —
    including a fresh row (prefix 0) and per-row bucket-pad tails."""
    rng = np.random.default_rng(22)
    cfg = tiny_dense(vocab_size=32)
    group = cfg.num_heads // cfg.num_kv_heads
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    ps = 8
    prefix_lens = [0, 13, 24]
    n_reals = [9, 16, 11]                     # row 0 and 2 carry pad tails
    S = 16
    k_pages, v_pages, bt, lens = _make_paged(rng, prefix_lens, ps, hkv, hd,
                                             num_pages=16, dtype=jnp.float32)
    p = attn_lib.init_attention(
        jax.random.PRNGKey(0), cfg,
        head_layout(cfg.num_heads, cfg.num_kv_heads, 1), dtype=jnp.float32)
    x = jnp.asarray(rng.standard_normal((3, S, cfg.d_model)) * 0.2,
                    jnp.float32)
    starts = lens
    k_limit = starts + jnp.asarray(n_reals, jnp.int32)
    batched, kv_b = attn_lib.attn_prefill_paged_partial(
        p, x, cfg, group, k_pages=k_pages, v_pages=v_pages,
        block_tables=bt, prefix_lens=lens, start_pos=starts, k_limit=k_limit)
    for b in range(3):
        single, kv_s = attn_lib.attn_prefill_paged_partial(
            p, x[b:b + 1], cfg, group, k_pages=k_pages, v_pages=v_pages,
            block_tables=bt[b:b + 1], prefix_lens=lens[b:b + 1],
            start_pos=jnp.int32(prefix_lens[b]),
            k_limit=jnp.int32(prefix_lens[b] + n_reals[b]))
        real = np.s_[:n_reals[b]]
        assert float(jnp.max(jnp.abs(batched[b][real] - single[0][real]))) \
            < 1e-5
        assert float(jnp.max(jnp.abs(kv_b[0][b] - kv_s[0][0]))) < 1e-6


def test_merge_softmax_states_matches_full_softmax():
    """Splitting the key set and merging partial states == one softmax."""
    rng = np.random.default_rng(3)
    B, Sq, Hq, hd, Sk = 2, 5, 4, 16, 12
    q = jnp.asarray(rng.standard_normal((B, Sq, Hq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Sk, Hq, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Sk, Hq, hd)), jnp.float32)
    q_pos = jnp.broadcast_to(Sk + jnp.arange(Sq)[None], (B, Sq)).astype(
        jnp.int32)
    k_pos = jnp.broadcast_to(jnp.arange(Sk)[None], (B, Sk)).astype(jnp.int32)
    full = attn_lib.sdpa(q, k, v, q_pos=q_pos, k_pos=k_pos, causal=True)
    cut = 7
    oa, ma, la = attn_lib.sdpa_partial(q, k[:, :cut], v[:, :cut], q_pos=q_pos,
                                       k_pos=k_pos[:, :cut], causal=True)
    ob, mb, lb = attn_lib.sdpa_partial(q, k[:, cut:], v[:, cut:], q_pos=q_pos,
                                       k_pos=k_pos[:, cut:], causal=True)
    merged = attn_lib.merge_softmax_states(oa, ma, la, ob, mb, lb)
    assert float(jnp.max(jnp.abs(merged - full))) < 1e-5


# ---------------------------------------------------------------------------
# layer level: paged path == dense-gathered path
# ---------------------------------------------------------------------------

def _layer_oracle_pair(rng, *, prefix_len, S_chunk, n_pad=0, window=0,
                       intra=0):
    """Build matched inputs for the paged and dense-gathered prefill paths."""
    cfg = tiny_dense(vocab_size=32, sliding_window=window)
    layout_group = cfg.num_heads // cfg.num_kv_heads
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    ps = 8
    k_pages, v_pages, bt, lens = _make_paged(rng, [prefix_len], ps, hkv, hd,
                                             num_pages=16, dtype=jnp.float32)
    p = attn_lib.init_attention(
        jax.random.PRNGKey(0), cfg,
        head_layout(cfg.num_heads, cfg.num_kv_heads, 1), dtype=jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, S_chunk, cfg.d_model)) * 0.2,
                    jnp.float32)
    return cfg, layout_group, p, x, k_pages, v_pages, bt, lens, ps


@pytest.mark.parametrize("prefix_len,S_chunk,n_pad,window",
                         [(8, 8, 0, 0),        # chunk == page
                          (13, 11, 0, 0),      # straddling pages
                          (13, 16, 5, 0),      # bucket-padded tail
                          (19, 9, 3, 12)])     # window + pad
def test_layer_paged_equals_dense_gather(prefix_len, S_chunk, n_pad, window):
    rng = np.random.default_rng(4)
    cfg, group, p, x, k_pages, v_pages, bt, lens, ps = _layer_oracle_pair(
        rng, prefix_len=prefix_len, S_chunk=S_chunk, window=window)
    start = jnp.int32(prefix_len)
    n_real = S_chunk - n_pad
    k_limit = (start + n_real) if n_pad else None

    paged, kv_paged = attn_lib.attn_prefill_paged_partial(
        p, x, cfg, group, k_pages=k_pages, v_pages=v_pages,
        block_tables=bt, prefix_lens=lens, start_pos=start,
        window=window, k_limit=k_limit)

    # oracle: gather the prefix dense (the pre-kernel engine path)
    pos_pages = jnp.full(k_pages.shape[:2], -1, jnp.int32)
    for blk in range(-(-prefix_len // ps)):
        n = min(ps, prefix_len - blk * ps)
        pos_pages = pos_pages.at[bt[0, blk], :n].set(
            blk * ps + jnp.arange(n, dtype=jnp.int32))
    kd = gather_pages(k_pages[None], bt)[0]
    vd = gather_pages(v_pages[None], bt)[0]
    posd = gather_positions(pos_pages, bt)
    posd = jnp.where(posd < prefix_len, posd, -1)
    dense, kv_dense = attn_lib.attn_prefill_partial(
        p, x, cfg, group, start_pos=start, prefix_kv=(kd, vd),
        prefix_pos=posd, window=window, k_limit=k_limit)

    real = np.s_[:, :n_real]
    assert float(jnp.max(jnp.abs(paged[real] - dense[real]))) < 1e-4
    assert float(jnp.max(jnp.abs(kv_paged[0] - kv_dense[0]))) < 1e-5


def test_layer_intra_call_chunk_kv():
    """Second ISO chunk of a grant: paged prefix via kernel + first chunk's
    KV attended densely must equal the all-dense reference."""
    rng = np.random.default_rng(5)
    prefix_len, S1, S2 = 11, 6, 7
    cfg, group, p, x_all, k_pages, v_pages, bt, lens, ps = _layer_oracle_pair(
        rng, prefix_len=prefix_len, S_chunk=S1 + S2)
    x1, x2 = x_all[:, :S1], x_all[:, S1:]
    start = jnp.int32(prefix_len)

    _, kv1 = attn_lib.attn_prefill_paged_partial(
        p, x1, cfg, group, k_pages=k_pages, v_pages=v_pages,
        block_tables=bt, prefix_lens=lens, start_pos=start)
    intra_pos = (prefix_len + jnp.arange(S1, dtype=jnp.int32))[None]
    paged2, _ = attn_lib.attn_prefill_paged_partial(
        p, x2, cfg, group, k_pages=k_pages, v_pages=v_pages,
        block_tables=bt, prefix_lens=lens, start_pos=start + S1,
        intra_kv=kv1, intra_pos=intra_pos)

    pos_pages = jnp.full(k_pages.shape[:2], -1, jnp.int32)
    for blk in range(-(-prefix_len // ps)):
        n = min(ps, prefix_len - blk * ps)
        pos_pages = pos_pages.at[bt[0, blk], :n].set(
            blk * ps + jnp.arange(n, dtype=jnp.int32))
    kd = gather_pages(k_pages[None], bt)[0]
    vd = gather_pages(v_pages[None], bt)[0]
    posd = gather_positions(pos_pages, bt)
    _, kv1_d = attn_lib.attn_prefill_partial(
        p, x1, cfg, group, start_pos=start, prefix_kv=(kd, vd),
        prefix_pos=posd)
    dense2, _ = attn_lib.attn_prefill_partial(
        p, x2, cfg, group, start_pos=start + S1,
        prefix_kv=(jnp.concatenate([kd, kv1_d[0]], 1),
                   jnp.concatenate([vd, kv1_d[1]], 1)),
        prefix_pos=jnp.concatenate([posd, intra_pos], 1))
    assert float(jnp.max(jnp.abs(paged2 - dense2))) < 1e-4


# ---------------------------------------------------------------------------
# engine level: bucketed paged == dense unbucketed, no dense gather
# ---------------------------------------------------------------------------

def _dense_ref(cfg, iso, params, prompts, new):
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    iso=iso)
    eng = Engine(config, params, mesh=None, max_batch=2, max_len=160,
                 bucket=16)
    rids = [eng.add_request(Request(
        prompt=p.copy(), sampling=SamplingParams(max_new_tokens=new,
                                                 eos_id=-1)))
        for p in prompts]
    out = eng.run_until_complete()
    return [out[r] for r in rids]


def _paged_run(cfg, iso, params, prompts, new, **sv_kw):
    sv = dict(page_size=8, max_batch=2, max_len=160, prefill_token_budget=16)
    sv.update(sv_kw)
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    iso=iso, serving=ServingConfig(**sv))
    eng = PagedEngine(config, params)
    rids = [eng.add_request(Request(
        prompt=p.copy(), sampling=SamplingParams(max_new_tokens=new,
                                                 eos_id=-1)))
        for p in prompts]
    out = eng.run_until_complete()
    return [out[r] for r in rids], eng


def test_engine_bucketed_matches_dense_boundary_lengths():
    """Grant lengths hitting bucket boundaries exactly, one below, one above,
    and resumed mid-bucket grants — all must match the dense stream."""
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    rng = np.random.default_rng(6)
    lengths = (16, 15, 17, 32, 33, 70, 7)
    prompts = [rng.integers(2, 64, n).astype(np.int32) for n in lengths]
    ref = _dense_ref(cfg, iso, params, prompts, new=5)
    got, eng = _paged_run(cfg, iso, params, prompts, new=5)
    assert got == ref
    assert eng._buckets is not None
    assert eng.metrics["prefill_pad_tokens"] > 0, "bucketing never padded"


def test_engine_bucketing_off_still_matches():
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(2, 64, n).astype(np.int32) for n in (23, 41)]
    ref = _dense_ref(cfg, iso, params, prompts, new=4)
    got, eng = _paged_run(cfg, iso, params, prompts, new=4,
                          grant_bucketing=False)
    assert got == ref
    assert eng._buckets is None
    assert eng.metrics["prefill_pad_tokens"] == 0


@pytest.mark.parametrize("batched", [True, False])
def test_resumed_grants_never_dense_gather(monkeypatch, batched):
    """The paged prefill kernel replaced the per-grant dense prefix gather;
    a resumed grant calling gather_pages again would be a regression — in
    both the packed and the batch-1 prefill paths."""
    import repro.serving.kvcache as kvcache_mod

    def _boom(*a, **k):
        raise AssertionError("resumed prefill called the dense prefix gather")

    monkeypatch.setattr(kvcache_mod, "gather_pages", _boom)
    monkeypatch.setattr(kvcache_mod, "gather_positions", _boom)
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    rng = np.random.default_rng(8)
    prompts = [rng.integers(2, 64, 70).astype(np.int32)]   # forces resume
    got, eng = _paged_run(cfg, iso, params, prompts, new=3,
                          prefill_batching=batched)
    assert len(got[0]) == 3
    assert eng.metrics["resumed_grants"] > 0, \
        "workload never exercised a resumed grant"
    if not batched:
        resumed_keys = [k for k in eng._prefill_fns if k[2]]
        assert resumed_keys, "batch-1 path never compiled a resumed closure"


# ---------------------------------------------------------------------------
# hypothesis random walk (skipped when hypothesis is missing, like
# test_paged_props.py — CI installs it; guarded per-test so the rest of this
# module still runs without it)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                            # pragma: no cover - env dep
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.lists(st.integers(min_value=3, max_value=90), min_size=1,
                    max_size=4),
           st.integers(min_value=0, max_value=2 ** 31 - 1))
    def test_random_walk_bucketed_paged_equals_dense(lengths, seed):
        """Property: for ANY mixed-length workload, paged-bucketed prefill
        emits token streams identical to dense unbucketed prefill."""
        cfg = tiny_dense(vocab_size=64)
        iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
        params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                                 dtype=jnp.float32)
        rng = np.random.default_rng(seed)
        prompts = [rng.integers(2, 64, n).astype(np.int32) for n in lengths]
        ref = _dense_ref(cfg, iso, params, prompts, new=3)
        got, _ = _paged_run(cfg, iso, params, prompts, new=3)
        assert got == ref
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_random_walk_bucketed_paged_equals_dense():
        pass

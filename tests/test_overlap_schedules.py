"""Decode collective schedules (core/iso.py) + psum_wait barrier semantics.

Covers the decode-overlap bugfix sweep: the ``psum_wait`` self-barrier on
trailing reduces, cross-block token identity vs sequential, odd-batch
batch-split grids, and the B < 2 fallbacks (both the iso-level delegate in
``run_stack_decode_overlap`` and the engine's per-step sequential closure
when traffic drains to one resident request)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense, iso_cfg
from repro import compat
from repro.config import Config, ParallelConfig, ServingConfig
from repro.core.overlap import AxisCtx, Pending, psum_now, psum_start, \
    psum_wait
from repro.models import api
from repro.serving import PagedEngine, Request
from repro.serving.requests import SamplingParams

P = jax.sharding.PartitionSpec


# ---------------------------------------------------------------------------
# psum_wait barrier semantics (satellite: empty-overlap self-barrier)
# ---------------------------------------------------------------------------

def test_psum_wait_noop_empty_is_identity_no_barrier():
    """tp_axis=None + no overlap outputs: identity value, and no barrier in
    the jaxpr — the no-op ctx has nothing to pin."""
    ctx = AxisCtx()
    x = jnp.arange(4.0)
    pend = psum_start(x, ctx)
    assert isinstance(pend, Pending) and pend.noop
    reduced, rebound = psum_wait(pend)
    assert rebound == ()
    assert jnp.array_equal(reduced, x)
    jaxpr = jax.make_jaxpr(lambda y: psum_wait(psum_start(y, ctx))[0])(x)
    assert "optimization_barrier" not in str(jaxpr)


def test_psum_wait_noop_with_overlap_still_pins():
    """Even a no-op reduce pins against overlap outputs (the schedule shape
    must not depend on the mesh, or tp=1 oracles compile different graphs)."""
    ctx = AxisCtx()
    x = jnp.arange(4.0)
    jaxpr = jax.make_jaxpr(
        lambda y: psum_wait(psum_start(y, ctx), (y * 2,)))(x)
    assert "optimization_barrier" in str(jaxpr)
    reduced, (other,) = psum_wait(psum_start(x, ctx), (x * 2,))
    assert jnp.array_equal(reduced, x) and jnp.array_equal(other, x * 2)


def _tp1_mesh():
    return compat.make_mesh((1, 1), ("data", "model"))


def _sharded_jaxpr(fn, x, mesh):
    wrapped = compat.shard_map(fn, mesh=mesh, in_specs=P(), out_specs=P(),
                               check_vma=False)
    return str(jax.make_jaxpr(wrapped)(x))


def test_psum_wait_empty_overlap_self_barriers_real_reduce():
    """A REAL (mesh-backed) trailing reduce with no overlap outputs must
    stay behind a barrier: without it XLA's all-reduce combiner may merge
    the deferred cross-block reduce with a neighbour, re-serializing the
    schedule the caller staged."""
    mesh = _tp1_mesh()
    ctx = AxisCtx(tp_axis="model", tp=1)
    x = jnp.arange(4.0)

    def wait_only(y):
        return psum_wait(psum_start(y, ctx))[0]

    s = _sharded_jaxpr(wait_only, x, mesh)
    assert "psum" in s and "optimization_barrier" in s
    out = jax.jit(compat.shard_map(wait_only, mesh=mesh, in_specs=P(),
                                   out_specs=P(), check_vma=False))(x)
    assert jnp.array_equal(out, x)        # tp=1: reduce is value-identity


def test_psum_wait_quantized_ctx_routes_and_barriers():
    mesh = _tp1_mesh()
    ctx = AxisCtx(tp_axis="model", tp=1, quantized_comm=True)
    x = jnp.linspace(-2.0, 2.0, 8)

    def wait_only(y):
        return psum_wait(psum_start(y, ctx))[0]

    s = _sharded_jaxpr(wait_only, x, mesh)
    assert "optimization_barrier" in s
    out = jax.jit(compat.shard_map(wait_only, mesh=mesh, in_specs=P(),
                                   out_specs=P(), check_vma=False))(x)
    # quantization round-trips through int8 blocks — close, not exact
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=0.05)


def test_psum_now_matches_wait_value():
    ctx = AxisCtx()
    x = jnp.arange(6.0).reshape(2, 3)
    assert jnp.array_equal(psum_now(x, ctx), psum_wait(psum_start(x, ctx))[0])


# ---------------------------------------------------------------------------
# schedule drivers through the engine (fp32: schedules must be token-equal)
# ---------------------------------------------------------------------------

def _engine(cfg, iso, params, *, max_batch, schedule="auto", page_size=8,
            max_len=96, budget=48, decode_overlap=True):
    sv = ServingConfig(page_size=page_size, max_batch=max_batch,
                       max_len=max_len, prefill_token_budget=budget,
                       decode_schedule=schedule,
                       decode_overlap=decode_overlap)
    return PagedEngine(Config(model=cfg,
                              parallel=ParallelConfig(data=1, model=1),
                              iso=iso, serving=sv), params, mesh=None)


def _serve(eng, prompts, max_new=8):
    rids = [eng.add_request(Request(
        prompt=p.copy(),
        sampling=SamplingParams(max_new_tokens=max_new, eos_id=-1)))
        for p in prompts]
    outs = eng.run_until_complete()
    return [outs[r] for r in rids]


def _mixed_prompts(rng, n, lo=8, hi=24):
    return [rng.integers(2, 64, int(rng.integers(lo, hi))).astype(np.int32)
            for _ in range(n)]


def test_cross_block_tokens_equal_sequential():
    """Deferring every reduce to the next stage top must not change tokens
    (fp32; the barrier is an identity and no mesh means identity reduces)."""
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    prompts = _mixed_prompts(np.random.default_rng(7), 5)
    seq = _serve(_engine(cfg, iso, params, max_batch=3,
                         schedule="sequential"), prompts)
    xb = _serve(_engine(cfg, iso, params, max_batch=3,
                        schedule="cross_block"), prompts)
    assert seq == xb


@pytest.mark.parametrize("max_batch", [3, 5, 7])
def test_batch_split_odd_batch_tokens_equal_sequential(max_batch):
    """Odd B splits as (B//2, B - B//2); every odd grid must stay
    token-equal to the sequential schedule (fp32, identity collectives)."""
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    prompts = _mixed_prompts(np.random.default_rng(max_batch), max_batch + 2)
    seq = _serve(_engine(cfg, iso, params, max_batch=max_batch,
                         schedule="sequential"), prompts)
    ovl = _serve(_engine(cfg, iso, params, max_batch=max_batch,
                         schedule="batch_split"), prompts)
    assert seq == ovl


def test_overlap_stack_b1_falls_back_to_sequential():
    """Direct iso-level call at B=1: run_stack_decode_overlap must degrade
    to the sequential driver instead of crashing (pre-fix: assert B >= 2)."""
    cfg = tiny_dense(vocab_size=64)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    caches = api.init_caches(cfg, 1, 32, 1, dtype=jnp.float32)
    toks = jnp.array([[5]], jnp.int32)
    lens = jnp.array([4], jnp.int32)
    ctx = AxisCtx()
    l_seq, _ = api.decode_step(params, cfg, ctx, toks, caches, lens,
                               schedule="sequential")
    l_ovl, _ = api.decode_step(params, cfg, ctx, toks, caches, lens,
                               schedule="batch_split")
    assert jnp.array_equal(l_seq, l_ovl)


def test_engine_drain_to_one_uses_fallback_and_matches_sequential():
    """Regression (the B < 2 crash): a batch-split engine whose traffic
    drains to ONE resident decode must fall back to a sequential closure
    for those steps — cached in ``_decode_fallback_fns`` so the main
    ``_decode_fns`` key set stays schedule-pure — and still emit the same
    tokens as an all-sequential engine."""
    cfg = tiny_dense(vocab_size=64)
    iso = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(2, 64, 12).astype(np.int32) for _ in range(2)]

    def run(schedule):
        eng = _engine(cfg, iso, params, max_batch=2, schedule=schedule)
        rids = []
        for i, mn in enumerate((4, 40)):   # req 1 decodes long alone
            rids.append(eng.add_request(Request(
                prompt=prompts[i].copy(),
                sampling=SamplingParams(max_new_tokens=mn, eos_id=-1))))
        outs = eng.run_until_complete()
        return [outs[r] for r in rids], eng

    toks_ovl, eng_ovl = run("batch_split")
    toks_seq, eng_seq = run("sequential")
    assert toks_ovl == toks_seq
    assert set(eng_ovl._decode_fallback_fns) == {(1, 1)}, \
        "drained steps must compile the sequential fallback closure"
    assert set(eng_ovl._decode_fns) == {(1, 1)}
    assert not eng_seq._decode_fallback_fns
    falls = [e for e in eng_ovl.trace.events()
             if e.kind == "decision"
             and e.payload.get("point") == "decode_schedule"]
    assert falls and all(e.payload["active"] < 2 for e in falls)


def test_enable_latency_hiding_idempotent(monkeypatch):
    """All three flag names already present (any value): nothing is
    appended, the env is untouched, and no subprocess probe runs."""
    from repro.launch import mesh
    preset = " ".join(f.split("=")[0] + "=false"
                      for f in mesh.LATENCY_HIDING_XLA_FLAGS)
    monkeypatch.setenv("XLA_FLAGS", preset)
    monkeypatch.setattr(mesh, "_flags_accepted",
                        lambda *a, **k: pytest.fail("probe must not run"))
    assert mesh.enable_latency_hiding() is False
    assert os.environ["XLA_FLAGS"] == preset


def test_enable_latency_hiding_filters_rejected_flags(monkeypatch):
    """Flags the installed XLA rejects must be filtered, not applied (a
    CPU-only jaxlib aborts at backend init on an unknown flag)."""
    from repro.launch import mesh
    monkeypatch.setenv("XLA_FLAGS", "--xla_force_host_platform_device_count=2")
    ok = {"--xla_gpu_enable_latency_hiding_scheduler=true"}
    monkeypatch.setattr(mesh, "_flags_accepted",
                        lambda flags, **k: set(flags) <= ok)
    assert mesh.enable_latency_hiding() is True
    flags = os.environ["XLA_FLAGS"].split()
    assert flags == ["--xla_force_host_platform_device_count=2",
                     "--xla_gpu_enable_latency_hiding_scheduler=true"]


def test_decode_schedule_validation():
    cfg = tiny_dense(vocab_size=64)
    params = api.init_params(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    with pytest.raises(AssertionError):
        _engine(cfg, iso_cfg(), params, max_batch=2, schedule="bogus")

"""Dry-run launcher guard: the production-mesh lower+compile path must stay
green (smallest arch x cheapest shape; full sweep is the offline deliverable)."""
import json
import subprocess
import sys

import pytest

_CMD = [sys.executable, "-m", "repro.launch.dryrun", "--arch", "xlstm-350m",
        "--shape", "decode_32k", "--out", "/tmp/dryrun_guard.json"]


@pytest.mark.slow
def test_dryrun_single_pair_compiles():
    res = subprocess.run(_CMD, capture_output=True, text=True, timeout=540)
    assert res.returncode == 0, res.stderr[-2000:]
    data = json.load(open("/tmp/dryrun_guard.json"))
    assert len(data["reports"]) == 1 and not data["failures"]
    r = data["reports"][0]
    assert r["devices"] == 256
    ro = r["roofline"]
    assert ro["compute_s"] > 0 and ro["memory_s"] > 0
    assert r["collective_wire_bytes_per_device"] > 0

"""Measured cost model (perf/costmodel.py): fit, decisions, fallback,
determinism, and the engine-level differential.

The load-bearing invariants:

  * every decision axis is TOKEN-NEUTRAL — chunk caps are exact chunk
    splits, pack width is call grouping, split count is a numerics-stable
    re-association, skipping speculation is the plain-decode path — so a
    model-driven engine must emit streams identical to the static-default
    engine on ANY traffic (the differential here runs sharing + preemption
    + spec_k=2 + forced splits);
  * graceful degradation — missing / malformed / wrong-platform tables fall
    back to static defaults with exactly ONE warning trace event;
  * determinism — decisions are pure table lookups (no clocks), so an
    identical table + traffic yields an identical decision sequence.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense, iso_cfg
from repro.config import Config, ParallelConfig, ServingConfig
from repro.models import api
from repro.perf.costmodel import (SCHEMA, CostModel, fit_linear,
                                  load_cost_model, measure_alpha_beta,
                                  validate_table)
from repro.serving import PagedEngine, Request
from repro.serving.requests import SamplingParams

CFG = tiny_dense(vocab_size=64)
ISO = iso_cfg(2, min_chunk_tokens=8, chunk_align=8)


@pytest.fixture(scope="module")
def params():
    return api.init_params(jax.random.PRNGKey(0), CFG, tp=1,
                           dtype=jnp.float32)


def _table(*, platform="cpu", tp=1, prefill=None, decode=None,
           alpha=1e-6, beta=1e-10):
    """Hand-built schema-valid table with controllable decision surfaces."""
    return {
        "schema": SCHEMA, "version": 1, "platform": platform,
        "mesh": {"tp": tp}, "model": "t-dense", "page_size": 8,
        "alpha_beta": {"alpha_s": alpha, "beta_s_per_byte": beta, "r2": 1.0},
        "prefill_us": prefill if prefill is not None
        else {"16x1": 100.0, "32x1": 150.0, "64x1": 260.0},
        "decode_us": decode if decode is not None
        else {"1/1/2": 50.0, "1/1/8": 90.0},
    }


def _paged(params, *, cost_model=None, cost_table="", spec_k=0, num_pages=0,
           budget=16, max_batch=2, kv_splits=0):
    config = Config(model=CFG, parallel=ParallelConfig(data=1, model=1),
                    iso=ISO,
                    serving=ServingConfig(page_size=8, max_batch=max_batch,
                                          max_len=160, num_pages=num_pages,
                                          prefill_token_budget=budget,
                                          spec_k=spec_k,
                                          decode_kv_splits=kv_splits,
                                          cost_model=cost_model,
                                          cost_table=cost_table))
    return PagedEngine(config, params)


def _repetitive(rng, n, period=6):
    base = rng.integers(2, 64, period).astype(np.int32)
    return np.tile(base, -(-n // period))[:n]


def _mixed_prompts(rng):
    shared = rng.integers(2, 64, 24).astype(np.int32)
    return [
        _repetitive(rng, 30),
        rng.integers(2, 64, 33).astype(np.int32),
        np.concatenate([shared, rng.integers(2, 64, 9).astype(np.int32)]),
        np.concatenate([shared, rng.integers(2, 64, 5).astype(np.int32)]),
    ]


def _run(eng, prompts, new=8):
    rids = [eng.add_request(Request(
        prompt=p.copy(),
        sampling=SamplingParams(max_new_tokens=new, eos_id=-1)))
        for p in prompts]
    outs = eng.run_until_complete()
    return [outs[r] for r in rids]


# ---------------------------------------------------------------------------
# fit + measurement primitives
# ---------------------------------------------------------------------------

def test_fit_linear_recovers_synthetic_line():
    alpha, beta = 3e-6, 2e-10
    xs = [1024, 8192, 65536, 1 << 20]
    alpha_f, beta_f, r2 = fit_linear([(x, alpha + beta * x) for x in xs])
    assert abs(alpha_f - alpha) < 1e-9
    assert abs(beta_f - beta) / beta < 1e-6
    assert r2 > 0.999


def test_fit_linear_degenerate_inputs():
    a, b, r2 = fit_linear([(100.0, 5.0)])
    assert (a, b) == (5.0, 0.0) and r2 == 1.0
    a, b, _ = fit_linear([(100.0, 5.0), (100.0, 7.0)])  # all-equal x
    assert b == 0.0 and a == 6.0
    # negative intercept from noise clamps to zero, never a negative latency
    a, _, _ = fit_linear([(10.0, 0.1), (20.0, 30.0)])
    assert a >= 0.0


def test_measure_alpha_beta_single_device():
    ab = measure_alpha_beta(sizes=(1024, 65536), iters=2, warmup=1)
    assert ab["collective"] == "local"
    assert np.isfinite(ab["alpha_s"]) and ab["alpha_s"] >= 0
    assert np.isfinite(ab["beta_s_per_byte"]) and ab["beta_s_per_byte"] >= 0
    assert len(ab["samples"]) == 2


# ---------------------------------------------------------------------------
# table schema
# ---------------------------------------------------------------------------

def test_validate_table_accepts_good_and_names_problems():
    assert validate_table(_table()) == []
    assert validate_table([]) == ["table is not a JSON object"]
    bad = _table()
    bad["schema"] = "nope"
    assert any("schema" in p for p in validate_table(bad))
    bad = _table()
    bad["alpha_beta"]["alpha_s"] = float("nan")
    assert any("alpha_s" in p for p in validate_table(bad))
    bad = _table(decode={"1/1": 50.0})            # wrong key arity
    assert any("malformed key" in p for p in validate_table(bad))
    bad = _table(prefill={"16x1": -1.0})
    assert any("timing" in p for p in validate_table(bad))
    bad = _table()
    del bad["mesh"]
    assert any("mesh" in p for p in validate_table(bad))


# ---------------------------------------------------------------------------
# CostModel decisions from synthetic tables
# ---------------------------------------------------------------------------

def test_decode_splits_picks_measured_argmin():
    cm = CostModel(_table(decode={
        "1/1/4": 100.0, "1/2/4": 60.0, "1/4/4": 80.0,
        "1/1/16": 400.0, "1/2/16": 390.0, "1/4/16": 200.0}))
    assert cm.decode_splits(4, K=1) == 2
    assert cm.decode_splits(16, K=1) == 4
    # interpolated depth between measured points still decides
    assert cm.decode_splits(8, K=1) in (2, 4)
    # no data for this K -> None (caller falls back to the static heuristic)
    assert cm.decode_splits(8, K=3) is None
    # a span can never exceed the page walk
    assert cm.decode_splits(1, K=1) == 1


def test_decode_splits_tie_breaks_smaller_and_respects_cap():
    cm = CostModel(_table(decode={"1/1/8": 100.0, "1/2/8": 100.0,
                                  "1/4/8": 50.0}))
    assert cm.decode_splits(8, K=1, max_splits=2) == 1   # tie -> smaller S
    assert cm.decode_splits(8, K=1) == 4


def test_grant_cap_best_time_per_token():
    cm = CostModel(_table(prefill={"16x1": 100.0, "32x1": 120.0,
                                   "64x1": 400.0}))
    # per-token: 6.25, 3.75, 6.25 -> 32 wins
    assert cm.grant_cap() == 32
    assert cm.grant_cap(buckets=(16, 64)) == 16
    assert cm.grant_cap(buckets=(128,)) is None


def test_pack_rows_best_time_per_grant():
    cm = CostModel(_table(prefill={
        "32x1": 100.0, "32x2": 150.0, "32x4": 500.0}))
    # per-grant: 100, 75, 125 -> 2 wins, at the nearest measured bucket
    assert cm.pack_rows(32) == 2
    assert cm.pack_rows(40) == 2


def test_spec_worth_verify_vs_expected_accepts():
    cm = CostModel(_table(decode={"1/1/8": 100.0, "3/1/8": 150.0}))
    assert cm.spec_worth(3, 8, expected_accept=2.0) is True    # 150 < 200
    assert cm.spec_worth(3, 8, expected_accept=1.2) is False   # 150 >= 120
    assert cm.spec_worth(5, 8, expected_accept=3.0) is None    # K=5 unmeasured


def test_collective_s_alpha_beta():
    cm = CostModel(_table(alpha=2e-6, beta=1e-9))
    assert cm.collective_s(0) == pytest.approx(2e-6)
    assert cm.collective_s(1000) == pytest.approx(2e-6 + 1e-6)


def test_costmodel_rejects_invalid_table():
    bad = _table()
    bad["schema"] = "nope"
    with pytest.raises(ValueError):
        CostModel(bad)


# ---------------------------------------------------------------------------
# fallback contract: static defaults + exactly one warning event
# ---------------------------------------------------------------------------

def _warnings(eng):
    return [e for e in eng.trace.events() if e.kind == "warning"]


def test_fallback_missing_table(params, tmp_path):
    eng = _paged(params, cost_table=str(tmp_path / "nope.json"))
    assert eng.cost_model is None
    (w,) = _warnings(eng)
    assert w.payload["what"] == "cost_table"
    assert w.payload["reason"] == "missing"


def test_fallback_malformed_table(params, tmp_path):
    p = tmp_path / "bad.json"
    p.write_text("{not json")
    eng = _paged(params, cost_table=str(p))
    assert eng.cost_model is None
    (w,) = _warnings(eng)
    assert w.payload["reason"].startswith("unreadable")

    p2 = tmp_path / "invalid.json"
    p2.write_text(json.dumps({"schema": "costmodel-v1"}))
    eng2 = _paged(params, cost_table=str(p2))
    assert eng2.cost_model is None
    (w2,) = _warnings(eng2)
    assert w2.payload["reason"].startswith("invalid")


def test_fallback_wrong_platform_or_mesh(params, tmp_path):
    p = tmp_path / "tpu.json"
    p.write_text(json.dumps(_table(platform="tpu")))
    eng = _paged(params, cost_table=str(p))
    assert eng.cost_model is None
    (w,) = _warnings(eng)
    assert "mismatch" in w.payload["reason"]

    p2 = tmp_path / "tp8.json"
    p2.write_text(json.dumps(_table(tp=8)))
    eng2 = _paged(params, cost_table=str(p2))
    assert eng2.cost_model is None
    (w2,) = _warnings(eng2)
    assert "tp8" in w2.payload["reason"]


def test_fallback_serves_identically_to_no_table(params, tmp_path):
    """A failed table load must not just warn — the engine must behave
    exactly like one never configured with a table."""
    rng = np.random.default_rng(5)
    prompts = _mixed_prompts(rng)
    plain = _run(_paged(params), prompts)
    fallen = _run(_paged(params, cost_table=str(tmp_path / "gone.json")),
                  prompts)
    assert fallen == plain


def test_load_cost_model_roundtrip(tmp_path):
    p = tmp_path / "good.json"
    p.write_text(json.dumps(_table()))
    cm = load_cost_model(str(p), platform="cpu", tp=1, trace=None)
    assert cm is not None and cm.platform == "cpu" and cm.tp == 1
    assert load_cost_model(str(p), platform="tpu", tp=1, trace=None) is None


# ---------------------------------------------------------------------------
# decisions drive the engine (and are traced)
# ---------------------------------------------------------------------------

def _decisions(eng, point=None):
    evs = [e for e in eng.trace.events() if e.kind == "decision"]
    if point is not None:
        evs = [e for e in evs if e.payload["point"] == point]
    return evs


def test_modeled_kv_splits_override_static(params):
    """A table whose measurements favour S=2 at depth must steer the auto
    heuristic away from the static answer (S=1 at shallow depths) and key
    the decode closures on the modeled S."""
    cm = CostModel(_table(decode={"1/1/2": 100.0, "1/2/2": 40.0,
                                  "1/1/16": 500.0, "1/2/16": 200.0}))
    eng = _paged(params, cost_model=cm)
    rng = np.random.default_rng(9)
    _run(eng, [rng.integers(2, 64, 20).astype(np.int32)], new=4)
    assert set(eng._decode_fns) == {(1, 2)}, sorted(eng._decode_fns)
    decs = _decisions(eng, "kv_splits")
    assert decs and all(d.payload["chosen"] == 2 for d in decs)
    assert all(d.payload["static"] == 1 for d in decs)


def test_explicit_kv_splits_beats_model(params):
    """ServingConfig.decode_kv_splits != 0 is an explicit operator choice —
    the model must not override it."""
    cm = CostModel(_table(decode={"1/1/2": 100.0, "1/2/2": 40.0}))
    eng = _paged(params, cost_model=cm, kv_splits=1)
    rng = np.random.default_rng(9)
    _run(eng, [rng.integers(2, 64, 20).astype(np.int32)], new=4)
    assert set(eng._decode_fns) == {(1, 1)}
    assert not _decisions(eng, "kv_splits")


def test_modeled_grant_cap_truncates_grants(params):
    """A table favouring 16-token prefill calls caps every grant at 16;
    the remainder resumes next step (exact split — tokens unchanged)."""
    cm = CostModel(_table(prefill={"16x1": 100.0, "32x1": 400.0,
                                   "64x1": 900.0}))
    eng = _paged(params, cost_model=cm, budget=64)
    assert eng.scheduler._grant_cap == 16
    rng = np.random.default_rng(10)
    prompts = [rng.integers(2, 64, 40).astype(np.int32)]
    got = _run(eng, prompts)
    assert _decisions(eng, "grant_cap")
    assert all(e.payload["n"] <= 16 for e in eng.trace.events()
               if e.kind == "grant_commit")
    plain = _run(_paged(params, budget=64), [p.copy() for p in prompts])
    assert got == plain


def test_modeled_pack_cap_limits_rows(params):
    """A table where 1-row calls beat wider packs forces singleton packs."""
    prefill = {f"{t}x{r}": 100.0 * t * (r ** 2) / 16
               for t in (16, 32, 64) for r in (1, 2, 4)}
    cm = CostModel(_table(prefill=prefill))
    eng = _paged(params, cost_model=cm, max_batch=4, budget=256)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(2, 64, 30).astype(np.int32) for _ in range(3)]
    got = _run(eng, prompts)
    assert _decisions(eng, "pack_rows")
    # every prefill call ran a single real row
    assert all(e.payload["rows"] == 1 for e in eng.trace.events()
               if e.kind == "prefill_call")
    plain = _run(_paged(params, max_batch=4, budget=256),
                 [p.copy() for p in prompts])
    assert got == plain


def test_modeled_spec_gate_disables_unprofitable_speculation(params,
                                                             monkeypatch):
    """A table where the K-token verify costs more than the accepts it
    replaces must gate speculation OFF once the histogram warms up — and
    the stream must still equal the plain-decode stream."""
    monkeypatch.setattr(PagedEngine, "SPEC_GATE_MIN_SAMPLES", 1)
    cm = CostModel(_table(decode={"1/1/2": 100.0, "1/1/16": 100.0,
                                  "3/1/2": 1000.0, "3/1/16": 1000.0}))
    rng = np.random.default_rng(12)
    prompts = [_repetitive(rng, 30), _repetitive(rng, 24)]
    eng = _paged(params, cost_model=cm, spec_k=2)
    got = _run(eng, prompts, new=10)
    gate = _decisions(eng, "spec_gate")
    assert gate and all(d.payload["chosen"] == 1 for d in gate)
    plain = _run(_paged(params, spec_k=0), [p.copy() for p in prompts],
                 new=10)
    assert got == plain
    # profitable table (verify cheaper than even ONE plain step, so the
    # verdict holds for any histogram mean): gate stays open
    cm2 = CostModel(_table(decode={"1/1/2": 100.0, "1/1/16": 100.0,
                                   "3/1/2": 90.0, "3/1/16": 90.0}))
    eng2 = _paged(params, cost_model=cm2, spec_k=2)
    got2 = _run(eng2, [p.copy() for p in prompts], new=10)
    assert got2 == plain
    assert not _decisions(eng2, "spec_gate")
    assert eng2.metrics["spec_calls"] > 0


# ---------------------------------------------------------------------------
# determinism: identical table + traffic -> identical decision sequence
# ---------------------------------------------------------------------------

def test_decision_sequence_is_deterministic(params):
    table = _table(
        prefill={f"{t}x{r}": 50.0 * t / 16 + 10.0 * r
                 for t in (16, 32) for r in (1, 2)},
        decode={"1/1/2": 100.0, "1/2/2": 60.0, "3/1/2": 140.0,
                "1/1/16": 300.0, "1/2/16": 150.0, "3/1/16": 350.0})

    def run_once():
        eng = _paged(params, cost_model=CostModel(table), spec_k=2,
                     max_batch=2, budget=24)
        rng = np.random.default_rng(21)
        _run(eng, _mixed_prompts(rng))
        return [(e.payload["point"], e.payload["chosen"],
                 e.payload["static"]) for e in _decisions(eng)]

    first = run_once()
    second = run_once()
    assert first, "model made no decisions on mixed traffic"
    assert first == second


# ---------------------------------------------------------------------------
# the differential: model-driven == static on adversarial mixed traffic
# ---------------------------------------------------------------------------

def test_model_driven_serving_token_equal_on_mixed_traffic(params):
    """The acceptance-criteria battery: sharing + preemption (tiny pool) +
    spec_k=2 + a table that FORCES non-default choices on every axis.  The
    decision sequence differs from static; the tokens must not."""
    table = _table(
        prefill={"16x1": 100.0, "16x2": 150.0, "32x1": 400.0,
                 "32x2": 500.0, "64x1": 900.0, "64x2": 1100.0},
        decode={"1/1/2": 100.0, "1/2/2": 40.0, "3/1/2": 5000.0,
                "3/2/2": 5000.0, "1/1/16": 400.0, "1/2/16": 150.0,
                "3/1/16": 5000.0, "3/2/16": 5000.0})
    rng = np.random.default_rng(31)
    prompts = _mixed_prompts(rng)
    # num_pages small enough to force preemption under 4 requests
    kw = dict(spec_k=2, num_pages=10, max_batch=2, budget=24)
    static_eng = _paged(params, **kw)
    static = _run(static_eng, prompts)
    model_eng = _paged(params, cost_model=CostModel(table), **kw)
    modeled = _run(model_eng, prompts)
    assert modeled == static
    assert static_eng.metrics["preemptions"] > 0, \
        "workload failed to exercise preemption"
    decs = _decisions(model_eng)
    points = {d.payload["point"] for d in decs}
    # the table above forces non-static answers on the split + chunk axes
    assert "kv_splits" in points and "grant_cap" in points
    # and the engine really decoded through the modeled split closures
    assert any(s > 1 for (_, s) in model_eng._decode_fns)


@pytest.mark.slow
def test_autotuned_table_token_equal_roundtrip(params, tmp_path):
    """End-to-end: autotune (smoke) -> write -> load via cost_table ->
    serve; tokens must equal the static engine's."""
    from repro.perf.costmodel import autotune, write_table

    config = Config(model=CFG, parallel=ParallelConfig(data=1, model=1),
                    iso=ISO,
                    serving=ServingConfig(page_size=8, max_batch=2,
                                          max_len=160,
                                          prefill_token_budget=16))
    table = autotune(config, params, smoke=True)
    assert validate_table(table) == []
    path = tmp_path / "local.json"
    write_table(table, str(path))
    rng = np.random.default_rng(41)
    prompts = _mixed_prompts(rng)
    static = _run(_paged(params), prompts)
    eng = _paged(params, cost_table=str(path))
    assert eng.cost_model is not None and not _warnings(eng)
    assert _run(eng, prompts) == static

"""Layer-level unit tests: head layout, MoE dispatch, SSM/mLSTM state handoff
(chunked == full == sequential)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.config import ModelConfig, MoEConfig, SSMConfig
from repro.layers import moe as moe_lib
from repro.layers import ssm as ssm_lib
from repro.layers import xlstm as xlstm_lib
from repro.layers.heads import head_layout


# ---------------------------------------------------------------------------
# head layout (GQA padding under TP) — property-based
# ---------------------------------------------------------------------------

@given(hkv=st.integers(1, 64), group=st.integers(1, 8),
       extra=st.integers(0, 3), tp=st.sampled_from([1, 2, 4, 8, 16, 32]))
@settings(max_examples=200, deadline=None)
def test_head_layout_properties(hkv, group, extra, tp):
    hq = min(hkv * group + extra, hkv * group * 2)
    hq = max(hq, hkv)
    lo = head_layout(hq, hkv, tp)
    assert lo.hq_pad % tp == 0 and lo.hkv_eff % tp == 0
    # every logical q head appears exactly once
    logical = [h for h in lo.q_map if h >= 0]
    assert sorted(logical) == list(range(hq))
    # uniform grouping consistency (also asserted inside, re-check here)
    G = -(-hq // hkv)
    for s, h in enumerate(lo.q_map):
        if h >= 0:
            assert lo.kv_map[s // lo.group_eff] == h // G


def test_head_layout_known_cases():
    cases = {  # (hq, hkv, tp) -> (hq_pad, hkv_eff)
        (32, 8, 16): (32, 16), (25, 5, 16): (32, 16), (64, 8, 16): (64, 16),
        (24, 8, 16): (32, 16), (32, 32, 16): (32, 32), (16, 16, 16): (16, 16),
        (32, 8, 1): (32, 8), (25, 5, 1): (25, 5),
    }
    for (hq, hkv, tp), (hq_pad, hkv_eff) in cases.items():
        lo = head_layout(hq, hkv, tp)
        assert (lo.hq_pad, lo.hkv_eff) == (hq_pad, hkv_eff), (hq, hkv, tp, lo)


# ---------------------------------------------------------------------------
# MoE: expert-shard decomposition is exact; capacity drops are bounded
# ---------------------------------------------------------------------------

def test_moe_expert_parallel_decomposition(key=jax.random.PRNGKey(0)):
    mcfg = MoEConfig(num_experts=8, top_k=2, d_ff_expert=32, capacity_factor=8.0)
    p = moe_lib.init_moe(key, 32, mcfg, tp=1, num_layers=2, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    y_full, _ = moe_lib.moe_partial(p, x, mcfg, tp=1, expert_offset=0)
    acc = 0
    for s in range(4):
        p_loc = dict(p)
        for k in ("w_up", "w_gate", "w_down"):
            p_loc[k] = p[k][s * 2:(s + 1) * 2]
        ys, _ = moe_lib.moe_partial(p_loc, x, mcfg, tp=4, expert_offset=s * 2)
        acc = acc + ys
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(acc), atol=1e-5)


def test_moe_capacity_drops_tokens_not_crashes():
    mcfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=16,
                     capacity_factor=0.25)
    p = moe_lib.init_moe(jax.random.PRNGKey(0), 16, mcfg, tp=1, num_layers=1,
                         dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 16), jnp.float32)
    y, aux = moe_lib.moe_partial(p, x, mcfg, tp=1, expert_offset=0)
    assert y.shape == x.shape and not bool(jnp.any(jnp.isnan(y)))
    assert float(aux) > 0


def test_moe_padded_experts_masked():
    """Router must never select a padding expert slot."""
    mcfg = MoEConfig(num_experts=5, top_k=2, d_ff_expert=16, capacity_factor=4.0)
    e_pad = mcfg.padded_experts(4)          # 8 slots, 3 padding
    assert e_pad == 8
    p = moe_lib.init_moe(jax.random.PRNGKey(0), 16, mcfg, tp=4, num_layers=1,
                         dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16), jnp.float32)
    _, idx, _ = moe_lib.route(p["router"], x, mcfg, e_pad)
    assert int(jnp.max(idx)) < mcfg.num_experts


# ---------------------------------------------------------------------------
# SSM: chunked state handoff == full sequence == step-by-step recurrence
# ---------------------------------------------------------------------------

def test_ssm_chunk_handoff_exact():
    scfg = SSMConfig(state_dim=8, conv_dim=4, expand=2)
    p = ssm_lib.init_ssm(jax.random.PRNGKey(0), 32, scfg, tp=1, num_layers=2,
                         dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 20, 32), jnp.float32)
    y_full, st_full = ssm_lib.ssm_partial(p, x, scfg)
    y0, st0 = ssm_lib.ssm_partial(p, x[:, :8], scfg)
    y1, st1 = ssm_lib.ssm_partial(p, x[:, 8:], scfg, st0)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y0, y1], 1)),
                               np.asarray(y_full), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st1.h), np.asarray(st_full.h),
                               atol=1e-5)


def test_ssm_decode_matches_prefill_tail():
    scfg = SSMConfig(state_dim=8, conv_dim=4, expand=2)
    p = ssm_lib.init_ssm(jax.random.PRNGKey(0), 32, scfg, tp=1, num_layers=2,
                         dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 9, 32), jnp.float32)
    y_full, _ = ssm_lib.ssm_partial(p, x, scfg)
    _, st = ssm_lib.ssm_partial(p, x[:, :8], scfg)
    y_step, _ = ssm_lib.ssm_decode_partial(p, x[:, 8:9], scfg, st)
    np.testing.assert_allclose(np.asarray(y_step[:, 0]),
                               np.asarray(y_full[:, 8]), atol=1e-5)


# ---------------------------------------------------------------------------
# mLSTM: chunkwise form == explicit sequential recurrence
# ---------------------------------------------------------------------------

def _mlstm_sequential(p, x, cfg):
    """Step-by-step stabilized mLSTM recurrence (independent oracle)."""
    B, S, D = x.shape
    H = cfg.num_heads
    hd = D // H
    q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"]).astype(jnp.float32)
    k = jnp.einsum("bsd,dhk->bshk", x, p["w_k"]).astype(jnp.float32) * hd ** -0.5
    v = jnp.einsum("bsd,dhk->bshk", x, p["w_v"]).astype(jnp.float32)
    og = jax.nn.sigmoid(jnp.einsum("bsd,dhk->bshk", x, p["w_og"]).astype(jnp.float32))
    ilog = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["w_i"]) + p["i_bias"]
    flog = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["w_f"]) + p["f_bias"])
    C = jnp.zeros((B, H, hd, hd))
    n = jnp.zeros((B, H, hd))
    m = jnp.full((B, H), -1e30)
    outs = []
    for t in range(S):
        m_new = jnp.maximum(flog[:, t] + m, ilog[:, t])
        f_e = jnp.exp(flog[:, t] + m - m_new)
        i_e = jnp.exp(ilog[:, t] - m_new)
        C = f_e[..., None, None] * C + i_e[..., None, None] * \
            jnp.einsum("bhd,bhk->bhdk", k[:, t], v[:, t])
        n = f_e[..., None] * n + i_e[..., None] * k[:, t]
        m = m_new
        num = jnp.einsum("bhd,bhdk->bhk", q[:, t], C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q[:, t], n)),
                          jnp.exp(-m))
        outs.append(num / den[..., None])
    h = jnp.stack(outs, axis=1) * og
    return jnp.einsum("bshk,hkd->bsd", h, p["w_out"].astype(jnp.float32))


def test_mlstm_chunkwise_matches_sequential():
    cfg = ModelConfig(name="m", family="ssm", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=32)
    p = xlstm_lib.init_mlstm(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 32), jnp.float32)
    y_seq = _mlstm_sequential(p, x, cfg)
    y_chunk, _ = xlstm_lib.mlstm_partial(p, x, cfg, inner_chunk=4)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               atol=1e-4)


def test_mlstm_state_handoff_exact():
    cfg = ModelConfig(name="m", family="ssm", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=32)
    p = xlstm_lib.init_mlstm(jax.random.PRNGKey(0), cfg, tp=1,
                             dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32), jnp.float32)
    y_full, _ = xlstm_lib.mlstm_partial(p, x, cfg, inner_chunk=16)
    y0, st = xlstm_lib.mlstm_partial(p, x[:, :8], cfg, inner_chunk=8)
    y1, _ = xlstm_lib.mlstm_partial(p, x[:, 8:], cfg, st, inner_chunk=8)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y0, y1], 1)),
                               np.asarray(y_full), atol=1e-4)


def test_slstm_state_handoff_exact():
    cfg = ModelConfig(name="s", family="ssm", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=32)
    p = xlstm_lib.init_slstm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 32), jnp.float32)
    y_full, _ = xlstm_lib.slstm_forward(p, x, cfg)
    y0, st = xlstm_lib.slstm_forward(p, x[:, :5], cfg)
    y1, _ = xlstm_lib.slstm_forward(p, x[:, 5:], cfg, st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y0, y1], 1)),
                               np.asarray(y_full), atol=1e-5)

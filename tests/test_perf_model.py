"""Analytic performance model: paper Table-1 band reproduction + pipeline
simulator invariants."""
import pytest

from repro.config import get_model_config
from repro.perf.model import (HW_PROFILES, prefill_time, simulate_pipeline,
                              speedup_table)


def test_pipeline_sim_baseline_serialises():
    # 1 chunk, equal comp/comm: total = sum of both
    units = [(1.0, 0), (1.0, 0)]
    comms = [0.5, 0.5]
    t = simulate_pipeline(units, comms, penalty=0.0)
    assert t == pytest.approx(3.0)


def test_pipeline_sim_iso_overlaps():
    # 2 chunks: chunk1 compute hides chunk0 comm
    units = [(1.0, 0), (1.0, 1), (1.0, 0), (1.0, 1)]
    comms = [0.5] * 4
    t = simulate_pipeline(units, comms, penalty=0.0)
    assert t < 4.0 + 2.0            # strictly better than serial
    assert t == pytest.approx(4.5)  # compute-bound: only last comm exposed


def test_iso_never_slower_in_model_without_penalty():
    cfg = get_model_config("paper-70b")
    for hw in ("4090", "a800", "v5e"):
        for s in (4096, 32768):
            base = prefill_time(cfg, s, hw, 8, iso=False)
            iso = prefill_time(cfg, s, hw, 8, lengths=[s // 2, s - s // 2])
            if HW_PROFILES[hw].comm_penalty == 0:
                assert iso <= base * 1.001, (hw, s)


def test_table1_bands():
    """Paper: ~35% average reduction on 4090 (int8 comm), ~15% on A800, for
    prompts >= 4k.  The analytic model must land in those bands."""
    lengths = [4096, 8192, 16384, 32768]
    r30_4090 = speedup_table(get_model_config("paper-30b"), "4090", 4,
                             lengths, int8_comm=True)
    r70_a800 = speedup_table(get_model_config("paper-70b"), "a800", 8, lengths)
    avg_4090 = sum(r30_4090.values()) / len(r30_4090)
    avg_a800 = sum(r70_a800.values()) / len(r70_a800)
    assert 25.0 <= avg_4090 <= 50.0, r30_4090
    assert 5.0 <= avg_a800 <= 25.0, r70_a800


def test_quantized_comm_shrinks_comm_share():
    """Paper Fig 2a: int8 cuts the 4090 comm share from ~75% to ~50%."""
    from repro.perf.model import layer_costs
    cfg = get_model_config("paper-30b")
    hw = HW_PROFILES["4090"]
    fp = layer_costs(cfg, 0, 8192, hw, 4, int8_comm=False)
    q = layer_costs(cfg, 0, 8192, hw, 4, int8_comm=True)
    assert q["comm"] == pytest.approx(fp["comm"] / 2)
    share_fp = 2 * fp["comm"] / (fp["attn"] + fp["mlp"] + 2 * fp["comm"])
    share_q = 2 * q["comm"] / (q["attn"] + q["mlp"] + 2 * q["comm"])
    # paper: ~75% -> ~50% (they additionally tuned p2p; we only halve bytes)
    assert 0.68 < share_fp < 0.82, share_fp
    assert share_q < share_fp - 0.1 and share_q < 0.65, (share_fp, share_q)

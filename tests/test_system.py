"""End-to-end system behaviour: the full paper pipeline on one process —
prefill (ISO) -> serving cache -> decode -> training step -> analytic claims."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import tiny_dense, iso_cfg, ISO_OFF
from repro.config import Config, ParallelConfig, RuntimeConfig, get_model_config
from repro.core.overlap import AxisCtx
from repro.launch.mesh import local_test_mesh
from repro.models import api
from repro.perf.model import speedup_table
from repro.serving import Engine, Request
from repro.serving.requests import SamplingParams
from repro.training.data import make_training_batch
from repro.training.trainer import init_train_state, make_train_step

CTX = AxisCtx()


def test_full_pipeline_prefill_decode_train(key):
    """One model: ISO prefill == baseline, its cache decodes correctly, and the
    same stack trains."""
    cfg = tiny_dense(vocab_size=64)
    params = api.init_params(key, cfg, tp=1, dtype=jnp.float32)
    batch = api.make_inputs(cfg, 48, 2, key=key, dtype=jnp.float32)

    # 1. the paper's invariant
    base = api.prefill(params, cfg, CTX, ISO_OFF, batch, return_cache=True,
                       cache_len=64)
    iso = api.prefill(params, cfg, CTX, iso_cfg(2, min_chunk_tokens=8), batch,
                      return_cache=True, cache_len=64)
    assert float(jnp.max(jnp.abs(
        base["logits_local"] - iso["logits_local"]))) < 2e-4

    # 2. serving continuity from the ISO-built cache
    lengths = jnp.full((2,), 48, jnp.int32)
    tok = jnp.argmax(iso["logits_local"][:, -1:, :64], axis=-1).astype(jnp.int32)
    lg_iso, _ = api.decode_step(params, cfg, CTX, tok, iso["caches"], lengths)
    lg_base, _ = api.decode_step(params, cfg, CTX, tok, base["caches"], lengths)
    assert float(jnp.max(jnp.abs(lg_iso - lg_base))) < 2e-4

    # 3. the same stack trains (shared code path, not a separate model)
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    runtime=RuntimeConfig(mode="train", max_steps=10,
                                          warmup_steps=1, remat=False))
    mesh = local_test_mesh(1, 1)
    p2, opt = init_train_state(config, mesh, key, dtype=jnp.float32)
    step_fn, *_ = make_train_step(config, mesh, jax.eval_shape(lambda: p2))
    b = make_training_batch(cfg, 32, 2, 0)
    b = {k: jnp.asarray(v) for k, v in b.items()}
    with mesh:
        _, _, loss, gnorm = step_fn(p2, opt, b, jnp.int32(1))
    assert bool(jnp.isfinite(loss)) and bool(jnp.isfinite(gnorm))


def test_paper_headline_claims_hold():
    """The two numbers the paper leads with, via the calibrated model."""
    lengths = [4096, 8192, 16384, 32768]
    r4090 = speedup_table(get_model_config("paper-30b"), "4090", 4, lengths,
                          int8_comm=True)
    ra800 = speedup_table(get_model_config("paper-70b"), "a800", 8, lengths)
    assert 25 <= sum(r4090.values()) / 4 <= 50      # paper: ~35 %
    assert 5 <= sum(ra800.values()) / 4 <= 25       # paper: ~15 %


def test_engine_serves_all_assigned_family_kinds(key):
    """The engine handles a mixed queue across request kinds."""
    from conftest import tiny_vlm
    cfg = tiny_vlm(vocab_size=64)
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    iso=iso_cfg(2, min_chunk_tokens=16, chunk_align=8))
    params = api.init_params(key, cfg, tp=1, dtype=jnp.float32)
    eng = Engine(config, params, mesh=None, max_batch=2, max_len=96, bucket=16)
    rng = np.random.default_rng(0)
    for i in range(3):
        eng.add_request(Request(
            prompt=rng.integers(2, 64, 12 + i).astype(np.int32),
            patches=(rng.standard_normal((cfg.num_patches, cfg.d_model)) * 0.1
                     ).astype(np.float32),
            sampling=SamplingParams(max_new_tokens=3, eos_id=-1)))
    outs = eng.run_until_complete()
    assert len(outs) == 3 and all(len(v) == 3 for v in outs.values())

"""Per-assigned-architecture smoke tests (deliverable f): a REDUCED variant of
the same family (2-4 layers, d_model<=512, <=4 experts) runs one forward and one
train step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import (Config, ISOConfig, ParallelConfig, RuntimeConfig,
                          get_model_config, padded_vocab)
from repro.core.overlap import AxisCtx
from repro.launch.mesh import local_test_mesh
from repro.launch.train import reduce_cfg
from repro.models import api
from repro.training.data import make_training_batch
from repro.training.trainer import init_train_state, make_train_step

ASSIGNED = [
    "granite-moe-3b-a800m", "qwen3-4b", "hymba-1.5b", "kimi-k2-1t-a32b",
    "xlstm-350m", "qwen3-8b", "whisper-medium", "qwen3-32b", "internvl2-2b",
    "codeqwen1.5-7b",
]

CTX = AxisCtx()
ISO = ISOConfig(enabled=True, num_chunks=2, min_chunk_tokens=8, chunk_align=8)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_forward(arch, key):
    cfg = reduce_cfg(get_model_config(arch), "tiny")
    assert cfg.d_model <= 512 and cfg.num_layers <= 4
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = api.init_params(key, cfg, tp=1)
    S, B = 48, 2
    batch = api.make_inputs(cfg, S, B, key=key)
    out = api.prefill(params, cfg, CTX, ISO, batch, logits_mode="all")
    logits = out["logits_local"]
    exp_s = S if cfg.family != "audio" else S
    assert logits.shape == (B, exp_s, padded_vocab(cfg, 1))
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    assert out["num_chunks"] == 2          # ISO actually engaged


@pytest.mark.parametrize("arch", ["granite-moe-3b-a800m", "hymba-1.5b",
                                  "xlstm-350m", "whisper-medium",
                                  "internvl2-2b", "qwen3-4b"])
def test_reduced_train_step(arch, key):
    cfg = reduce_cfg(get_model_config(arch), "tiny")
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=1),
                    runtime=RuntimeConfig(mode="train", max_steps=10,
                                          warmup_steps=2, remat=False))
    mesh = local_test_mesh(1, 1)
    params, opt = init_train_state(config, mesh, key)
    step_fn, *_ = make_train_step(config, mesh, jax.eval_shape(lambda: params))
    b = make_training_batch(cfg, 32, 2, step=0)
    b = {k: jnp.asarray(v) for k, v in b.items()}
    import numpy as np
    before = [np.asarray(x, np.float32).copy()
              for x in jax.tree_util.tree_leaves(params)][:8]
    with mesh:
        # params/opt are DONATED by the train step — snapshot taken above
        # step=1: warmup LR at step 0 is exactly 0 (no param change by design)
        params2, opt2, loss, gnorm = step_fn(params, opt, b, jnp.int32(1))
    assert jnp.isfinite(loss) and jnp.isfinite(gnorm)
    after = [np.asarray(x, np.float32)
             for x in jax.tree_util.tree_leaves(params2)][:8]
    assert any(np.max(np.abs(a - b2)) > 0 for a, b2 in zip(before, after))


@pytest.mark.parametrize("arch", ["qwen3-4b", "hymba-1.5b", "xlstm-350m",
                                  "granite-moe-3b-a800m", "whisper-medium",
                                  "codeqwen1.5-7b"])
def test_reduced_decode_step(arch, key):
    cfg = reduce_cfg(get_model_config(arch), "tiny")
    params = api.init_params(key, cfg, tp=1)
    batch = api.make_inputs(cfg, 24, 2, key=key)
    out = api.prefill(params, cfg, CTX, ISO, batch, return_cache=True,
                      cache_len=32)
    lengths = jnp.full((2,), 24 + (cfg.num_patches if cfg.family == "vlm" else 0),
                       jnp.int32)
    tok = jnp.ones((2, 1), jnp.int32)
    logits, caches = api.decode_step(params, cfg, CTX, tok, out["caches"],
                                     lengths)
    assert logits.shape[0:2] == (2, 1)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))

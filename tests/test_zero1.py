"""ZeRO-1 optimizer sharding == replicated AdamW, on a real dp=4 x tp=2 mesh
(subprocess: the main pytest process keeps 1 device)."""
import subprocess
import sys

import pytest

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.config import Config, ModelConfig, ParallelConfig, RuntimeConfig
from repro.launch.mesh import make_mesh
from repro.training.trainer import make_train_step, init_train_state
from repro.training.data import make_training_batch

cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                  qk_norm=True)
pc = ParallelConfig(data=4, model=2)
mesh = make_mesh(pc)
key = jax.random.PRNGKey(0)

def run(zero1):
    rt = RuntimeConfig(mode="train", max_steps=20, warmup_steps=1, zero1=zero1,
                       remat=False)
    config = Config(model=cfg, parallel=pc, runtime=rt)
    params, opt = init_train_state(config, mesh, key, dtype=jnp.float32)
    step_fn, *_ = make_train_step(config, mesh, jax.eval_shape(lambda: params))
    with mesh:
        for s in range(4):
            b = make_training_batch(cfg, 32, 8, s)
            b = {k: jnp.asarray(v) for k, v in b.items()}
            params, opt, loss, gn = step_fn(params, opt, b, jnp.int32(s + 1))
    # optimizer state footprint: PER-DEVICE elements (what HBM actually holds)
    n_opt = sum(x.addressable_data(0).size
                for x in jax.tree_util.tree_leaves(opt))
    return params, n_opt

p_ref, n_ref = run(False)
p_z, n_z = run(True)
d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                        jax.tree_util.tree_leaves(p_z)))
assert d < 1e-4, d
assert n_z < n_ref / 3, (n_z, n_ref)   # state sharded ~1/dp (dp=4, + padding)
print("ZERO1_OK", d, n_ref, n_z)
"""


@pytest.mark.slow
def test_zero1_matches_replicated_adamw():
    res = subprocess.run([sys.executable, "-c", _CHILD], capture_output=True,
                         text=True, timeout=540)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "ZERO1_OK" in res.stdout

"""paper-70b — the paper's ~70B dense GQA evaluation model (Table 1)."""
from repro.config import ModelConfig, register


@register("paper-70b")
def config() -> ModelConfig:
    return ModelConfig(
        name="paper-70b",
        family="dense",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,               # GQA
        d_ff=28672,
        vocab_size=125696,
        rope_theta=1e4,
        source="paper §4.1 (70b GQA)",
    )

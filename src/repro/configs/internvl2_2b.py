"""internvl2-2b [vlm] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553;
InternViT vision encoder + projector STUBBED per the assignment carve-out
(input_specs feeds 256 pre-projected patch embeddings prepended to the text);
the InternLM2 language backbone is implemented in full.  [arXiv:2404.16821]

``long_500k`` is SKIPPED (full-attention InternLM2, no windowed variant in the
source model) — DESIGN.md §Arch-applicability.
"""
from repro.config import ModelConfig, register


@register("internvl2-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        family="vlm",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=92553,
        num_patches=256,
        rope_theta=1e6,
        source="arXiv:2404.16821",
    )

"""Assigned-architecture registry: importing this package registers every config.

10 assigned archs (public pool, citations in each file) + the paper's own two
evaluation models (30B MHA / 70B GQA dense, Table 1) + ladder-residual twins
of the dense serving configs (configs/ladder.py).
"""
from repro.configs import (  # noqa: F401
    codeqwen1_5_7b,
    granite_moe_3b_a800m,
    hymba_1_5b,
    internvl2_2b,
    kimi_k2_1t_a32b,
    paper_30b,
    paper_70b,
    qwen3_32b,
    qwen3_4b,
    qwen3_8b,
    whisper_medium,
    xlstm_350m,
)
# after the dense bases above: each ladder twin re-derives its base config
from repro.configs import ladder  # noqa: E402,F401

LADDER = ["ladder-qwen3-4b", "ladder-qwen3-8b", "ladder-paper-30b"]

ASSIGNED = [
    "granite-moe-3b-a800m", "qwen3-4b", "hymba-1.5b", "kimi-k2-1t-a32b",
    "xlstm-350m", "qwen3-8b", "whisper-medium", "qwen3-32b", "internvl2-2b",
    "codeqwen1.5-7b",
]
PAPER = ["paper-30b", "paper-70b"]

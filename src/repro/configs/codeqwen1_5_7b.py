"""codeqwen1.5-7b [dense] — 32L d_model=4096 32H (MHA kv=32) d_ff=13440
vocab=92416; qwen1.5 architecture (no qk_norm).  [hf:Qwen/CodeQwen1.5-7B]"""
from repro.config import ModelConfig, register


@register("codeqwen1.5-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        d_ff=13440,
        vocab_size=92416,
        qk_norm=False,
        rope_theta=1e6,
        source="hf:Qwen/CodeQwen1.5-7B",
    )

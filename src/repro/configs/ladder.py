"""Ladder-residual twins of registered dense configs (PAPERS.md,
arXiv 2501.06589).

Same shapes, parameter layout and head counts as the base config; only the
residual-stream wiring differs (``ModelConfig.residual_wiring="ladder"``):
stage k reads the residual as of stage k-2, so stage k-1's TP all-reduce
completes behind stage k's compute (core/iso.run_layer ``ladder=True`` for
prefill, ``run_stack_decode_ladder`` for decode).  A ladder config is a
DIFFERENT model function from its base — a train-from-scratch/adapted
architecture — so the differential battery (tests/test_ladder.py) proves
schedule-equality (deferred vs immediate collectives of the SAME ladder
function), not equality to the standard wiring.

The twin of ``ladder-<name>`` is ``<name>``: strip the prefix to recover the
standard-residual config with identical shapes.
"""
from repro.config import ladder_variant, register
from repro.configs import paper_30b, qwen3_4b, qwen3_8b


@register("ladder-qwen3-4b")
def config_ladder_qwen3_4b():
    return ladder_variant(qwen3_4b.config())


@register("ladder-qwen3-8b")
def config_ladder_qwen3_8b():
    return ladder_variant(qwen3_8b.config())


@register("ladder-paper-30b")
def config_ladder_paper_30b():
    return ladder_variant(paper_30b.config())

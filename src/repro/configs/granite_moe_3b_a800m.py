"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512(/expert)
vocab=49155, MoE 40 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base]

Assignment-line discrepancy: the spec says both "MoE 40e top-8" and "32 experts
top-8"; we use the explicit config field (40 experts) — see DESIGN.md §4.
40 % 16 != 0, so experts pad to 48 with router masking under TP=16.
"""
from repro.config import ModelConfig, MoEConfig, register


@register("granite-moe-3b-a800m")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        num_layers=32,
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,
        d_ff=0,
        vocab_size=49155,
        block_pattern=("attn_moe",),
        moe=MoEConfig(num_experts=40, top_k=8, d_ff_expert=512),
        rope_theta=1e4,
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )

"""paper-30b — the paper's ~30B dense MHA evaluation model (Table 1).

The paper (Baichuan) does not publish exact dims; this uses standard 30B-class
MHA sizing consistent with the stated "30b (MHA)".
"""
from repro.config import ModelConfig, register


@register("paper-30b")
def config() -> ModelConfig:
    return ModelConfig(
        name="paper-30b",
        family="dense",
        num_layers=48,
        d_model=6656,
        num_heads=52,
        num_kv_heads=52,              # MHA
        d_ff=17920,
        vocab_size=125696,
        rope_theta=1e4,
        source="paper §4.1 (30b MHA)",
    )

"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048(/expert)
vocab=163840, MoE 384 experts top-8 + 1 shared expert — trillion-param MoE
(paper-table scale).  [arXiv:2501.kimi2]

Experts shard 384/16 = 24 per device under TP=16 expert parallelism; the
capacity-based index dispatch (layers/moe.py) is what keeps this config's
dispatch memory bounded (the GShard one-hot would be O(T*384*C)).
"""
from repro.config import ModelConfig, MoEConfig, register


@register("kimi-k2-1t-a32b")
def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=64,
        num_kv_heads=8,
        d_ff=0,
        vocab_size=163840,
        block_pattern=("attn_moe",),
        moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048,
                      shared_expert_d_ff=2048),
        rope_theta=5e4,
        source="arXiv:2501.kimi2",
    )

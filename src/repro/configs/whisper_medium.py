"""whisper-medium [audio] — enc-dec, 24+24L d_model=1024 16H d_ff=4096
vocab=51865; conv/mel frontend STUBBED per the assignment carve-out
(input_specs feeds (B, 1500, 1024) frame embeddings).  [arXiv:2212.04356]

decode shapes exercise the decoder's serve_step (self-KV + cross-KV caches);
``long_500k`` is SKIPPED for this arch (full-attention decoder, 500k tokens is
out of distribution for the backbone) — DESIGN.md §Arch-applicability.
"""
from repro.config import ModelConfig, register


@register("whisper-medium")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="audio",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=51865,
        block_pattern=("dec_block",),
        norm_type="ln",
        mlp_type="gelu",
        pos_type="sinusoidal",
        encoder_layers=24,
        encoder_frames=1500,
        source="arXiv:2212.04356",
    )

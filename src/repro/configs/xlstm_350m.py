"""xlstm-350m [ssm] — 24L d_model=1024 4H d_ff=0 vocab=50304; sLSTM + mLSTM
blocks (7:1-ish -> 3 mLSTM : 1 sLSTM per period here).  [arXiv:2405.04517]

TP note (DESIGN.md §4): 4 heads don't shard 16 ways; the mLSTM value/output
feature dim (256/head) shards instead, so mLSTM blocks still end in the TP
all-reduce ISO overlaps.  sLSTM blocks are replicated + sequential — the recorded
ISO-inapplicable case.
"""
from repro.config import ModelConfig, register


@register("xlstm-350m")
def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m",
        family="ssm",
        num_layers=24,
        d_model=1024,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
        pos_type="none",
        source="arXiv:2405.04517",
    )

"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16; parallel attention + mamba heads per block.  [arXiv:2411.13676]

25 Q / 5 KV heads do not divide TP=16: the head-layout solver pads Q->32 slots /
KV->16 slots with exact zero-padded projections (layers/heads.py).  Hymba uses
sliding-window attention for most layers -> window=2048 here, which also makes
this arch ``long_500k``-eligible (SWA + recurrent mamba state are both O(1) per
decode step).
"""
from repro.config import ModelConfig, SSMConfig, register


@register("hymba-1.5b")
def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        d_ff=5504,
        vocab_size=32001,
        block_pattern=("hybrid",),
        ssm=SSMConfig(state_dim=16, conv_dim=4, expand=2),
        sliding_window=2048,
        rope_theta=1e4,
        source="arXiv:2411.13676",
    )

"""Paged KV-cache: fixed-size token pages, free-list allocator, block tables.

Replaces the engine's dense per-slot ``(P, B, max_len, H, hd)`` caches with a
shared pool of pages, vLLM-style: KV memory scales with the tokens actually
resident instead of ``max_batch * max_len``.  Two halves:

  * ``PageAllocator`` — pure-Python bookkeeping (free list, per-request block
    tables, committed token counts).  No JAX; unit-testable in isolation.
  * ``PagedKVCache`` — the device arrays, one (k, v) page pool per
    attention-bearing position of ``cfg.block_pattern`` (leading ``periods``
    dim, like the dense caches), plus ONE shared position pool (the token
    layout is identical across layers).  Gather/scatter helpers are pure
    functions over arrays so engine code can jit around them.

Layout per attention position:  k_pages (Pd, N+1, page_size, Hkv, hd).
Page index N is a reserved scratch page: batched-decode scatters from inactive
slots are routed there, so the update stays a single dynamic scatter with no
masking inside the kernel.
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.layers.heads import head_layout

# block kinds that own a KV cache (mirrors models/decoder.init_caches)
KV_KINDS = ("attn_mlp", "attn_moe", "hybrid", "dec_block")


class OutOfPages(RuntimeError):
    """Raised by PageAllocator when the pool cannot satisfy a request; the
    scheduler turns this into preemption-by-eviction."""


def pages_for(n_tokens: int, page_size: int) -> int:
    return -(-n_tokens // page_size)


class PageAllocator:
    """Free-list page allocator with per-request block tables.

    Invariants (asserted in tests):
      * free + allocated == num_pages, always;
      * a page belongs to at most one request (no aliasing / double-free);
      * a request's capacity ``len(table) * page_size`` always covers its
        committed token count.
    """

    def __init__(self, num_pages: int, page_size: int):
        assert num_pages > 0 and page_size > 0
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._free_set = set(self._free)
        self.tables: Dict[int, List[int]] = {}
        self.lengths: Dict[int, int] = {}

    # ---- queries ----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    def capacity(self, rid: int) -> int:
        return len(self.tables.get(rid, ())) * self.page_size

    def tokens(self, rid: int) -> int:
        return self.lengths.get(rid, 0)

    def can_fit(self, rid: int, n_tokens: int) -> bool:
        need = pages_for(n_tokens, self.page_size) - len(self.tables.get(rid, ()))
        return need <= len(self._free)

    def utilization(self) -> float:
        """Fraction of allocated page slots holding live tokens."""
        used = self.used_pages * self.page_size
        if not used:
            return 1.0
        return sum(self.lengths.values()) / used

    def fragmentation(self) -> int:
        """Allocated-but-empty token slots (tail waste of partial pages)."""
        return self.used_pages * self.page_size - sum(self.lengths.values())

    # ---- mutation ---------------------------------------------------------
    def ensure(self, rid: int, n_tokens: int) -> None:
        """Grow ``rid``'s block table so it can hold ``n_tokens`` total tokens.
        Raises OutOfPages (allocating nothing) if the pool can't cover it."""
        table = self.tables.setdefault(rid, [])
        need = pages_for(n_tokens, self.page_size) - len(table)
        if need <= 0:
            return
        if need > len(self._free):
            if not self.tables[rid]:
                del self.tables[rid]
            raise OutOfPages(f"need {need} pages, {len(self._free)} free")
        for _ in range(need):
            pg = self._free.pop()
            self._free_set.discard(pg)
            table.append(pg)

    def commit(self, rid: int, n_tokens: int) -> None:
        """Record ``n_tokens`` more live tokens for ``rid`` (capacity must
        already exist via ``ensure``)."""
        new = self.lengths.get(rid, 0) + n_tokens
        assert new <= self.capacity(rid), (rid, new, self.capacity(rid))
        self.lengths[rid] = new

    def free(self, rid: int) -> List[int]:
        """Release all of ``rid``'s pages back to the pool."""
        table = self.tables.pop(rid, [])
        self.lengths.pop(rid, None)
        for pg in table:
            assert pg not in self._free_set, f"double free of page {pg}"
            self._free.append(pg)
            self._free_set.add(pg)
        return table

    def block_table(self, rid: int, max_blocks: int) -> np.ndarray:
        """Padded (-1) block table row of static width ``max_blocks``."""
        table = self.tables.get(rid, [])
        assert len(table) <= max_blocks, (rid, len(table), max_blocks)
        row = np.full(max_blocks, -1, np.int32)
        row[:len(table)] = table
        return row

    def stats(self) -> Dict[str, Any]:
        return {"num_pages": self.num_pages, "page_size": self.page_size,
                "free_pages": self.free_pages, "used_pages": self.used_pages,
                "utilization": self.utilization(),
                "fragmentation_tokens": self.fragmentation()}


# ---------------------------------------------------------------------------
# device arrays + pure gather/scatter
# ---------------------------------------------------------------------------

def token_page_coords(positions, block_table, page_size: int, scratch: int):
    """Map absolute token positions -> (page_id, offset) through a block table.

    positions: (T,) int32; block_table: (MB,) int32 (-1 pad).  Entries whose
    block-table slot is unallocated map to the scratch page.
    """
    blk = positions // page_size
    page = jnp.where(blk < block_table.shape[0],
                     block_table[jnp.clip(blk, 0, block_table.shape[0] - 1)],
                     -1)
    page = jnp.where(page < 0, scratch, page)
    return page, positions % page_size


def gather_pages(pages: jnp.ndarray, block_tables: jnp.ndarray) -> jnp.ndarray:
    """pages (Pd, N, ps, ...), block_tables (B, MB) -> dense (Pd, B, MB*ps, ...).

    Padded (-1) table entries gather page 0 but are masked by the caller via
    ``gather_positions`` (their positions come back -1)."""
    Pd, _, ps = pages.shape[:3]
    B, MB = block_tables.shape
    g = pages[:, jnp.maximum(block_tables, 0)]      # (Pd, B, MB, ps, ...)
    return g.reshape((Pd, B, MB * ps) + pages.shape[3:])


def gather_positions(pos_pages: jnp.ndarray, block_tables: jnp.ndarray
                     ) -> jnp.ndarray:
    """pos_pages (N, ps), block_tables (B, MB) -> (B, MB*ps) int32, -1 invalid."""
    B, MB = block_tables.shape
    ps = pos_pages.shape[1]
    g = pos_pages[jnp.maximum(block_tables, 0)]     # (B, MB, ps)
    g = jnp.where((block_tables >= 0)[:, :, None], g, -1)
    return g.reshape(B, MB * ps)


class PagedKVCache:
    """Owns the page pools.  All arrays live in a dict pytree so jitted engine
    closures can take/return them wholesale."""

    def __init__(self, cfg: ModelConfig, num_pages: int, page_size: int,
                 tp: int = 1, dtype=jnp.bfloat16):
        self.cfg = cfg
        self.num_pages = num_pages            # usable pages (scratch excluded)
        self.page_size = page_size
        n = len(cfg.block_pattern)
        periods = cfg.num_layers // n
        layout = head_layout(cfg.num_heads, max(cfg.num_kv_heads, 1), tp)
        hkv = layout.hkv_eff                  # single-device engine: global view
        hd = cfg.resolved_head_dim
        self.kv_positions = tuple(i for i, kind in enumerate(cfg.block_pattern)
                                  if kind in KV_KINDS)
        k_pages, v_pages = [], []
        for i in self.kv_positions:
            k_pages.append(jnp.zeros((periods, num_pages + 1, page_size, hkv,
                                      hd), dtype))
            v_pages.append(jnp.zeros((periods, num_pages + 1, page_size, hkv,
                                      hd), dtype))
        self.arrays: Dict[str, Any] = {
            "k": tuple(k_pages), "v": tuple(v_pages),
            "pos": jnp.full((num_pages + 1, page_size), -1, jnp.int32),
        }

    @property
    def scratch_page(self) -> int:
        return self.num_pages

    def page_bytes(self) -> int:
        """KV bytes per page across all layers (k and v)."""
        return sum(2 * k[:, 0].size * k.dtype.itemsize for k in self.arrays["k"])

    def kv_bytes(self, allocator: PageAllocator) -> int:
        """Live KV footprint: bytes of pages actually allocated to requests."""
        return allocator.used_pages * self.page_bytes()

    def total_bytes(self) -> int:
        leaves = jax.tree_util.tree_leaves(self.arrays)
        return sum(l.size * l.dtype.itemsize for l in leaves)

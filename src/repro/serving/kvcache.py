"""Paged KV-cache: fixed-size token pages, free-list allocator, block tables.

Replaces the engine's dense per-slot ``(P, B, max_len, H, hd)`` caches with a
shared pool of pages, vLLM-style: KV memory scales with the tokens actually
resident instead of ``max_batch * max_len``.  Two halves:

  * ``PageAllocator`` — pure-Python bookkeeping (free list, per-request block
    tables, committed token counts).  No JAX; unit-testable in isolation.
  * ``PagedKVCache`` — the device arrays, one (k, v) page pool per
    attention-bearing position of ``cfg.block_pattern`` (leading ``periods``
    dim, like the dense caches), plus ONE shared position pool (the token
    layout is identical across layers).  Gather/scatter helpers are pure
    functions over arrays so engine code can jit around them.

Layout per attention position:  k_pages (Pd, N+1, page_size, Hkv, hd).
Page index N is a reserved scratch page: batched-decode scatters from inactive
slots are routed there, so the update stays a single dynamic scatter with no
masking inside the kernel.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.layers.heads import head_layout

# block kinds that own a KV cache (mirrors models/decoder.init_caches)
KV_KINDS = ("attn_mlp", "attn_moe", "hybrid", "dec_block")


class OutOfPages(RuntimeError):
    """Raised by PageAllocator when the pool cannot satisfy a request; the
    scheduler turns this into preemption-by-eviction."""


def pages_for(n_tokens: int, page_size: int) -> int:
    return -(-n_tokens // page_size)


class PageAllocator:
    """Free-list page allocator with per-request block tables and refcounted
    prefix/page sharing.

    Invariants (asserted in tests):
      * free + unique-allocated == num_pages, always;
      * a page's refcount equals the number of block tables referencing it
        (no aliasing beyond declared sharing, no double-free);
      * a freshly handed-out page (``ensure`` growth or ``cow`` copy target)
        comes from the free list — never a page another request still holds;
      * a request's capacity ``len(table) * page_size`` always covers its
        committed token count.
    """

    def __init__(self, num_pages: int, page_size: int, trace=None):
        assert num_pages > 0 and page_size > 0
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._free_set = set(self._free)
        self.tables: Dict[int, List[int]] = {}
        self.lengths: Dict[int, int] = {}
        self.refcount: Dict[int, int] = {}        # page -> #tables holding it
        # optional obs.TraceRing: every pool mutation narrates itself
        # (alloc/free/cow/adopt) so a trace replay can prove conservation —
        # pages_allocated - pages_freed == used_pages.  None = silent.
        self.trace = trace

    def _emit(self, kind: str, rid: int, **payload) -> None:
        if self.trace is not None:
            self.trace.emit(kind, rid=rid, free=len(self._free),
                            used=self.used_pages, **payload)

    # ---- queries ----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    def capacity(self, rid: int) -> int:
        return len(self.tables.get(rid, ())) * self.page_size

    def tokens(self, rid: int) -> int:
        return self.lengths.get(rid, 0)

    def can_fit(self, rid: int, n_tokens: int) -> bool:
        need = pages_for(n_tokens, self.page_size) - len(self.tables.get(rid, ()))
        return need <= len(self._free)

    def shared_pages(self) -> int:
        """Pages referenced by more than one block table."""
        return sum(1 for rc in self.refcount.values() if rc > 1)

    def page_shared(self, pg: int) -> bool:
        return self.refcount.get(pg, 0) > 1

    def logical_tokens(self) -> int:
        """Tokens committed across requests (counts shared pages per sharer)."""
        return sum(self.lengths.values())

    def utilization(self) -> float:
        """Committed tokens per allocated page slot.  Can exceed 1.0 when
        prefix sharing packs several requests' tokens onto one page."""
        used = self.used_pages * self.page_size
        if not used:
            return 1.0
        return sum(self.lengths.values()) / used

    def fragmentation(self) -> int:
        """Allocated-but-empty token slots (tail waste of partial pages);
        floored at 0 under sharing (shared slots count once)."""
        return max(0, self.used_pages * self.page_size
                   - sum(self.lengths.values()))

    # ---- mutation ---------------------------------------------------------
    def ensure(self, rid: int, n_tokens: int) -> None:
        """Grow ``rid``'s block table so it can hold ``n_tokens`` total tokens.
        Raises OutOfPages (allocating nothing) if the pool can't cover it."""
        table = self.tables.setdefault(rid, [])
        need = pages_for(n_tokens, self.page_size) - len(table)
        if need <= 0:
            return
        if need > len(self._free):
            if not self.tables[rid]:
                del self.tables[rid]
            raise OutOfPages(f"need {need} pages, {len(self._free)} free")
        for _ in range(need):
            pg = self._free.pop()
            self._free_set.discard(pg)
            assert self.refcount.get(pg, 0) == 0, \
                f"free list handed out live page {pg}"
            self.refcount[pg] = 1
            table.append(pg)
        self._emit("alloc", rid, n=need)

    def commit(self, rid: int, n_tokens: int) -> None:
        """Record ``n_tokens`` more live tokens for ``rid`` (capacity must
        already exist via ``ensure``)."""
        new = self.lengths.get(rid, 0) + n_tokens
        assert new <= self.capacity(rid), (rid, new, self.capacity(rid))
        self.lengths[rid] = new

    def adopt(self, rid: int, pages: List[int], n_tokens: int) -> None:
        """Map another request's prefix ``pages`` into fresh request ``rid``'s
        table (prefix sharing): refcounts bump, ``n_tokens`` are committed as
        already resident.  The donor keeps its pages; nothing is copied."""
        assert rid not in self.tables, f"adopt into non-fresh request {rid}"
        assert n_tokens <= len(pages) * self.page_size
        for pg in pages:
            assert self.refcount.get(pg, 0) > 0, f"adopting dead page {pg}"
            assert pg not in self._free_set
            self.refcount[pg] += 1
        self.tables[rid] = list(pages)
        self.lengths[rid] = n_tokens
        self._emit("adopt", rid, n_pages=len(pages), tokens=n_tokens)

    def cow(self, rid: int, block_idx: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write: give ``rid`` a private copy of a shared page before
        it writes into it.  Returns (old_page, new_page) for the device-side
        content copy, or None if the page was already exclusive.  Raises
        OutOfPages (mutating nothing) when no free page is available."""
        table = self.tables[rid]
        old = table[block_idx]
        if self.refcount.get(old, 0) <= 1:
            return None
        if not self._free:
            raise OutOfPages(f"cow of page {old}: no free pages")
        new = self._free.pop()
        self._free_set.discard(new)
        assert self.refcount.get(new, 0) == 0, \
            f"free list handed out live page {new}"
        self.refcount[new] = 1
        self.refcount[old] -= 1
        table[block_idx] = new
        # the copy target counts as an allocation for conservation (the old
        # page stays live with the other sharers)
        self._emit("alloc", rid, n=1)
        self._emit("cow", rid, old=old, new=new)
        return old, new

    def free(self, rid: int) -> List[int]:
        """Drop all of ``rid``'s page references.  Returns the pages whose
        refcount hit zero (actually released — the caller scrubs only those;
        pages still shared by another request stay live)."""
        table = self.tables.pop(rid, [])
        self.lengths.pop(rid, None)
        released = []
        rc_drops = 0
        for pg in table:
            assert pg not in self._free_set, f"double free of page {pg}"
            rc = self.refcount.get(pg, 0)
            assert rc > 0, f"freeing page {pg} with refcount 0"
            if rc == 1:
                del self.refcount[pg]
                self._free.append(pg)
                self._free_set.add(pg)
                released.append(pg)
            else:
                self.refcount[pg] = rc - 1
                rc_drops += 1
        if released:
            self._emit("free", rid, n=len(released))
        if rc_drops:
            # a sharer dropping its refcount releases nothing, so it is
            # invisible to the free/alloc conservation pair — narrate it as
            # its own (replay-neutral) event so cross-allocator accounting
            # can balance shared pages (tests/test_disagg.py)
            self._emit("rc_drop", rid, n=rc_drops)
        return released

    def import_tables(self, tables: Dict[int, List[int]],
                      lengths: Dict[int, int]) -> Dict[int, int]:
        """Adopt exported block tables into THIS pool (serving/kvstate.py
        page migration): ``tables`` reference export-local page ids; every
        distinct id gets one fresh page from the free list (so sharing
        structure among the imported requests is preserved, refcounts equal
        to the number of importing tables).  Returns the local-id -> new-page
        mapping for the device-side payload scatter.  Raises OutOfPages
        (mutating nothing) when the free list can't cover the distinct-page
        count — the disagg router's defer-and-retry path."""
        local_ids = sorted({pg for t in tables.values() for pg in t})
        for rid in tables:
            assert rid not in self.tables, f"import into live request {rid}"
            assert rid in lengths, rid
        if len(local_ids) > len(self._free):
            raise OutOfPages(f"import needs {len(local_ids)} pages, "
                             f"{len(self._free)} free")
        mapping: Dict[int, int] = {}
        for lid in local_ids:
            pg = self._free.pop()
            self._free_set.discard(pg)
            assert self.refcount.get(pg, 0) == 0, \
                f"free list handed out live page {pg}"
            mapping[lid] = pg
        for rid, t in tables.items():
            new_t = [mapping[lid] for lid in t]
            for pg in new_t:
                self.refcount[pg] = self.refcount.get(pg, 0) + 1
            self.tables[rid] = new_t
            self.lengths[rid] = lengths[rid]
            assert self.lengths[rid] <= len(new_t) * self.page_size
        self._emit("alloc", next(iter(tables), -1), n=len(local_ids))
        return mapping

    # ---- serialization ----------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Full allocator state as a plain JSON-able dict (dict keys become
        strings; ``restore`` converts back).  Free-list ORDER is preserved so
        a restored allocator hands out pages in the identical sequence."""
        return {"num_pages": self.num_pages, "page_size": self.page_size,
                "free": list(self._free),
                "tables": {str(r): list(t) for r, t in self.tables.items()},
                "lengths": {str(r): n for r, n in self.lengths.items()},
                "refcount": {str(p): rc for p, rc in self.refcount.items()}}

    def restore(self, snap: Dict[str, Any]) -> None:
        """Overwrite this allocator's state from a ``snapshot()`` dict (the
        geometry must match) and re-check the structural invariants."""
        assert snap["num_pages"] == self.num_pages, \
            (snap["num_pages"], self.num_pages)
        assert snap["page_size"] == self.page_size, \
            (snap["page_size"], self.page_size)
        self._free = [int(p) for p in snap["free"]]
        self._free_set = set(self._free)
        self.tables = {int(r): [int(p) for p in t]
                       for r, t in snap["tables"].items()}
        self.lengths = {int(r): int(n) for r, n in snap["lengths"].items()}
        self.refcount = {int(p): int(rc)
                         for p, rc in snap["refcount"].items()}
        self.check()

    def check(self) -> None:
        """Structural invariants (asserted after ``restore`` and by the
        round-trip property tests): free + unique-allocated == num_pages, a
        page's refcount equals the number of tables referencing it, no page
        is both free and referenced, and every request's committed tokens
        fit its capacity."""
        allocated = {pg for t in self.tables.values() for pg in t}
        assert not (allocated & self._free_set), \
            f"pages both free and allocated: {allocated & self._free_set}"
        assert len(self._free) == len(self._free_set), "free-list duplicates"
        assert len(self._free) + len(allocated) == self.num_pages, \
            (len(self._free), len(allocated), self.num_pages)
        refs: Dict[int, int] = {}
        for t in self.tables.values():
            for pg in t:
                refs[pg] = refs.get(pg, 0) + 1
        assert refs == self.refcount, (refs, self.refcount)
        for rid, n in self.lengths.items():
            assert n <= len(self.tables.get(rid, ())) * self.page_size, \
                (rid, n)

    def block_table(self, rid: int, max_blocks: int) -> np.ndarray:
        """Padded (-1) block table row of static width ``max_blocks``."""
        table = self.tables.get(rid, [])
        assert len(table) <= max_blocks, (rid, len(table), max_blocks)
        row = np.full(max_blocks, -1, np.int32)
        row[:len(table)] = table
        return row

    def stats(self) -> Dict[str, Any]:
        return {"num_pages": self.num_pages, "page_size": self.page_size,
                "free_pages": self.free_pages, "used_pages": self.used_pages,
                "shared_pages": self.shared_pages(),
                "logical_tokens": self.logical_tokens(),
                "utilization": self.utilization(),
                "fragmentation_tokens": self.fragmentation()}


class PrefixCache:
    """Hash index over committed prompt prefixes for page sharing.

    Every admitted request registers its prompt: one hash per page-aligned
    prefix (``tokens[:k * page_size]`` for each full page ``k``).  A new
    request looks up the LONGEST page-aligned prefix of its own prompt that
    matches a live donor (hash first, then exact token verification — hash
    collisions can suggest, never corrupt), then extends token-by-token into
    the donor's next page so a partially-matching page can be shared too
    (the engine CoWs it before the sharer's first divergent write).

    The index holds request ids, not pages: validity is re-checked against
    the allocator at lookup time, so donor eviction/free needs no eager
    invalidation — a dead donor simply stops matching.
    """

    def __init__(self, page_size: int):
        self.ps = page_size
        self._prompts: Dict[int, np.ndarray] = {}       # rid -> prompt tokens
        self._by_hash: Dict[int, List[int]] = {}        # prefix hash -> rids

    @staticmethod
    def _h(tokens: np.ndarray) -> int:
        return hash(np.asarray(tokens, np.int32).tobytes())

    def register(self, rid: int, prompt: np.ndarray) -> None:
        if rid in self._prompts:
            return                            # re-admission after preemption
        prompt = np.asarray(prompt, np.int32)
        self._prompts[rid] = prompt
        for k in range(1, len(prompt) // self.ps + 1):
            self._by_hash.setdefault(self._h(prompt[:k * self.ps]),
                                     []).append(rid)

    def forget(self, rid: int) -> None:
        prompt = self._prompts.pop(rid, None)
        if prompt is None:
            return
        for k in range(1, len(prompt) // self.ps + 1):
            h = self._h(prompt[:k * self.ps])
            rids = self._by_hash.get(h, [])
            if rid in rids:
                rids.remove(rid)
            if not rids:
                self._by_hash.pop(h, None)

    def snapshot(self) -> Dict[str, Any]:
        """Registered prompts as a JSON-able dict.  The hash index is NOT
        serialized: ``hash(bytes)`` is salted per process, so ``restore``
        rebuilds it from the prompts (re-registration is the one canonical
        index constructor — a stale serialized index could never be
        verified)."""
        return {"page_size": self.ps,
                "prompts": {str(r): [int(t) for t in p]
                            for r, p in self._prompts.items()}}

    def restore(self, snap: Dict[str, Any]) -> None:
        assert snap["page_size"] == self.ps, (snap["page_size"], self.ps)
        self._prompts = {}
        self._by_hash = {}
        for r, toks in snap["prompts"].items():
            self.register(int(r), np.asarray(toks, np.int32))

    def lookup(self, prompt: np.ndarray, alloc: "PageAllocator",
               exclude: int = -1):
        """Best live donor for ``prompt``.  Returns (donor_rid, shared_tokens,
        shared_pages) or None.  ``shared_tokens`` is capped at
        ``len(prompt) - 1`` so the sharer always prefills at least one token
        (it needs last-position logits to sample)."""
        prompt = np.asarray(prompt, np.int32)
        ps = self.ps
        for k in range(len(prompt) // ps, 0, -1):
            for rid in self._by_hash.get(self._h(prompt[:k * ps]), ()):
                if rid == exclude or rid not in alloc.tables:
                    continue
                donor = self._prompts.get(rid)
                if donor is None or len(donor) < k * ps or \
                        not np.array_equal(donor[:k * ps], prompt[:k * ps]):
                    continue
                if alloc.tokens(rid) < k * ps or \
                        len(alloc.tables[rid]) < k:
                    continue                  # donor hasn't prefilled this far
                # extend token-wise into the donor's page k (partial share)
                limit = min(len(prompt) - 1, len(donor), alloc.tokens(rid),
                            len(alloc.tables[rid]) * ps)
                t = k * ps
                while t < limit and donor[t] == prompt[t]:
                    t += 1
                t = min(t, len(prompt) - 1)
                if t <= 0:
                    continue
                n_pages = pages_for(t, ps)
                return rid, t, list(alloc.tables[rid][:n_pages])
        return None


# ---------------------------------------------------------------------------
# device arrays + pure gather/scatter
# ---------------------------------------------------------------------------

def token_page_coords(positions, block_table, page_size: int, scratch: int):
    """Map absolute token positions -> (page_id, offset) through a block table.

    positions: (T,) int32; block_table: (MB,) int32 (-1 pad).  Entries whose
    block-table slot is unallocated map to the scratch page.
    """
    blk = positions // page_size
    page = jnp.where(blk < block_table.shape[0],
                     block_table[jnp.clip(blk, 0, block_table.shape[0] - 1)],
                     -1)
    page = jnp.where(page < 0, scratch, page)
    return page, positions % page_size


def window_page_coords(lengths, block_tables, k_tokens: int, page_size: int,
                       scratch: int, decode_mask=None):
    """Map a K-token decode window's positions -> (page, off, ok, positions)
    through per-request block tables (the batched sibling of
    ``token_page_coords``; shared by core/iso's KV scatter and the paged
    engine's pos-array update so their validity rules cannot drift).

    lengths: (B,) int32; block_tables: (B, MB) int32 (-1 pad); window token
    qi sits at position ``lengths[b] + qi``; ``decode_mask``: optional (B,)
    bool of slots really decoding.  All returns are (B, K): ``ok`` marks
    positions landing in a live page of an active slot — everything else has
    ``page`` already routed to ``scratch`` (and must record pos -1).
    """
    positions = (lengths[:, None].astype(jnp.int32)
                 + jnp.arange(k_tokens, dtype=jnp.int32)[None])
    blk = positions // page_size
    MB = block_tables.shape[1]
    page = jnp.take_along_axis(block_tables, jnp.clip(blk, 0, MB - 1), axis=1)
    ok = (page >= 0) & (blk < MB)
    if decode_mask is not None:
        ok &= decode_mask[:, None]
    page = jnp.where(ok, page, scratch)
    return page, positions % page_size, ok, positions


def gather_pages(pages: jnp.ndarray, block_tables: jnp.ndarray) -> jnp.ndarray:
    """pages (Pd, N, ps, ...), block_tables (B, MB) -> dense (Pd, B, MB*ps, ...).

    Padded (-1) table entries gather page 0 but are masked by the caller via
    ``gather_positions`` (their positions come back -1)."""
    Pd, _, ps = pages.shape[:3]
    B, MB = block_tables.shape
    g = pages[:, jnp.maximum(block_tables, 0)]      # (Pd, B, MB, ps, ...)
    return g.reshape((Pd, B, MB * ps) + pages.shape[3:])


def gather_positions(pos_pages: jnp.ndarray, block_tables: jnp.ndarray
                     ) -> jnp.ndarray:
    """pos_pages (N, ps), block_tables (B, MB) -> (B, MB*ps) int32, -1 invalid."""
    B, MB = block_tables.shape
    ps = pos_pages.shape[1]
    g = pos_pages[jnp.maximum(block_tables, 0)]     # (B, MB, ps)
    g = jnp.where((block_tables >= 0)[:, :, None], g, -1)
    return g.reshape(B, MB * ps)


class PagedKVCache:
    """Owns the page pools.  All arrays live in a dict pytree so jitted engine
    closures can take/return them wholesale."""

    def __init__(self, cfg: ModelConfig, num_pages: int, page_size: int,
                 tp: int = 1, dtype=jnp.bfloat16):
        self.cfg = cfg
        self.num_pages = num_pages            # usable pages (scratch excluded)
        self.page_size = page_size
        n = len(cfg.block_pattern)
        periods = cfg.num_layers // n
        layout = head_layout(cfg.num_heads, max(cfg.num_kv_heads, 1), tp)
        hkv = layout.hkv_eff                  # single-device engine: global view
        hd = cfg.resolved_head_dim
        self.kv_positions = tuple(i for i, kind in enumerate(cfg.block_pattern)
                                  if kind in KV_KINDS)
        k_pages, v_pages = [], []
        for i in self.kv_positions:
            k_pages.append(jnp.zeros((periods, num_pages + 1, page_size, hkv,
                                      hd), dtype))
            v_pages.append(jnp.zeros((periods, num_pages + 1, page_size, hkv,
                                      hd), dtype))
        self.arrays: Dict[str, Any] = {
            "k": tuple(k_pages), "v": tuple(v_pages),
            "pos": jnp.full((num_pages + 1, page_size), -1, jnp.int32),
        }

    @property
    def scratch_page(self) -> int:
        return self.num_pages

    def page_bytes(self) -> int:
        """KV bytes per page across all layers (k and v)."""
        return sum(2 * k[:, 0].size * k.dtype.itemsize for k in self.arrays["k"])

    def kv_bytes(self, allocator: PageAllocator) -> int:
        """Live KV footprint: bytes of pages actually allocated to requests."""
        return allocator.used_pages * self.page_bytes()

    def total_bytes(self) -> int:
        leaves = jax.tree_util.tree_leaves(self.arrays)
        return sum(l.size * l.dtype.itemsize for l in leaves)

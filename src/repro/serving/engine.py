"""Serving engine: continuous batching with ISO prefill.

The paper's serving shape: prefill runs per-request (batch 1 — Table 1's setting)
under the ISO schedule; decode runs batched over all active slots with the plain
schedule (paper: overlap doesn't pay at decode).  Prompt lengths are bucketed to
bound recompilation; padded tail slots are scrubbed from the KV cache position
array so decode masking stays exact.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import Config
from repro.launch import runner
from repro.models import api
from repro.obs import jaxprof
from repro.obs.registry import (ACCEPT_LEN_BUCKETS, MetricsRegistry,
                                TPOT_BUCKETS_S, TTFT_BUCKETS_S)
from repro.obs.trace import TraceRing
from repro.serving.requests import Request, RequestState
from repro.serving.sampler import sample


def _bucket(n: int, b: int) -> int:
    return max(b, ((n + b - 1) // b) * b)


class Engine:
    def __init__(self, config: Config, params, mesh=None, *, max_batch: int = 4,
                 max_len: int = 512, bucket: int = 64, spec_k: int = 0,
                 observability: bool = True):
        self.config = config
        self.cfg = config.model
        self.params = params
        self.mesh = mesh
        self.max_batch = max_batch
        self.max_len = max_len
        self.bucket = bucket
        self.tp = config.parallel.model if mesh is not None else 1

        self._params_shape = jax.eval_shape(lambda: params)
        self._prefill_fns: Dict[Tuple[int, bool], Any] = {}
        self._decode_fn = None

        cache_dtype = jax.tree_util.tree_leaves(params)[0].dtype
        self.caches = api.init_caches(self.cfg, max_batch, max_len, self.tp,
                                      dtype=cache_dtype)
        self.slots: List[Optional[RequestState]] = [None] * max_batch
        self.lengths = np.zeros(max_batch, np.int64)
        self.last_tokens = np.zeros(max_batch, np.int64)
        self.pending: List[Request] = []
        self._finished: List[RequestState] = []
        # speculative decoding (paper §Discussion): greedy-only self-drafting.
        # Attention-only stacks, like PagedEngine: the verify scrub rolls back
        # rejected KV positions, but a K-token step would have advanced
        # recurrent SSM/xLSTM state (and whisper's decode) K times with no way
        # back — so those families silently fall back to plain decode
        self.spec_k = spec_k if all(k in ("attn_mlp", "attn_moe")
                                    for k in self.cfg.block_pattern) else 0
        self._drafts: List[Optional[Any]] = [None] * max_batch
        # observability parity with PagedEngine (src/repro/obs): same
        # registry-backed counter names (plus dense-only spec_accepted) so
        # differential tests can assert metric equality, not just tokens.
        # preemptions is registered and stays 0 — the dense engine never
        # evicts — precisely so cross-engine metric diffs are key-aligned.
        self.registry = MetricsRegistry()
        self.trace = TraceRing(enabled=observability)
        self.registry.histogram("ttft", TTFT_BUCKETS_S)
        self.registry.histogram("tpot", TPOT_BUCKETS_S)
        self.registry.histogram("accept_len", ACCEPT_LEN_BUCKETS)
        self.registry.counters((
            "prefill_s", "decode_s", "prefill_dispatch_s",
            "decode_dispatch_s", "prefill_tokens", "decode_tokens",
            "completed", "decode_calls", "prefill_calls", "steps",
            "preemptions", "ttft_sum", "ttft_n", "spec_accepted",
            "spec_calls", "spec_tokens", "prefill_samples"))
        self.metrics = self.registry.view()
        self._t_submit: Dict[int, float] = {}

    # ------------------------------------------------------------------
    def add_request(self, req: Request) -> int:
        self._t_submit[req.rid] = time.perf_counter()
        self.pending.append(req)
        return req.rid

    def _get_prefill(self, plen: int, batch: Dict[str, Any]):
        key = (plen, "frames" in batch, "patches" in batch)
        if key not in self._prefill_fns:
            build = runner.make_prefill_fn(
                self.config, self.mesh, self._params_shape, logits_mode="all",
                return_cache=True, cache_len=self.max_len, global_batch=1) \
                if self.mesh is not None else None
            if self.mesh is not None:
                self._prefill_fns[key] = build(batch)
            else:
                from repro.core.overlap import AxisCtx
                ctx = AxisCtx()
                fn = jax.jit(lambda p, b: api.prefill(
                    p, self.cfg, ctx, self.config.iso, b, logits_mode="all",
                    return_cache=True, cache_len=self.max_len))
                self._prefill_fns[key] = fn
        return self._prefill_fns[key]

    def _get_decode(self):
        if self._decode_fn is None:
            if self.mesh is not None:
                cshape = jax.eval_shape(lambda: self.caches)
                self._decode_fn = runner.make_decode_fn(
                    self.config, self.mesh, self._params_shape, cshape,
                    global_batch=self.max_batch)
            else:
                from repro.core.overlap import AxisCtx
                ctx = AxisCtx()
                self._decode_fn = jax.jit(lambda p, t, c, l: api.decode_step(
                    p, self.cfg, ctx, t, c, l))
        return self._decode_fn

    # ------------------------------------------------------------------
    def _start_request(self, req: Request, slot: int) -> None:
        plen = len(req.prompt)
        blen = min(_bucket(plen, self.bucket), self.max_len)
        toks = np.zeros((1, blen), np.int32)
        toks[0, :plen] = req.prompt
        batch: Dict[str, Any] = {"tokens": jnp.asarray(toks)}
        if req.frames is not None:
            batch["frames"] = jnp.asarray(req.frames)[None]
        if req.patches is not None:
            batch["patches"] = jnp.asarray(req.patches)[None]

        t0 = time.perf_counter()
        with jaxprof.annotate(f"prefill/T={blen}"):
            out = self._get_prefill(blen, batch)(self.params, batch)
        # fence the WHOLE output (caches included) inside the timed region;
        # logits alone can land before the KV write-back finishes
        self.metrics["prefill_dispatch_s"] += time.perf_counter() - t0
        jax.block_until_ready(out)
        dur = time.perf_counter() - t0
        self.metrics["prefill_s"] += dur
        self.metrics["prefill_tokens"] += plen
        self.metrics["prefill_calls"] += 1
        self.trace.emit("prefill_call", rid=req.rid, slot=slot, dur=dur,
                        ts=t0, tokens=plen, pad=blen - plen, rows=1)

        extra = out["caches"]
        # effective prompt length in the decoder stream (vlm prepends patches)
        eff_plen = plen + (req.patches.shape[0] if req.patches is not None else 0)
        eff_blen = blen + (req.patches.shape[0] if req.patches is not None else 0)
        self._write_slot(extra, slot, eff_plen)
        logits = np.asarray(jax.device_get(out["logits_local"]))[0]
        # sample over the REAL vocab only (the table is padded for TP sharding)
        first = sample(logits[eff_plen - 1][:self.cfg.vocab_size], req.sampling,
                       step=0)
        self.metrics["prefill_samples"] += 1
        ttft = time.perf_counter() - self._t_submit.pop(req.rid,
                                                        time.perf_counter())
        self.metrics["ttft_sum"] += ttft
        self.metrics["ttft_n"] += 1
        self.registry.histogram("ttft").observe(ttft)
        self.trace.emit("sample", rid=req.rid, slot=slot, first=True)

        st = RequestState(request=req, slot=slot, prompt_len=eff_plen)
        st.generated.append(first)
        st.finish_check()
        self.lengths[slot] = eff_plen
        self.last_tokens[slot] = first
        if self.spec_k:
            from repro.serving.speculative import BigramDraft
            d = BigramDraft()
            d.observe([int(t) for t in req.prompt] + [first])
            self._drafts[slot] = d
        if st.done:
            self.metrics["completed"] += 1
            self.trace.emit("finish", rid=req.rid, slot=slot)
            self._finished.append(st)
            self._clear_slot(slot)
        else:
            self.slots[slot] = st

    def _clear_slot(self, slot: int) -> None:
        """Drop ALL per-slot state when a request leaves.  Leaving stale
        ``lengths``/``last_tokens``/``_drafts`` behind is not cosmetic: the
        speculative gate reads ``max(self.lengths)``, so one finished long
        request would silently disable speculation for the rest of the
        batch's lifetime."""
        self.slots[slot] = None
        self.lengths[slot] = 0
        self.last_tokens[slot] = 0
        self._drafts[slot] = None

    def _write_slot(self, new_caches, slot: int, real_len: int) -> None:
        """Scatter a batch-1 prefill cache into the engine's slot, scrubbing
        padded positions (pos >= real_len -> empty)."""
        def put(big, small):
            if small.ndim >= 2 and small.shape[1] == 1:   # (P,1,...) batch dim
                return big.at[:, slot].set(small[:, 0].astype(big.dtype))
            return big

        def scrub(leaf_big, leaf_new):
            merged = put(leaf_big, leaf_new)
            return merged

        merged = jax.tree_util.tree_map(scrub, self.caches, new_caches)
        # scrub pos arrays
        fixed = []
        for c in merged:
            c = dict(c)
            if "pos" in c:
                pos = c["pos"]
                c["pos"] = pos.at[:, slot].set(
                    jnp.where(pos[:, slot] < real_len, pos[:, slot], -1))
            fixed.append(c)
        self.caches = tuple(fixed)

    # ------------------------------------------------------------------
    def step(self) -> List[Tuple[int, int]]:
        """One engine iteration; returns (rid, token) events."""
        events: List[Tuple[int, int]] = []
        self.metrics["steps"] += 1
        # admission: start pending requests on free slots (prefill, batch=1)
        for i in range(self.max_batch):
            if self.slots[i] is None and self.pending:
                req = self.pending.pop(0)
                self.trace.emit("admit", rid=req.rid, slot=i)
                self._start_request(req, i)
                st = [s for s in ([self.slots[i]] + self._finished)
                      if s and s.request.rid == req.rid]
                if st:
                    events.append((req.rid, st[0].generated[-1]))

        active = [s for s in self.slots if s is not None]
        if not active:
            return events
        if self.spec_k and all(s.request.sampling.temperature <= 0
                               for s in active) and \
                max(self.lengths) + self.spec_k + 1 <= self.max_len:
            return events + self._step_speculative(active)

        toks = jnp.asarray(self.last_tokens[:, None].astype(np.int32))
        lens = jnp.asarray(self.lengths.astype(np.int32))
        t0 = time.perf_counter()
        with jaxprof.annotate("decode/K=1"):
            logits, self.caches = self._get_decode()(self.params, toks,
                                                     self.caches, lens)
        # fence logits AND the updated caches inside the timed region —
        # decode_s is execution time, decode_dispatch_s the async view
        self.metrics["decode_dispatch_s"] += time.perf_counter() - t0
        jax.block_until_ready((logits, self.caches))
        dur = time.perf_counter() - t0
        logits = np.asarray(jax.device_get(logits))
        self.metrics["decode_s"] += dur
        self.metrics["decode_calls"] += 1
        self.trace.emit("decode_call", dur=dur, ts=t0, k=1, active=len(active))

        for st in active:
            i = st.slot
            tok = sample(logits[i, 0][:self.cfg.vocab_size], st.request.sampling,
                         len(st.generated))
            st.generated.append(tok)
            self.lengths[i] += 1
            self.last_tokens[i] = tok
            if self._drafts[i] is not None:
                # plain steps (speculative gate closed) must still feed the
                # draft, or it re-engages with a stale anchor
                self._drafts[i].observe([tok])
            self.metrics["decode_tokens"] += 1
            self.trace.emit("accept", rid=st.request.rid, slot=i, n=1,
                            spec=False)
            self.registry.histogram("tpot").observe(dur)
            events.append((st.request.rid, tok))
            st.finish_check()
            if st.done:
                self.metrics["completed"] += 1
                self.trace.emit("finish", rid=st.request.rid, slot=i)
                self._finished.append(st)
                self._clear_slot(i)
        return events

    # ------------------------------------------------------------------
    def _get_spec_decode(self, K: int):
        key = ("spec", K)
        if key not in self._prefill_fns:
            from repro.core.overlap import AxisCtx
            ctx = AxisCtx()
            self._prefill_fns[key] = jax.jit(
                lambda p, t, c, l: api.decode_step(p, self.cfg, ctx, t, c, l))
        return self._prefill_fns[key]

    def _step_speculative(self, active) -> List[Tuple[int, int]]:
        """Verify a K-token window [last, d1..d_{K-1}] per slot; accept the
        longest greedy-matching prefix (paper §Discussion direction)."""
        from repro.serving.speculative import accept_greedy
        K = self.spec_k + 1
        B = self.max_batch
        toks = np.zeros((B, K), np.int32)
        drafts: Dict[int, List[int]] = {}
        for st in active:
            i = st.slot
            d = self._drafts[i].draft(self.spec_k)
            drafts[i] = d
            toks[i] = [self.last_tokens[i]] + d
        lens = jnp.asarray(self.lengths.astype(np.int32))
        t0 = time.perf_counter()
        with jaxprof.annotate(f"decode/K={K}"):
            logits, self.caches = self._get_spec_decode(K)(
                self.params, jnp.asarray(toks), self.caches, lens)
        self.metrics["decode_dispatch_s"] += time.perf_counter() - t0
        jax.block_until_ready((logits, self.caches))
        dur = time.perf_counter() - t0
        logits = np.asarray(jax.device_get(logits))
        self.metrics["decode_s"] += dur
        self.metrics["decode_calls"] += 1
        self.metrics["spec_calls"] += 1
        self.trace.emit("decode_call", dur=dur, ts=t0, k=K, active=len(active))

        events: List[Tuple[int, int]] = []
        new_lens = self.lengths.copy()
        for st in active:
            i = st.slot
            argmaxes = logits[i, :, :self.cfg.vocab_size].argmax(axis=-1)
            budget = st.request.sampling.max_new_tokens - len(st.generated)
            acc = accept_greedy(drafts[i], argmaxes)[:max(budget, 1)]
            self.metrics["spec_accepted"] += len(acc) - 1
            self.metrics["spec_tokens"] += len(acc)
            self.metrics["decode_tokens"] += len(acc)
            self.registry.histogram("accept_len").observe(len(acc))
            self.registry.histogram("tpot").observe(dur / len(acc))
            self.trace.emit("accept", rid=st.request.rid, slot=i, n=len(acc),
                            spec=True)
            for tok in acc:
                st.generated.append(int(tok))
                events.append((st.request.rid, int(tok)))
            self._drafts[i].observe(acc)
            new_lens[i] = self.lengths[i] + len(acc)
            self.last_tokens[i] = acc[-1]
            st.finish_check()
            if st.done:
                self.metrics["completed"] += 1
                self.trace.emit("finish", rid=st.request.rid, slot=i)
                self._finished.append(st)
                self._clear_slot(i)
                # self.lengths is replaced wholesale below — zero the slot in
                # new_lens too, so the scrub invalidates the whole slot's pos
                # and the speculative gate stops reading the stale length
                new_lens[i] = 0
        # scrub cache slots of rejected draft tokens (pos >= confirmed length)
        nl = jnp.asarray(new_lens.astype(np.int32))
        fixed = []
        for c in self.caches:
            c = dict(c)
            if "pos" in c:
                c["pos"] = jnp.where(c["pos"] >= nl[None, :, None], -1, c["pos"])
            fixed.append(c)
        self.caches = tuple(fixed)
        self.lengths = new_lens
        return events

    def run_until_complete(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            self.step()
            if not self.pending and all(s is None for s in self.slots):
                break
        for st in self._finished:
            out[st.request.rid] = st.generated
        return out

"""Self-speculative decoding (paper §Discussion: "speculative sampling involves
a greater number of input tokens, thereby increasing the relative computational
volume" — i.e. it moves decode toward the regime where ISO-style overlap pays).

Draft model: a per-request bigram ("last token -> most recent successor") table
built online from the prompt + generated stream — zero extra model weights, the
cheapest honest draft.  Verify: one K-token decode step — the generalized
``attn_decode_partial`` on the dense Engine, the K-token flash-decode kernel
(``attn_decode_paged_partial``) on the PagedEngine; greedy acceptance of the
longest matching prefix yields 1..K tokens per model call.  The paged engine
commits only accepted tokens to the allocator and rolls rejected window
positions back by invalidating their ``pos`` entries (serving/paged_engine.py).
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np


class BigramDraft:
    def __init__(self):
        self.table: Dict[int, int] = {}
        self.last: int = -1

    def observe(self, tokens: Sequence[int]) -> None:
        prev = self.last
        for t in tokens:
            if prev >= 0:
                self.table[prev] = int(t)
            prev = int(t)
        self.last = prev

    def draft(self, k: int) -> List[int]:
        out, cur = [], self.last
        for _ in range(k):
            cur = self.table.get(cur, cur if cur >= 0 else 0)
            out.append(int(cur))
        return out


def accept_greedy(draft: List[int], argmaxes: np.ndarray) -> List[int]:
    """argmaxes[i] = greedy model prediction AFTER consuming position i of the
    [last, d1..d_{K-1}] verify window.  Returns the accepted new tokens
    (>= 1: the paper's guarantee — worst case degenerates to plain decode)."""
    out = []
    for i, d in enumerate(draft):
        model_tok = int(argmaxes[i])
        out.append(model_tok)
        if model_tok != d:
            break
    else:
        # every draft token accepted: bank the model's bonus prediction too
        out.append(int(argmaxes[len(draft)]))
    return out

from repro.serving.engine import Engine  # noqa: F401
from repro.serving.requests import Request, RequestState  # noqa: F401

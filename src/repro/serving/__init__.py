from repro.serving.disagg import DisaggRouter, PageTransfer  # noqa: F401
from repro.serving.engine import Engine  # noqa: F401
from repro.serving.kvcache import PageAllocator, PagedKVCache  # noqa: F401
from repro.serving.kvstate import KVPool  # noqa: F401
from repro.serving.paged_engine import PagedEngine  # noqa: F401
from repro.serving.requests import Request, RequestState  # noqa: F401
from repro.serving.scheduler import TokenBudgetScheduler  # noqa: F401

"""Disaggregated prefill/decode serving: two engines, KV pages migrate.

Prefill and decode have opposite resource shapes — prefill is compute-bound
(the ISO chunk schedule overlaps its collectives), decode is memory-bound
(the paged cache walk) — so serving them from ONE engine makes each phase
inherit the other's batching compromises.  This module splits them:

  * a ``phase="prefill"`` ``PagedEngine`` admits requests and runs chunked
    prefill ONLY (its scheduler never plans a decode step);
  * a ``phase="decode"`` ``PagedEngine`` decodes ONLY (it never admits — its
    requests arrive by ``attach_requests``);
  * the ``DisaggRouter`` moves each request between them the moment its
    prompt is fully resident: ``PagedEngine.detach_requests`` exports the KV
    pages + lifecycle state as a ``PageTransfer`` (host arrays + plain
    records — nothing engine- or mesh-local), and ``attach_requests``
    re-adopts it into the decode pool at remapped page ids.

Token streams are BYTE-IDENTICAL to single-engine serving: sampling is a pure
function of (seed, step index), prefill/decode math is row-independent, and
migration copies committed KV verbatim — the differential battery in
tests/test_disagg.py pins equality under prefix sharing, preemption,
speculation and batched prefill simultaneously.

Flow control: when the decode pool cannot host the next migration (no free
slot, or fewer free pages than the transfer's distinct pages) the request
simply STAYS on the prefill engine — admitted, fully prefilled, holding its
pages — until decode-side completions free room.  A transfer that was already
detached and then fails to attach (``OutOfPages`` is atomic — nothing is
mutated) queues host-side and retries with bounded backoff.  Neither path
preempts a decode-resident request, loses tokens, or raises.  Decode-side
preemption victims (pool pressure from growing decode windows) bounce BACK to
the prefill engine in recompute mode — the same prompt+generated re-prefill a
single-engine preemption does.  See docs/serving.md.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Tuple

from repro.config import Config, ServingConfig
from repro.serving.kvcache import OutOfPages, pages_for
from repro.serving.paged_engine import PagedEngine
from repro.serving.requests import Request


@dataclass
class RequestRecord:
    """One request's engine-external lifecycle state — everything the decode
    engine needs to continue the stream exactly where prefill left it."""
    request: Request
    generated: List[int]              # tokens sampled so far (>= 1 on migrate)
    prompt_len: int                   # effective prompt length (text+patches)
    prefilled: int                    # prompt tokens committed to KV
    chunk_plan: Tuple[int, ...]
    t_submit: float                   # TTFT/TPOT stamps travel with the
    t_first: float                    # request (TTFT is a prefill-side event)
    last_token: int                   # next decode input (not yet in KV)
    draft_table: Optional[Dict[int, int]]   # speculative self-draft state —
    draft_last: int                         # without it, spec streams diverge


@dataclass
class PageTransfer:
    """The migration message: lifecycle records + the ``KVPool.export_pages``
    blob (numpy payloads, export-local page ids).  Pure host state."""
    records: List[RequestRecord]
    blob: Dict[str, Any] = field(repr=False)

    @property
    def n_pages(self) -> int:
        return self.blob["n_pages"]

    @property
    def rids(self) -> List[int]:
        return [r.request.rid for r in self.records]


class DisaggRouter:
    """One prefill engine + one decode engine + the migration loop.

    Single-process, two (optional) meshes — the transport is host memory, but
    the ``PageTransfer`` payload is already serialization-shaped, so a
    multi-host transport only swaps the hand-off, not the protocol.
    """

    # consecutive failed attach retries double the cooldown up to this many
    # router steps — bounded backoff, never preemption
    MAX_BACKOFF_STEPS = 8

    def __init__(self, config: Config, params, *,
                 serving: ServingConfig = None,
                 prefill_mesh=None, decode_mesh=None):
        sv = serving or config.serving
        assert all(k in ("attn_mlp", "attn_moe")
                   for k in config.model.block_pattern), \
            "disagg migrates KV pages only; recurrent per-slot state " \
            "(SSM/xLSTM) does not transfer"
        self.sv = sv
        dec_sv = sv if not sv.decode_pool_pages else \
            replace(sv, num_pages=sv.decode_pool_pages)
        self.prefill = PagedEngine(config, params, serving=sv,
                                   mesh=prefill_mesh, phase="prefill")
        self.decode = PagedEngine(config, params, serving=dec_sv,
                                  mesh=decode_mesh, phase="decode")
        self.migrate_batch = sv.migrate_batch
        self._pending: List[PageTransfer] = []    # detached, attach deferred
        self._cooldown = 0
        self._defers = 0
        self.stats = {"migrations": 0, "migrated_requests": 0,
                      "deferrals": 0, "bounce_backs": 0}

    # ------------------------------------------------------------------
    def add_request(self, req: Request) -> int:
        """Admit to the prefill engine — after validating that the request
        can EVER live in the decode pool too (the prefill engine only checks
        its own pool; a request too big for the decode side would admit,
        prefill, then wedge the migration queue forever)."""
        eff = len(req.prompt) + \
            (req.patches.shape[0] if req.patches is not None else 0)
        need = pages_for(eff + req.sampling.max_new_tokens, self.decode.ps)
        if need > self.decode.alloc.num_pages:
            raise ValueError(
                f"request {req.rid}: needs {need} pages but the decode pool "
                f"has {self.decode.alloc.num_pages} (raise "
                f"ServingConfig.decode_pool_pages)")
        return self.prefill.add_request(req)

    # ------------------------------------------------------------------
    def step(self) -> List[Tuple[int, int]]:
        """One router iteration: prefill step -> migrate ready requests ->
        decode step -> bounce eviction victims back.  Returns the merged
        (rid, token) events of both engines."""
        events = self.prefill.step()
        self._retry_pending()
        self._migrate()
        events += self.decode.step()
        self._bounce_back()
        return events

    def done(self) -> bool:
        return (not self._pending
                and not self.prefill.scheduler.waiting
                and all(s is None for s in self.prefill.slots)
                and not self.decode.scheduler.waiting
                and all(s is None for s in self.decode.slots))

    def run_until_complete(self, max_steps: int = 10_000
                           ) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            self.step()
            if self.done():
                break
        for st in self.prefill._finished + self.decode._finished:
            out[st.request.rid] = st.generated
        return out

    # ------------------------------------------------------------------
    # migration
    # ------------------------------------------------------------------
    def _migrate(self) -> None:
        """Move every decode-ready request the decode pool can host NOW.

        Candidates — fully prefilled, holding their first sampled token, not
        finished — are taken in the prefill scheduler's policy order (so
        priority traffic migrates first and attach-side arrival order matches
        admission semantics), capped at ``migrate_batch`` per step (0 = all).
        The prefix that fits is computed against the decode side's free slots
        and free pages MINUS what already-deferred transfers will consume;
        what doesn't fit stays resident on the prefill engine — no detach
        without a home."""
        ready = [s for s in self.prefill.slots
                 if s is not None and not s.done and s.generated
                 and s.prefilled >= sum(s.chunk_plan)]
        if not ready:
            return
        rids = self.prefill.scheduler.order([s.request.rid for s in ready])
        if self.migrate_batch > 0:
            rids = rids[:self.migrate_batch]
        free_slots = sum(1 for s in self.decode.slots if s is None) \
            - sum(len(t.records) for t in self._pending)
        free_pages = self.decode.alloc.free_pages \
            - sum(t.n_pages for t in self._pending)
        take: List[int] = []
        pages: set = set()
        for rid in rids:
            grown = pages | set(self.prefill.alloc.tables[rid])
            if len(take) + 1 > free_slots or len(grown) > free_pages:
                break                 # decode pool full: the rest stays put
            take.append(rid)
            pages = grown
        if not take:
            if rids:
                self.stats["deferrals"] += 1
            return
        transfer = self.prefill.detach_requests(take)
        try:
            self.decode.attach_requests(transfer)
        except OutOfPages:
            # can only race the capacity check via deferred-transfer retries;
            # atomic — queue host-side and retry, never preempt
            self._note_defer()
            self._pending.append(transfer)
            return
        self.stats["migrations"] += 1
        self.stats["migrated_requests"] += len(take)

    def _note_defer(self) -> None:
        self.stats["deferrals"] += 1
        self._defers += 1
        self._cooldown = min(self.MAX_BACKOFF_STEPS, 1 << min(self._defers, 3))

    def _retry_pending(self) -> None:
        """Re-attach deferred transfers, oldest first, under bounded backoff
        (consecutive failures double the cooldown up to MAX_BACKOFF_STEPS
        router steps)."""
        if not self._pending:
            return
        if self._cooldown > 0:
            self._cooldown -= 1
            return
        still: List[PageTransfer] = []
        for t in self._pending:
            if still:                 # keep order: don't leapfrog a stuck one
                still.append(t)
                continue
            free_slots = sum(1 for s in self.decode.slots if s is None)
            if len(t.records) > free_slots:
                still.append(t)
                continue
            try:
                self.decode.attach_requests(t)
                self.stats["migrations"] += 1
                self.stats["migrated_requests"] += len(t.records)
            except OutOfPages:
                still.append(t)
        if still:
            self._note_defer()
        else:
            self._defers = 0
        self._pending = still

    def _bounce_back(self) -> None:
        """Decode-side preemption victims re-enter the PREFILL engine in
        recompute mode.  ``_preempt_one`` already freed their pages, reset
        ``prefilled`` and re-planned chunks over prompt+generated — exactly
        the single-engine recompute state — but a decode-phase engine can
        never re-prefill them, so the router moves the RequestState across
        and the normal admission path takes over."""
        while self.decode.scheduler.waiting:
            rid = self.decode.scheduler.pop_waiting()
            st = self.decode._by_rid.pop(rid)
            self.decode.scheduler.forget(rid)
            self.prefill._by_rid[rid] = st
            self.prefill.scheduler.add(rid, priority=st.request.priority)
            self.stats["bounce_backs"] += 1

    # ------------------------------------------------------------------
    def migration_stats(self) -> Dict[str, Any]:
        """Router + both engines' migration counters, one dict."""
        out = dict(self.stats)
        out["migrated_pages"] = self.prefill.metrics["migrated_pages"]
        out["migration_us"] = (self.prefill.metrics["migration_us"]
                               + self.decode.metrics["migration_us"])
        out["pending_transfers"] = len(self._pending)
        return out

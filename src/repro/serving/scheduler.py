"""Token-budget continuous-batching scheduler (Sarathi-style chunked prefill).

Prompts are split at admission with ``core/chunking.split_chunks`` — the ISO
chunk is the scheduling quantum.  Each engine iteration the scheduler hands
the engine a plan: which requests prefill how many tokens this step (bounded
by ``prefill_token_budget``), which decode.  Consecutive chunks of one request
granted in the same step run as ONE forward call, so the model's ISO schedule
overlaps their collectives exactly as in a monolithic prefill.

Policies: ``fcfs`` (arrival order) and ``priority`` (higher ``Request.priority``
first, arrival order within a class).  Preemption-by-eviction: when the page
pool is exhausted the victim is the lowest-priority most-recently-arrived
running request; its pages are freed and it re-enters the waiting queue in
recompute mode (prompt := original prompt + tokens generated so far).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.config import ISOConfig, ModelConfig
from repro.core.chunking import round_to_bucket, split_chunks


@dataclass
class PrefillGrant:
    """One step's prefill work for one request."""
    rid: int
    start: int                 # tokens already prefilled (absolute offset)
    n_tokens: int              # tokens granted this step
    last: bool                 # True if this grant finishes the prompt
    padded: int = 0            # bucket-rounded grant length (== n_tokens
                               # when bucketing is off); the engine pads the
                               # forward call to this length and masks the tail


def plan_chunks(prompt_len: int, iso: ISOConfig, cfg: ModelConfig,
                whole: bool = False) -> Tuple[int, ...]:
    """ISO chunk boundaries for a prompt — the scheduling quanta.  ``whole``
    forces a single chunk (multimodal prompts, where splitting would cut
    through prepended patch/frame embeddings)."""
    if whole:
        return (prompt_len,)
    return split_chunks(prompt_len, iso, cfg)


class TokenBudgetScheduler:
    """Pure bookkeeping — no JAX.  The engine owns slots/arrays; the scheduler
    owns ordering, budget accounting and victim selection, so its properties
    are testable without a model."""

    def __init__(self, policy: str = "fcfs", prefill_token_budget: int = 512,
                 grant_buckets: Optional[Tuple[int, ...]] = None, trace=None,
                 cost_model=None, phase: str = "mixed"):
        if policy not in ("fcfs", "priority"):
            raise ValueError(f"unknown scheduler policy {policy!r}")
        if phase not in ("mixed", "prefill", "decode"):
            raise ValueError(f"unknown scheduler phase {phase!r}")
        self.policy = policy
        # phase routing (disaggregated serving — serving/disagg.py): a
        # "prefill" scheduler grants prefill chunks but its engine never runs
        # the decode phase (finished-prefill requests are DETACHED and
        # migrated out); a "decode" scheduler issues no grants and its engine
        # never admits (requests arrive via attach).  "mixed" is the
        # single-engine default — both phases, unchanged behaviour.
        self.phase = phase
        self.budget = max(1, prefill_token_budget)
        # optional obs.TraceRing: grant/pack decisions narrate themselves
        self.trace = trace
        # grant-size bucketing: every grant's forward-call length is rounded
        # up to a bucket so the engine's compiled-prefill count stays
        # O(#buckets).  None = no bucketing (padded == n_tokens).
        self.grant_buckets = tuple(grant_buckets) if grant_buckets else None
        # measured cost model (perf/costmodel.py): with a table, the chunk
        # cap is the bucket with the best measured time-per-token (grants
        # past it buy no amortisation — the remainder resumes next step, an
        # exact split, so tokens cannot change) and pack widths are capped at
        # the best measured time-per-grant row count.  Both are computed from
        # the TABLE ONLY — no clocks — so the decision sequence is a pure
        # function of traffic (tests/test_costmodel.py pins determinism).
        self.cost_model = cost_model
        self._grant_cap: Optional[int] = None
        if cost_model is not None:
            cap = cost_model.grant_cap(self.grant_buckets)
            if cap is not None:
                self._grant_cap = max(1, int(cap))
        self._pack_caps: Dict[int, int] = {}  # padded len -> modeled rows
        self._arrival: Dict[int, int] = {}
        self._priority: Dict[int, int] = {}
        self._clock = 0
        self.waiting: List[int] = []          # rids, un-ordered; sorted on use

    # ---- phase routing ----------------------------------------------------
    @property
    def runs_prefill(self) -> bool:
        return self.phase != "decode"

    @property
    def runs_decode(self) -> bool:
        return self.phase != "prefill"

    # ---- queue ------------------------------------------------------------
    def add(self, rid: int, priority: int = 0) -> None:
        if rid not in self._arrival:          # preserve arrival on re-queue
            self._arrival[rid] = self._clock
            self._clock += 1
        self._priority[rid] = priority
        self.waiting.append(rid)

    def register(self, rid: int, priority: int = 0) -> None:
        """Arrival/priority bookkeeping WITHOUT queueing: an attached
        (migrated-in) request is already resident, but ``pick_victim``/
        ``order`` need its ``_key`` — registration order is the migration
        order, which the router keeps in policy order."""
        if rid not in self._arrival:
            self._arrival[rid] = self._clock
            self._clock += 1
        self._priority[rid] = priority

    def forget(self, rid: int) -> None:
        """Drop every trace of ``rid`` — including its waiting-queue entry.
        A request cancelled BEFORE admission would otherwise linger in
        ``waiting`` with no ``_arrival``, and the next ``pop_waiting``/
        ``order`` would KeyError inside ``_key``."""
        self._arrival.pop(rid, None)
        self._priority.pop(rid, None)
        while rid in self.waiting:
            self.waiting.remove(rid)

    def _key(self, rid: int):
        if self.policy == "priority":
            return (-self._priority.get(rid, 0), self._arrival[rid])
        return (self._arrival[rid],)

    def order(self, rids: Sequence[int]) -> List[int]:
        return sorted(rids, key=self._key)

    def pop_waiting(self) -> Optional[int]:
        if not self.waiting:
            return None
        rid = min(self.waiting, key=self._key)
        self.waiting.remove(rid)
        return rid

    def requeue_front(self, rid: int) -> None:
        """Preempted request: back to waiting, arrival preserved (so FCFS puts
        it ahead of anything that arrived later).  Idempotent — a rid already
        waiting is NOT enqueued twice (a duplicate entry would survive the
        single ``waiting.remove`` in ``pop_waiting`` and be admitted again)."""
        if rid not in self.waiting:
            self.waiting.append(rid)

    # ---- per-step planning -------------------------------------------------
    def grant_prefill(self, prefill_states: Sequence[Tuple[int, int, Tuple[int, ...]]]
                      ) -> List[PrefillGrant]:
        """Distribute this step's token budget over running prefills.

        ``prefill_states``: (rid, tokens_done, chunk_plan) for every running
        request with prompt tokens remaining, any order.  Grants whole chunks
        in policy order; the head-of-line request always gets at least its next
        chunk even if the chunk alone exceeds the budget (guarantees progress —
        a prompt whose chunk is bigger than the budget would otherwise starve).

        The returned list is in policy order — (arrival,) for fcfs,
        (-priority, arrival) for priority — independent of the iteration
        order of ``prefill_states`` (``pack_grants`` re-sorts by the same
        key, so grant PACKING is deterministic too).
        """
        if not self.runs_prefill:
            return []                         # decode-phase engine: no grants
        by_rid = {rid: (done, plan) for rid, done, plan in prefill_states}
        grants: List[PrefillGrant] = []
        remaining = self.budget
        for rid in self.order(list(by_rid)):
            done, plan = by_rid[rid]
            ends, acc = [], 0
            for c in plan:
                acc += c
                ends.append(acc)
            assert done < ends[-1], (rid, done, plan)
            take, prev = 0, done
            for e in ends:
                if e <= done:
                    continue
                chunk = e - prev
                head_of_line = not grants and take == 0
                if take + chunk > remaining and not head_of_line:
                    break
                take += chunk
                prev = e
            if take == 0:
                continue                      # budget exhausted for non-head
            if self._grant_cap is not None and take > self._grant_cap:
                # modeled chunk cap: the grant's tail resumes next step (an
                # exact chunk split — the engine prefill takes any offset)
                if self.trace is not None:
                    self.trace.emit("decision", rid=rid, point="grant_cap",
                                    chosen=self._grant_cap, static=take)
                take = self._grant_cap
            remaining = max(0, remaining - take)
            padded = take if self.grant_buckets is None else \
                round_to_bucket(take, self.grant_buckets)
            g = PrefillGrant(rid=rid, start=done, n_tokens=take,
                             last=done + take >= ends[-1], padded=padded)
            grants.append(g)
            if self.trace is not None:
                self.trace.emit("grant", rid=rid, start=g.start, n=g.n_tokens,
                                padded=g.padded, last=g.last)
            if remaining == 0:
                break
        return grants

    def pack_grants(self, grants: Sequence[PrefillGrant], max_rows: int = 0
                    ) -> List[List[PrefillGrant]]:
        """Group compatible grants into batched packs (one forward call each).

        Packing is DETERMINISTIC under both policies, by construction:
        grants are first sorted by the scheduler key — (arrival,) for fcfs,
        (-priority, arrival) for priority; the same total order
        ``grant_prefill`` emits in, re-applied here so callers cannot
        perturb packing by reordering the grant list — then greedily grouped
        by identical ``padded`` length (rows of one forward call must share
        the call shape).  A pack closes when it reaches ``max_rows``; packs
        are emitted in the policy order of their first member.  Grants whose
        bucket never repeats become singleton packs.

        ``max_rows <= 1`` disables packing (every grant is its own pack) —
        the batch-1 reference the differential tests compare against.
        """
        if max_rows <= 1:
            return [[g] for g in grants]
        ordered = sorted(grants, key=lambda g: self._key(g.rid))
        packs: List[List[PrefillGrant]] = []
        open_by_len: Dict[int, int] = {}      # padded length -> pack index
        for g in ordered:
            limit = self._pack_limit(g.padded, max_rows)
            idx = open_by_len.get(g.padded)
            if idx is None or len(packs[idx]) >= limit:
                open_by_len[g.padded] = len(packs)
                packs.append([g])
            else:
                packs[idx].append(g)
        if self.trace is not None:
            for pack in packs:
                if len(pack) > 1:
                    self.trace.emit("pack", rid=pack[0].rid,
                                    rows=len(pack), padded=pack[0].padded)
        return packs

    def _pack_limit(self, padded: int, max_rows: int) -> int:
        """Row cap for packs of ``padded``-token grants: the measured row
        bucket with the best time-per-grant when a cost model is loaded
        (memoised per padded length; the modeled answer never changes within
        a run), else ``max_rows``.  Packing only changes CALL GROUPING —
        packed grants are byte-identical to batch-1 (PR 5 differential) — so
        a modeled cap can shift performance but never tokens."""
        if self.cost_model is None:
            return max_rows
        cap = self._pack_caps.get(padded)
        if cap is None:
            modeled = self.cost_model.pack_rows(padded)
            cap = max_rows if modeled is None else max(1, int(modeled))
            self._pack_caps[padded] = cap
            if self.trace is not None and cap < max_rows:
                self.trace.emit("decision", point="pack_rows", chosen=cap,
                                static=max_rows, padded=padded)
        return min(cap, max_rows)

    def pick_victim(self, running: Sequence[int], protect: Sequence[int] = ()
                    ) -> Optional[int]:
        """Eviction victim: reverse policy order (lowest priority, youngest)."""
        protected = set(protect)              # hoisted: not O(len) per request
        cands = [r for r in running if r not in protected]
        if not cands:
            return None
        return max(cands, key=self._key)

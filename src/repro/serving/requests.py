"""Request objects + lifecycle for the serving engine."""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

_ids = itertools.count()


@dataclass
class SamplingParams:
    temperature: float = 0.0          # 0 => greedy
    top_k: int = 0                    # 0 => full
    max_new_tokens: int = 32
    eos_id: int = 1
    seed: int = 0


@dataclass
class Request:
    prompt: np.ndarray                # (S,) int32
    sampling: SamplingParams = field(default_factory=SamplingParams)
    rid: int = field(default_factory=lambda: next(_ids))
    priority: int = 0                 # paged engine "priority" policy: higher first
    # family extras (stub frontends)
    frames: Optional[np.ndarray] = None
    patches: Optional[np.ndarray] = None


@dataclass
class RequestState:
    request: Request
    slot: int
    generated: List[int] = field(default_factory=list)
    prompt_len: int = 0
    done: bool = False
    # --- paged engine (chunked prefill) bookkeeping ---
    prefilled: int = 0                # prompt tokens already resident in pages
    chunk_plan: Tuple[int, ...] = ()  # ISO chunk boundaries = scheduling quanta
    t_submit: float = 0.0
    t_first: float = -1.0             # wall time of the first sampled token

    @property
    def total_len(self) -> int:
        return self.prompt_len + len(self.generated)

    def finish_check(self) -> None:
        sp = self.request.sampling
        if (self.generated and self.generated[-1] == sp.eos_id) or \
                len(self.generated) >= sp.max_new_tokens:
            self.done = True

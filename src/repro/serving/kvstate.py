"""Engine-external KV state: the page pools + allocator as one portable object.

``PagedEngine`` used to construct its ``PageAllocator`` and ``PagedKVCache``
privately, which trapped every request's KV inside the engine that prefilled
it.  ``KVPool`` bundles the two behind an export/import surface so KV state
can MOVE:

  * ``export_pages(rids)`` materializes the requests' pages (k/v payloads and
    the shared ``pos`` page), block tables and committed lengths as HOST
    arrays — the payload half of a ``serving/disagg.PageTransfer``.  Page ids
    are remapped to a dense export-local namespace, and a page shared by
    several exported requests (CoW prefix sharing) is exported ONCE and
    referenced by each table, so sharing survives the move.
  * ``import_pages(blob)`` re-adopts an export into a different pool: every
    distinct exported page gets a fresh page from the target's free list
    (``PageAllocator.import_tables`` — refcount-correct, atomic on
    ``OutOfPages``), and the payloads are scattered into the device arrays at
    the remapped ids.  ``pos`` metadata moves verbatim, so attention validity
    (``pos >= 0``, ``pos < length``) is exactly what it was at export time —
    including CoW-divergent pages and speculatively-rolled-back positions.

The same surface is what later unlocks KV offload/restore (export to host or
disk, import back), elastic pool resizing (export everything, rebuild, import)
and multi-host transfer (the blob is plain numpy + JSON-able tables).  The
pure-bookkeeping halves (``PageAllocator``/``PrefixCache``) serialize
independently via their ``snapshot()``/``restore()``.  See docs/serving.md.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.serving.kvcache import PageAllocator, PagedKVCache


class KVPool:
    """Allocator + device page pools, engine-external.

    Composition, not inheritance: ``pool.alloc`` is the ``PageAllocator`` and
    ``pool.kv`` the ``PagedKVCache`` — the engine keeps using both directly
    (``kv.arrays`` is the jit-visible pytree) and the pool adds the
    migration/serialization surface on top.
    """

    def __init__(self, alloc: PageAllocator, kv: PagedKVCache):
        assert alloc.page_size == kv.page_size, (alloc.page_size, kv.page_size)
        assert alloc.num_pages == kv.num_pages, (alloc.num_pages, kv.num_pages)
        self.alloc = alloc
        self.kv = kv

    @classmethod
    def create(cls, cfg: ModelConfig, num_pages: int, page_size: int, *,
               tp: int = 1, dtype=jnp.bfloat16, trace=None) -> "KVPool":
        return cls(PageAllocator(num_pages, page_size, trace=trace),
                   PagedKVCache(cfg, num_pages, page_size, tp=tp, dtype=dtype))

    @property
    def page_size(self) -> int:
        return self.alloc.page_size

    # ------------------------------------------------------------------
    # export / import
    # ------------------------------------------------------------------
    def export_pages(self, rids: Sequence[int]) -> Dict[str, Any]:
        """Host-array blob of ``rids``' KV state.

        Returns ``{"page_size", "tables", "lengths", "n_pages", "pages"}``
        where ``tables`` maps rid -> export-local page ids (0..n_pages-1,
        first-reference order), ``lengths`` the committed token counts, and
        ``pages`` holds one gathered array per pool buffer: ``k``/``v`` are
        per-attention-position lists of ``(Pd, n_pages, ps, Hkv, hd)`` and
        ``pos`` is ``(n_pages, ps)``.  The source pool is NOT mutated — the
        caller decides whether the export is a move (free the pages) or a
        copy (KV offload)."""
        local_of: Dict[int, int] = {}
        tables: Dict[int, List[int]] = {}
        for rid in rids:
            assert rid in self.alloc.tables, f"export of pageless request {rid}"
            row = []
            for pg in self.alloc.tables[rid]:
                if pg not in local_of:
                    local_of[pg] = len(local_of)
                row.append(local_of[pg])
            tables[rid] = row
        src = np.fromiter(local_of.keys(), np.int32, count=len(local_of))
        pages = {
            "k": [np.asarray(k[:, src]) for k in self.kv.arrays["k"]],
            "v": [np.asarray(v[:, src]) for v in self.kv.arrays["v"]],
            "pos": np.asarray(self.kv.arrays["pos"][src]),
        }
        return {"page_size": self.page_size, "tables": tables,
                "lengths": {rid: self.alloc.tokens(rid) for rid in rids},
                "n_pages": len(local_of), "pages": pages}

    def import_pages(self, blob: Dict[str, Any]) -> Dict[int, int]:
        """Adopt an ``export_pages`` blob into THIS pool.

        Allocates one fresh page per distinct exported page (raising
        ``OutOfPages`` atomically — nothing mutated — when the free list
        can't cover it), installs the remapped block tables with refcounts
        equal to the number of importing tables, and scatters the payloads
        into the device arrays.  Returns the export-local-id -> new-page
        mapping."""
        assert blob["page_size"] == self.page_size, \
            (blob["page_size"], self.page_size)
        mapping = self.alloc.import_tables(blob["tables"], blob["lengths"])
        n = blob["n_pages"]
        if n == 0:
            return mapping
        new_ids = jnp.asarray([mapping[lid] for lid in range(n)], jnp.int32)
        arrays = dict(self.kv.arrays)
        arrays["k"] = tuple(
            k.at[:, new_ids].set(jnp.asarray(payload, k.dtype))
            for k, payload in zip(arrays["k"], blob["pages"]["k"]))
        arrays["v"] = tuple(
            v.at[:, new_ids].set(jnp.asarray(payload, v.dtype))
            for v, payload in zip(arrays["v"], blob["pages"]["v"]))
        arrays["pos"] = arrays["pos"].at[new_ids].set(
            jnp.asarray(blob["pages"]["pos"], jnp.int32))
        self.kv.arrays = arrays
        return mapping

    # ------------------------------------------------------------------
    def scrub(self, pages: Sequence[int]) -> None:
        """Invalidate the ``pos`` entries of released pages: attention
        validity derives from ``pos >= 0``, so a reused page that is only
        partially overwritten must not expose a dead request's tail KV."""
        if not len(pages):
            return
        arrays = dict(self.kv.arrays)
        arrays["pos"] = arrays["pos"].at[
            jnp.asarray(list(pages), jnp.int32)].set(-1)
        self.kv.arrays = arrays

    def stats(self) -> Dict[str, Any]:
        s = self.alloc.stats()
        s["kv_bytes_live"] = self.kv.kv_bytes(self.alloc)
        s["kv_bytes_reserved"] = self.kv.total_bytes()
        return s

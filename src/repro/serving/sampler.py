"""Host-side token sampling from full-vocab logits (greedy / temperature / top-k)."""
from __future__ import annotations

import numpy as np

from repro.serving.requests import SamplingParams


def sample(logits: np.ndarray, sp: SamplingParams, step: int) -> int:
    """logits: (V,) fp32 for one request."""
    lf = np.asarray(logits, np.float32)
    if sp.temperature <= 0.0:
        return int(np.argmax(lf))
    lf = lf / sp.temperature
    if sp.top_k:
        kth = np.partition(lf, -sp.top_k)[-sp.top_k]
        lf = np.where(lf < kth, -np.inf, lf)
    lf = lf - lf.max()
    p = np.exp(lf)
    p /= p.sum()
    rng = np.random.default_rng(sp.seed * 1_000_003 + step)
    return int(rng.choice(len(p), p=p))

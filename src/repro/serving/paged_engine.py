"""Paged continuous-batching engine: chunked prefill interleaved with decode.

The dense ``Engine`` (serving/engine.py) admits one request at a time with a
blocking full-prompt prefill into per-slot ``max_len`` caches.  This engine
replaces both halves:

  * KV memory is a shared page pool (serving/kvcache.py) — footprint scales
    with resident tokens, and admission never over-reserves;
  * each ``step()`` runs a token-budget slice of pending *prefill chunks*
    (the ISO chunk boundaries from ``core/chunking.split_chunks`` are the
    scheduling quanta) and then ONE batched decode step for every request
    whose prompt is fully resident — Sarathi-style chunk/decode mixing across
    requests, ISO overlap order inside each prefill call.  Grants sharing a
    bucket-padded length are PACKED into one multi-row forward call per tick
    (``ServingConfig.prefill_batching``, attention-only stacks): per-row
    ``pos_offset``/``prefix_len``/``valid_len`` ride through ``StageCtx``
    into the paged flash-prefill kernel, so a fresh request (prefix 0) and
    resumed requests at arbitrary depths share one call and one ISO overlap
    schedule instead of N serialized batch-1 calls.

A request whose prompt is partially prefilled keeps its KV prefix in pages and
its recurrent (SSM/xLSTM) states in per-slot arrays across engine steps; the
next grant resumes with ``prefill(prefix_caches=..., pos_offset=start)``.
When the pool runs dry the scheduler evicts a victim (recompute preemption:
its pages are freed and prompt+generated re-enter the waiting queue).

Decode reads the page pools IN PLACE through the paged flash-decode kernel
(kernels/flash_decode.py) — no dense gather.  With ``mesh`` both jitted
closures run inside ``shard_map`` over the TP "model" axis, and the batched
decode uses the batch-split ISO schedule (core/iso.run_stack_decode_overlap)
so each half's all-reduce hides behind the other half's compute.  Requests
with a common prompt prefix share KV pages copy-on-write
(``PageAllocator.adopt``/``cow`` + ``PrefixCache``).  With
``ServingConfig.spec_k > 0`` the decode phase verifies a (spec_k+1)-token
self-drafted window per slot through the same kernel — the paper's
§Discussion decode-side regime where fatter steps amortise the memory-bound
cache walk — committing only accepted tokens and rolling rejected positions
back by ``pos`` invalidation.  See docs/serving.md.
"""
from __future__ import annotations

import contextlib
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.config import Config, ServingConfig
from repro.core.chunking import grant_buckets, round_to_bucket
from repro.core.overlap import AxisCtx
from repro.layers import embeddings as emb_lib
from repro.models import api
from repro.obs import jaxprof
from repro.obs.registry import (ACCEPT_LEN_BUCKETS, GRANT_SIZE_BUCKETS,
                                MetricsRegistry, TPOT_BUCKETS_S,
                                TTFT_BUCKETS_S)
from repro.obs.trace import TraceRing
from repro.models.decoder import cache_specs, decoder_param_specs
from repro.serving.kvcache import (OutOfPages, PrefixCache, pages_for,
                                   token_page_coords, window_page_coords)
from repro.serving.kvstate import KVPool
from repro.serving.requests import Request, RequestState
from repro.serving.sampler import sample
from repro.serving.scheduler import TokenBudgetScheduler, plan_chunks


class PagedEngine:
    def __init__(self, config: Config, params, *, serving: ServingConfig = None,
                 mesh=None, phase: str = "mixed", kv_pool: KVPool = None):
        assert config.model.family != "audio", \
            "enc-dec (whisper) serving stays on the dense Engine"
        assert phase in ("mixed", "prefill", "decode"), phase
        self.config = config
        self.cfg = config.model
        self.params = params
        sv = serving or config.serving
        self.sv = sv
        self.ps = sv.page_size
        self.max_batch = sv.max_batch
        self.max_len = sv.max_len
        self.max_blocks = -(-sv.max_len // sv.page_size)
        num_pages = sv.num_pages or sv.max_batch * self.max_blocks
        cache_dtype = jax.tree_util.tree_leaves(params)[0].dtype

        # tensor-parallel serving: the whole engine step (prefill grants and
        # the batched decode) runs inside shard_map over the "model" axis
        self.mesh = mesh
        if mesh is not None:
            assert config.parallel.data == 1 and config.parallel.pods == 1, \
                "paged TP serving shards the model axis only"
            self.tp = config.parallel.model
            self._ctx = AxisCtx(tp_axis="model", tp=self.tp,
                                quantized_comm=config.iso.quantized_comm)
        else:
            self.tp = 1
            self._ctx = AxisCtx()
        # decode collective schedule (core/iso.py).  Ladder-wired configs
        # always run the ladder driver (the wiring is part of the model
        # function); decode_overlap only picks deferred vs immediate
        # collectives inside it.  Standard wiring: "auto" means the
        # batch-split schedule under TP (each half's all-reduce hides behind
        # the other half's attention), sequential otherwise; explicit
        # ServingConfig.decode_schedule forces sequential / batch_split /
        # cross_block.  A batch-split engine additionally falls back to a
        # sequential closure per step when < 2 requests are resident
        # (_decode_phase) — one active request has no second half.
        if self.cfg.residual_wiring == "ladder":
            self._decode_schedule = "ladder" if sv.decode_overlap \
                else "ladder_seq"
        elif sv.decode_schedule == "auto":
            self._decode_schedule = "batch_split" \
                if (mesh is not None and sv.decode_overlap
                    and sv.max_batch >= 2) else "sequential"
        else:
            assert sv.decode_schedule in ("sequential", "batch_split",
                                          "cross_block"), sv.decode_schedule
            self._decode_schedule = sv.decode_schedule
        # legacy view, pinned by tests: True iff batch-split is the schedule
        self._decode_overlap = self._decode_schedule == "batch_split"

        # observability (src/repro/obs): typed registry behind the legacy
        # dict view, structured trace ring the scheduler/allocator/phase
        # loops narrate into.  The registry is always on (counter bumps are
        # host-side nanoseconds); ``observability=False`` silences the trace.
        self.registry = MetricsRegistry()
        self.trace = TraceRing(capacity=sv.trace_events,
                               enabled=sv.observability)
        self.registry.histogram("ttft", TTFT_BUCKETS_S)
        self.registry.histogram("tpot", TPOT_BUCKETS_S)
        self.registry.histogram("grant_size", GRANT_SIZE_BUCKETS)
        self.registry.histogram("accept_len", ACCEPT_LEN_BUCKETS)
        self.registry.gauge("pool_occupancy")
        self.registry.gauge("free_list_fragmentation")

        # measured cost model (perf/costmodel.py): an injected CostModel, or
        # one loaded from ``cost_table`` ("" = off, "auto" = the bundled
        # per-platform table, else a path).  Load failures — missing file,
        # malformed table, wrong platform/mesh — emit ONE warning trace event
        # and leave the model None: every decision below then uses the
        # static-default path unchanged.
        self.cost_model = sv.cost_model
        if self.cost_model is None and sv.cost_table:
            from repro.perf.costmodel import load_cost_model
            self.cost_model = load_cost_model(
                sv.cost_table, platform=jax.default_backend(), tp=self.tp,
                trace=self.trace)

        # KV ownership lives OUTSIDE the engine (serving/kvstate.KVPool):
        # allocator + device page pools travel as one object, so KV state can
        # be exported/imported across engines (disaggregated serving, KV
        # offload/restore).  An injected pool is re-pointed at this engine's
        # trace ring so the replay-conservation oracle stays per-engine.
        if kv_pool is None:
            kv_pool = KVPool.create(self.cfg, num_pages, self.ps, tp=self.tp,
                                    dtype=cache_dtype, trace=self.trace)
        else:
            assert kv_pool.page_size == self.ps, (kv_pool.page_size, self.ps)
            kv_pool.alloc.trace = self.trace
        self.pool = kv_pool
        self.alloc = kv_pool.alloc
        self.kv = kv_pool.kv
        # phase routing (disagg): "prefill" never runs the decode phase,
        # "decode" never admits/prefills; "mixed" = the single-engine default
        self.phase = phase
        self.states = api.init_state_caches(self.cfg, sv.max_batch, tp=self.tp,
                                            dtype=cache_dtype)
        # grant-size bucketing: pad every prefill grant up to a bucket length
        # so compilation is keyed on the bucket — O(#buckets) compiled
        # closures instead of one per distinct grant length.  Attention-only
        # stacks (pad tokens are masked out of attention and KV scatter, but
        # would advance recurrent SSM/xLSTM state), and no patch-carrying
        # models: patch grants run unbucketed, which would break the
        # max_prefill_compiles() bound their closures share.
        self._buckets = None
        if sv.grant_bucketing and self.cfg.num_patches == 0 and \
                all(k in ("attn_mlp", "attn_moe")
                    for k in self.cfg.block_pattern):
            self._buckets = grant_buckets(sv.max_len, sv.min_grant_bucket,
                                          sv.grant_buckets)
        self.scheduler = TokenBudgetScheduler(
            policy=sv.scheduler_policy,
            prefill_token_budget=sv.prefill_token_budget,
            grant_buckets=self._buckets, trace=self.trace,
            cost_model=self.cost_model, phase=phase)
        # batched multi-request prefill grants: pack same-padded-length grants
        # into ONE forward call per tick (per-row pos_offset/prefix_len/
        # valid_len threaded through StageCtx into the paged prefill kernel).
        # Attention-only stacks without patch embeddings — recurrent families
        # carry per-slot state the packed rows cannot share, and patch grants
        # have a row-heterogeneous embed layout.  The row count is padded to
        # a power-of-two ladder so closures stay keyed on
        # (length bucket, row bucket) — O(#buckets x #row_buckets) compiles.
        self._batch_prefill = (sv.prefill_batching and self.cfg.num_patches == 0
                               and all(k in ("attn_mlp", "attn_moe")
                                       for k in self.cfg.block_pattern))
        self._row_buckets = grant_buckets(sv.max_batch, min_bucket=1) \
            if self._batch_prefill else (1,)
        # copy-on-write prefix sharing: attention-only stacks (recurrent
        # families carry per-slot SSM/xLSTM state that pages cannot share)
        self.prefix_cache: Optional[PrefixCache] = None
        if sv.prefix_sharing and all(k in ("attn_mlp", "attn_moe")
                                     for k in self.cfg.block_pattern):
            self.prefix_cache = PrefixCache(self.ps)

        # speculative decoding: greedy-only self-drafting (serving/speculative
        # .py); attention-only stacks — a K-token verify would advance
        # recurrent SSM/xLSTM state for rejected tokens too
        self.spec_k = 0
        if sv.spec_k and all(k in ("attn_mlp", "attn_moe")
                             for k in self.cfg.block_pattern):
            self.spec_k = sv.spec_k
        self._drafts: List[Optional[Any]] = [None] * sv.max_batch

        self.slots: List[Optional[RequestState]] = [None] * sv.max_batch
        self.lengths = np.zeros(sv.max_batch, np.int64)   # tokens resident
        self.last_tokens = np.zeros(sv.max_batch, np.int64)
        self._by_rid: Dict[int, RequestState] = {}        # waiting + running
        self._finished: List[RequestState] = []
        self._prefill_fns: Dict[Tuple, Any] = {}
        self._decode_fns: Dict[Tuple[int, int], Any] = {}  # (K, kv_splits) -> fn
        # sequential fallback closures for a batch-split engine running with
        # < 2 resident requests — kept OUT of _decode_fns so the CI
        # compile-guard lane's pinned key set stays schedule-pure
        self._decode_fallback_fns: Dict[Tuple[int, int], Any] = {}
        # overlap-probe closures live OUTSIDE _decode_fns: the CI
        # compile-guard lane pins that cache's key set to real traffic
        self._probe_decode_fns: Dict[Tuple[str, bool], Any] = {}
        self._copy_page_fn = None
        # legacy counter key set, pre-registered so `metrics[k] == 0` holds
        # before first use; timed sums are fenced EXECUTION time, the
        # *_dispatch_s pair keeps the async (dispatch-only) view
        self.registry.counters((
            "prefill_s", "decode_s", "prefill_dispatch_s",
            "decode_dispatch_s", "prefill_tokens", "decode_tokens",
            "completed", "decode_calls", "prefill_calls", "steps",
            "preemptions", "ttft_sum", "ttft_n", "prefix_shared_tokens",
            "cow_copies", "peak_used_pages", "prefill_pad_tokens",
            "prefill_samples", "spec_calls", "spec_tokens", "prefill_grants",
            "resumed_grants", "prefill_pad_rows", "migrations",
            "migrated_pages", "migration_us"))
        self.metrics = self.registry.view()

    # ------------------------------------------------------------------
    # request lifecycle
    # ------------------------------------------------------------------
    def _eff_extra(self, req: Request) -> int:
        return req.patches.shape[0] if req.patches is not None else 0

    def add_request(self, req: Request) -> int:
        assert req.frames is None, "audio requests need the dense Engine"
        assert self.phase != "decode", \
            "decode-phase engine: requests arrive via attach_requests only"
        eff = len(req.prompt) + self._eff_extra(req)
        if eff + req.sampling.max_new_tokens > self.max_len:
            raise ValueError(f"request {req.rid}: {eff} prompt + "
                             f"{req.sampling.max_new_tokens} new tokens exceeds "
                             f"max_len={self.max_len}")
        need = pages_for(eff + req.sampling.max_new_tokens, self.ps)
        if need > self.alloc.num_pages:
            raise ValueError(f"request {req.rid}: needs {need} pages even with "
                             f"every other request evicted; pool has "
                             f"{self.alloc.num_pages} (raise "
                             f"ServingConfig.num_pages)")
        st = RequestState(request=req, slot=-1, t_submit=time.perf_counter())
        st.prompt_len = eff
        st.chunk_plan = plan_chunks(eff, self.config.iso, self.cfg,
                                    whole=req.patches is not None)
        self._by_rid[req.rid] = st
        self.scheduler.add(req.rid, priority=req.priority)
        return req.rid

    def _admit(self) -> None:
        free = [i for i, s in enumerate(self.slots) if s is None]
        while free and self.scheduler.waiting:
            rid = self.scheduler.pop_waiting()
            st = self._by_rid[rid]
            st.slot = free.pop(0)
            st.prefilled = 0
            self.slots[st.slot] = st
            self.lengths[st.slot] = 0
            self.trace.emit("admit", rid=rid, slot=st.slot)
            self._try_share_prefix(st)

    def _try_share_prefix(self, st: RequestState) -> None:
        """Map a live donor's matching prompt-prefix pages into this request
        (refcounted, zero-copy); prefill then resumes after the shared part."""
        if self.prefix_cache is None or st.request.patches is not None:
            return
        rid = st.request.rid
        hit = self.prefix_cache.lookup(st.request.prompt, self.alloc,
                                       exclude=rid)
        if hit is not None:
            donor, t, pages = hit
            self.alloc.adopt(rid, pages, t)
            st.prefilled = t
            self.lengths[st.slot] = t
            self.metrics["prefix_shared_tokens"] += t
        self.prefix_cache.register(rid, st.request.prompt)

    def _copy_page(self, old: int, new: int) -> None:
        """Device-side page copy for copy-on-write (all layers + positions).
        One donated jitted call, compiled once for any (old, new) pair — the
        eager equivalent would rebuild every pool buffer per layer."""
        if self._copy_page_fn is None:
            def fn(arr, old_pg, new_pg):
                out = dict(arr)
                out["k"] = tuple(k.at[:, new_pg].set(k[:, old_pg])
                                 for k in arr["k"])
                out["v"] = tuple(v.at[:, new_pg].set(v[:, old_pg])
                                 for v in arr["v"])
                out["pos"] = arr["pos"].at[new_pg].set(arr["pos"][old_pg])
                return out
            self._copy_page_fn = jax.jit(fn, donate_argnums=(0,))
        with self._mesh_ctx():
            self.kv.arrays = self._copy_page_fn(self.kv.arrays,
                                                jnp.int32(old), jnp.int32(new))
        self.metrics["cow_copies"] += 1

    def _cow_range(self, rid: int, start: int, end: int) -> bool:
        """Copy-on-write every shared page the token range [start, end) will
        write into (evicting for the copy target if the pool is dry)."""
        table = self.alloc.tables.get(rid, [])
        for blk in range(start // self.ps, (end - 1) // self.ps + 1):
            if blk >= len(table):
                break                         # beyond the table: fresh pages
            while True:
                try:
                    pair = self.alloc.cow(rid, blk)
                    break
                except OutOfPages:
                    if not self._preempt_one(protect=[rid]):
                        return False
            if pair is not None:
                self._copy_page(*pair)
        return True

    def _release_pages(self, rid: int) -> None:
        """Free rid's pages and invalidate their position entries: attention
        validity is derived from ``pos >= 0``, so a reused page that is only
        partially overwritten must not expose the dead request's tail KV."""
        pages = self.alloc.free(rid)
        if pages:
            new_kv = dict(self.kv.arrays)
            new_kv["pos"] = new_kv["pos"].at[
                jnp.asarray(pages, jnp.int32)].set(-1)
            self.kv.arrays = new_kv

    def _preempt_one(self, protect: List[int]) -> bool:
        """Evict one running request (recompute mode).  False if none left."""
        running = [s.request.rid for s in self.slots if s is not None]
        victim = self.scheduler.pick_victim(running, protect=protect)
        if victim is None:
            return False
        st = self._by_rid[victim]
        self.trace.emit("evict", rid=victim, slot=st.slot)
        self._release_pages(victim)
        self.slots[st.slot] = None
        self.lengths[st.slot] = 0
        self.last_tokens[st.slot] = 0
        self._drafts[st.slot] = None
        st.slot = -1
        # recompute mode: everything generated so far becomes prompt; the
        # re-prefill's last-position logits yield the next token exactly where
        # decode left off
        st.prefilled = 0
        eff = st.prompt_len + len(st.generated)
        st.chunk_plan = plan_chunks(eff, self.config.iso, self.cfg,
                                    whole=st.request.patches is not None)
        self.scheduler.requeue_front(victim)
        self.metrics["preemptions"] += 1
        return True

    def _ensure_pages(self, rid: int, n_tokens: int) -> bool:
        """Grow rid's block table to n_tokens capacity, evicting if needed."""
        while True:
            try:
                self.alloc.ensure(rid, n_tokens)
                return True
            except OutOfPages:
                if not self._preempt_one(protect=[rid]):
                    return False

    def _resident_tokens(self, st: RequestState) -> np.ndarray:
        """Token ids the request's prompt re-prefill covers (recompute mode
        folds generated tokens in)."""
        toks = np.asarray(st.request.prompt, np.int32)
        if st.generated:
            toks = np.concatenate([toks, np.asarray(st.generated, np.int32)])
        return toks

    # ------------------------------------------------------------------
    # jitted closures (wrapped in shard_map over the TP axis under a mesh)
    # ------------------------------------------------------------------
    def _mesh_ctx(self):
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    def _kv_specs(self):
        kv = P(None, None, None, "model", None)   # (Pd, page, ps, HEADS, hd)
        n = len(self.kv.kv_positions)
        return {"k": (kv,) * n, "v": (kv,) * n, "pos": P(None, None)}

    def _state_specs(self):
        # recurrent-state leaves reuse the dense cache rules (names/ndims
        # only); batch stays unsharded — serving TP shards the model axis
        return cache_specs(jax.eval_shape(lambda: self.states),
                           batch_axes=None, shard_batch=False)

    def _wrap_prefill(self, fn, has_patches: bool):
        if self.mesh is None:
            return jax.jit(fn)
        p_specs = decoder_param_specs(jax.eval_shape(lambda: self.params))
        in_specs = (p_specs, P(None, None),
                    P(None, None, None) if has_patches else None,
                    self._kv_specs(), self._state_specs(),
                    P(None, None), P(), P())
        out_specs = (P(None, "model"), self._kv_specs(), self._state_specs())
        sm = compat.shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=False)
        return jax.jit(sm)

    def _wrap_decode(self, fn):
        if self.mesh is None:
            return jax.jit(fn)
        p_specs = decoder_param_specs(jax.eval_shape(lambda: self.params))
        in_specs = (p_specs, P(None, None), P(None, None), P(None),
                    self._kv_specs(), self._state_specs(), P(None))
        out_specs = (P(None, None, "model"), self._kv_specs(),
                     self._state_specs())
        sm = compat.shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=False)
        return jax.jit(sm)

    def _paged_prefix(self, kv_arrays, states_slot):
        """Per-position prefill caches exposing the page pools IN PLACE.

        The paged flash-prefill kernel (kernels/flash_prefill_paged.py) reads
        the prefix straight through the block table — no dense gather.  The
        kernel's ``k_pos < prefix_len`` masking also covers prefix sharing
        (the tail of a partially-shared page holds the DONOR's KV at
        positions >= the shared length, which this request must not attend).
        Recurrent positions carry their per-slot SSM/xLSTM state."""
        prefix, kv_i = [], 0
        for i, kind in enumerate(self.cfg.block_pattern):
            c = dict(states_slot[i])
            if i in self.kv.kv_positions:
                c["k_pages"] = kv_arrays["k"][kv_i]
                c["v_pages"] = kv_arrays["v"][kv_i]
                kv_i += 1
            prefix.append(c)
        return tuple(prefix)

    def _get_prefill(self, n_text: int, n_patches: int, resumed: bool):
        """Jitted prefill closure for a (padded) grant shape.

        ``n_text`` is the BUCKET-PADDED text length: with bucketing on, the
        key space is (bucket, patches, fresh|resumed) — O(#buckets) compiled
        closures total, regardless of how many distinct grant lengths the
        traffic produces.  The closure takes the REAL token count ``n_real``
        as a traced scalar: pad-tail tokens are masked out of attention
        (``valid_len`` -> StageCtx), scatter to the scratch page, and the
        sampled logits come from the last real position."""
        key = (n_text, n_patches, resumed)
        if key in self._prefill_fns:
            return self._prefill_fns[key]
        cfg, iso, ctx = self.cfg, self.config.iso, self._ctx
        T = n_text + n_patches
        scratch = self.kv.scratch_page

        def fn(params, tokens, patches, kv_arrays, states_slot, bt_row, start,
               n_real):
            batch = {"tokens": tokens}
            if n_patches:
                batch["patches"] = patches
            prefix = self._paged_prefix(kv_arrays, states_slot) \
                if resumed else None
            out = api.prefill(
                params, cfg, ctx, iso, batch, logits_mode="none",
                prefix_caches=prefix, pos_offset=start,
                block_tables=bt_row if resumed else None,
                prefix_lens=jnp.reshape(start, (1,)) if resumed else None,
                valid_len=n_real, return_extras=True)
            # logits of the last REAL token (the pad tail carries garbage)
            h_last = jax.lax.dynamic_slice_in_dim(out["hidden"], n_real - 1, 1,
                                                  axis=1)
            logits_last = emb_lib.lm_head_local(params["embed"], h_last)[:, 0]
            positions = start + jnp.arange(T, dtype=jnp.int32)
            page, off = token_page_coords(positions, bt_row[0], self.ps, scratch)
            # pad-tail tokens must not scatter KV into live pages
            page = jnp.where(jnp.arange(T) < n_real, page, scratch)
            # anything routed to the scratch page must write pos -1, never a
            # real position: pos[scratch] >= 0 would be a validity leak for
            # any pos-driven gather (tests/test_paged_spec.py invariant)
            positions = jnp.where(page != scratch, positions, -1)
            new_kv = dict(kv_arrays)
            ks, vs = list(kv_arrays["k"]), list(kv_arrays["v"])
            new_states = []
            for i, kind in enumerate(cfg.block_pattern):
                ex = out["extras"][i]
                if i in self.kv.kv_positions:
                    kv_i = self.kv.kv_positions.index(i)
                    ks[kv_i] = ks[kv_i].at[:, page, off].set(
                        ex["kv_k"][:, 0].astype(ks[kv_i].dtype))
                    vs[kv_i] = vs[kv_i].at[:, page, off].set(
                        ex["kv_v"][:, 0].astype(vs[kv_i].dtype))
                new_states.append({sk: ex[sk] for sk in ("ssm", "mlstm", "slstm")
                                   if sk in ex})
            new_kv["k"], new_kv["v"] = tuple(ks), tuple(vs)
            new_kv["pos"] = kv_arrays["pos"].at[page, off].set(positions)
            return logits_last, new_kv, tuple(new_states)

        self._prefill_fns[key] = self._wrap_prefill(fn, n_patches > 0)
        return self._prefill_fns[key]

    def _wrap_prefill_batched(self, fn):
        if self.mesh is None:
            return jax.jit(fn)
        p_specs = decoder_param_specs(jax.eval_shape(lambda: self.params))
        in_specs = (p_specs, P(None, None), self._kv_specs(),
                    P(None, None), P(None), P(None))
        out_specs = (P(None, "model"), self._kv_specs())
        sm = compat.shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=False)
        return jax.jit(sm)

    def _get_prefill_batched(self, n_text: int, rows: int, all_fresh: bool):
        """Jitted prefill closure for a PACK of grants: ``rows`` requests'
        grants (row-bucket-padded) run as one ``(rows, n_text)`` forward call.

        Every row resumes at its own absolute position: per-row
        ``starts`` doubles as the paged ``prefix_lens`` (a fresh request is
        simply a row with prefix 0 — the kernel returns the neutral partial
        state for it) and per-row ``n_reals`` masks each row's bucket-pad
        tail.  Pad ROWS (beyond the real pack size) carry all-(-1) block
        tables, start 0 and n_real 0: fully masked out of attention, KV
        routed to the scratch page with pos -1.  ``all_fresh`` packs (every
        row at start 0 — the common cold-prefill case) skip the paged
        kernel entirely: with no resident prefix the whole block-table walk
        would be masked, so they take the dense intra-call path like the
        batch-1 fresh closure did.  The key space is
        (length bucket, row bucket, all-fresh) — O(#buckets x #row_buckets)
        closures.  Attention-only stacks: no recurrent state crosses this
        call."""
        key = (n_text, rows, all_fresh)
        if key in self._prefill_fns:
            return self._prefill_fns[key]
        cfg, iso, ctx = self.cfg, self.config.iso, self._ctx
        T = n_text
        ps = self.ps
        scratch = self.kv.scratch_page
        empty_states = tuple({} for _ in cfg.block_pattern)

        def fn(params, tokens, kv_arrays, bt, starts, n_reals):
            prefix = None if all_fresh else \
                self._paged_prefix(kv_arrays, empty_states)
            out = api.prefill(
                params, cfg, ctx, iso, {"tokens": tokens}, logits_mode="none",
                prefix_caches=prefix, pos_offset=starts,
                block_tables=None if all_fresh else bt,
                prefix_lens=None if all_fresh else starts,
                valid_len=n_reals, return_extras=True)
            # logits of each row's last REAL token (pad tails carry garbage)
            h_last = out["hidden"][jnp.arange(rows),
                                   jnp.clip(n_reals - 1, 0, T - 1)]
            logits_last = emb_lib.lm_head_local(params["embed"],
                                                h_last[:, None])[:, 0]
            positions = (starts[:, None]
                         + jnp.arange(T, dtype=jnp.int32)[None])   # (rows, T)
            page, off = jax.vmap(
                lambda p_, b_: token_page_coords(p_, b_, ps, scratch))(
                    positions, bt)
            # pad-tail tokens (and whole pad rows) must not scatter KV into
            # live pages; anything routed to scratch writes pos -1
            page = jnp.where(jnp.arange(T)[None] < n_reals[:, None],
                             page, scratch)
            positions = jnp.where(page != scratch, positions, -1)
            new_kv = dict(kv_arrays)
            ks, vs = list(kv_arrays["k"]), list(kv_arrays["v"])
            for kv_i, i in enumerate(self.kv.kv_positions):
                ex = out["extras"][i]
                ks[kv_i] = ks[kv_i].at[:, page, off].set(
                    ex["kv_k"].astype(ks[kv_i].dtype))
                vs[kv_i] = vs[kv_i].at[:, page, off].set(
                    ex["kv_v"].astype(vs[kv_i].dtype))
            new_kv["k"], new_kv["v"] = tuple(ks), tuple(vs)
            new_kv["pos"] = kv_arrays["pos"].at[page, off].set(positions)
            return logits_last, new_kv

        self._prefill_fns[key] = self._wrap_prefill_batched(fn)
        return self._prefill_fns[key]

    # ---- compile accounting (CI compile-guard lane) -------------------
    def prefill_compile_count(self) -> int:
        """Total prefill-closure compilations so far (one jit cache entry per
        compiled executable)."""
        return sum(compat.jit_cache_size(fn)
                   for fn in self._prefill_fns.values())

    def max_prefill_compiles(self) -> Optional[int]:
        """Upper bound on prefill compilations under bucketing.  With batched
        grants: one closure per (length bucket, row bucket) pair — every
        grant, fresh or resumed, single or packed, runs through the batched
        closure.  Batch-1 mode keeps the old bound of one closure per
        (bucket, fresh|resumed) pair.  None when bucketing is off (one
        closure per distinct grant length — unbounded under mixed traffic)."""
        if self._buckets is None:
            return None
        if self._batch_prefill:
            # (length bucket, row bucket, all-fresh|has-resumed)
            return 2 * len(self._buckets) * len(self._row_buckets)
        return 2 * len(self._buckets)

    def _kv_splits(self, K: int = 1) -> int:
        """Split count S for this decode step's flash-decode page walk
        (split-KV sequence parallelism — kernels/flash_decode.py).

        ``ServingConfig.decode_kv_splits`` 0 = auto: with a cost model
        loaded, S is the split count with the best MEASURED decode time at
        the deepest resident request's page depth (perf/costmodel.py —
        logged as a ``decision`` trace event with the static answer it
        replaced); without one, the static heuristic splits by
        ``decode_split_factor`` only when the walk spans at least
        ``decode_split_min_pages`` pages (shallow walks gain nothing from
        the extra reduce step).  1 = sequential; >1 forced — an explicit
        setting always beats the model.  Clamped to the block-table width so
        every span owns >= 1 page slot.  S is STATIC — part of the decode
        closure's (K, S) compile key.  Split count never changes tokens
        (split == sequential proven by tests/test_split_kv.py), so a modeled
        S may differ from the static one without a differential risk."""
        sv = self.sv
        s = sv.decode_kv_splits
        if s == 0:
            deepest = pages_for(int(self.lengths.max()) + K, self.ps)
            static = sv.decode_split_factor \
                if deepest >= sv.decode_split_min_pages else 1
            s = static
            if self.cost_model is not None:
                chosen = self.cost_model.decode_splits(
                    deepest, K, max_splits=self.max_blocks)
                if chosen is not None:
                    s = chosen
                    self.trace.emit("decision", point="kv_splits",
                                    chosen=int(chosen), static=int(static),
                                    depth=int(deepest), k=int(K))
        return max(1, min(int(s), self.max_blocks))

    def _get_decode(self, K: int = 1, S: int = 1):
        """Jitted decode closure for a K-token window (K=1 plain decode,
        K=spec_k+1 speculative verify) walking the pages in S split-KV
        spans — one compiled closure per (K, S), all built on the engine's
        decode schedule (``_decode_schedule``)."""
        key = (K, S)
        if key not in self._decode_fns:
            self._decode_fns[key] = self._build_decode_fn(
                K, schedule=self._decode_schedule, ctx=self._ctx,
                kv_splits=S)
        return self._decode_fns[key]

    def _get_fallback_decode(self, K: int = 1, S: int = 1):
        """Sequential decode closure for a batch-split engine step with < 2
        resident requests (one active slot has no second half to overlap
        with — core/iso.run_stack_decode_overlap would degrade anyway, and
        running two half-calls where one is pure scratch wastes the step).
        Cached apart from ``_decode_fns`` so the compile-guard key pins
        stay schedule-pure."""
        key = (K, S)
        if key not in self._decode_fallback_fns:
            self._decode_fallback_fns[key] = self._build_decode_fn(
                K, schedule="sequential", ctx=self._ctx, kv_splits=S)
        return self._decode_fallback_fns[key]

    def _get_probe_decode(self, schedule: str, comm: bool = True):
        """Decode closure variants for the overlap-efficiency probe
        (obs/overlap_probe.py): one per collective schedule (sequential /
        batch_split / cross_block / ladder / ladder_seq), plus a
        collectives-disabled compute floor (``comm=False`` swaps in a bare
        AxisCtx — psum degrades to identity inside the same shard_map).
        Cached in ``_probe_decode_fns``, never ``_decode_fns``, whose key
        set the compile-guard lane pins to real traffic."""
        key = (schedule, comm)
        if key not in self._probe_decode_fns:
            ctx = self._ctx if comm else AxisCtx()
            # probes always walk sequentially (kv_splits=1): the probe
            # measures overlap efficiency, not split-KV reduce cost
            self._probe_decode_fns[key] = self._build_decode_fn(
                1, schedule=schedule, ctx=ctx, kv_splits=1)
        return self._probe_decode_fns[key]

    def measure_overlap_efficiency(self, iters: int = 10, warmup: int = 3):
        """Time the decode collective schedules (sequential vs batch-split
        vs ladder vs cross-block) on identical synthetic batches; see
        obs/overlap_probe.decode_overlap_probe."""
        from repro.obs.overlap_probe import decode_overlap_probe
        return decode_overlap_probe(self, iters=iters, warmup=warmup)

    def _build_decode_fn(self, K: int, schedule: str, ctx: AxisCtx,
                         kv_splits: int = 1):
        cfg = self.cfg
        scratch = self.kv.scratch_page
        ps = self.ps

        def fn(params, toks, bt, lengths, kv_arrays, states, active):
            # paged flash decode: the stack reads the page pools in place
            # through the block tables (kernels/flash_decode.py) and scatters
            # the window's KV to its pages (core/iso.run_stack_decode)
            caches, kv_i = [], 0
            for i, kind in enumerate(cfg.block_pattern):
                c = dict(states[i])
                if i in self.kv.kv_positions:
                    c["k_pages"] = kv_arrays["k"][kv_i]
                    c["v_pages"] = kv_arrays["v"][kv_i]
                    kv_i += 1
                caches.append(c)
            logits, new_caches = api.decode_step(
                params, cfg, ctx, toks, tuple(caches), lengths,
                block_tables=bt, decode_mask=active, schedule=schedule,
                kv_splits=kv_splits)
            B = toks.shape[0]
            page, off, ok, positions = window_page_coords(
                lengths, bt, K, ps, scratch=scratch, decode_mask=active)
            ks, vs = list(kv_arrays["k"]), list(kv_arrays["v"])
            new_states = []
            for i, kind in enumerate(cfg.block_pattern):
                nc = new_caches[i]
                if i in self.kv.kv_positions:
                    kv_i = self.kv.kv_positions.index(i)
                    ks[kv_i] = nc["k_pages"]
                    vs[kv_i] = nc["v_pages"]
                # recurrent states advance only for slots that really decoded
                sel = {}
                for sk in ("ssm", "mlstm", "slstm"):
                    if sk in states[i]:
                        sel[sk] = jax.tree_util.tree_map(
                            lambda new, old: jnp.where(
                                active.reshape((1, B) + (1,) * (new.ndim - 2)),
                                new, old), nc[sk], states[i][sk])
                new_states.append(sel)
            new_kv = dict(kv_arrays)
            new_kv["k"], new_kv["v"] = tuple(ks), tuple(vs)
            # scratch-routed scatters (inactive slots, no capacity) must
            # write pos -1, never a real position
            new_kv["pos"] = kv_arrays["pos"].at[page, off].set(
                jnp.where(ok, positions, -1))
            return logits, new_kv, tuple(new_states)

        return self._wrap_decode(fn)

    # ------------------------------------------------------------------
    # step phases
    # ------------------------------------------------------------------
    def _pad_len(self, st: RequestState, n_tokens: int) -> int:
        """Bucket-rounded forward-call length for a grant (== n_tokens when
        bucketing is off or the request carries patch embeddings)."""
        if self._buckets is None or st.request.patches is not None:
            return n_tokens
        return round_to_bucket(n_tokens, self._buckets)

    def _run_grant(self, st: RequestState, start: int, n_tokens: int,
                   padded: int, last: bool) -> Optional[int]:
        """Execute one prefill grant; returns the sampled token if ``last``.

        ``padded``: bucket length of the forward call (>= n_tokens); the
        pad tail is zero tokens, masked out of attention and KV scatter."""
        req = st.request
        slot = st.slot
        n_patches = self._eff_extra(req) if start == 0 else 0
        toks_all = self._resident_tokens(st)
        # text tokens covered by this grant (patches occupy the first
        # ``eff_extra`` effective positions of the first grant)
        t0 = max(0, start - self._eff_extra(req)) if req.patches is not None \
            else start
        n_text = n_tokens - n_patches
        buf = np.zeros(padded - n_patches, np.int32)
        buf[:n_text] = toks_all[t0:t0 + n_text]
        tokens = jnp.asarray(buf[None])
        patches = jnp.asarray(req.patches[None]) if n_patches else None

        bt_row = jnp.asarray(self.alloc.block_table(req.rid,
                                                    self.max_blocks)[None])
        states_slot = jax.tree_util.tree_map(
            lambda a: a[:, slot:slot + 1], self.states)
        fn = self._get_prefill(padded - n_patches, n_patches,
                               resumed=start > 0)
        t0_wall = time.perf_counter()
        with self._mesh_ctx(), jaxprof.annotate(f"prefill/T={padded}"):
            logits_last, new_kv, new_states = fn(
                self.params, tokens, patches, self.kv.arrays, states_slot,
                bt_row, jnp.int32(start), jnp.int32(n_tokens))
        # dispatch returns before the device finishes; the timed region must
        # cover EVERY output or prefill_s under-reports (the KV scatter can
        # outlive the logits) — dispatch-only time keeps its own counter
        self.metrics["prefill_dispatch_s"] += time.perf_counter() - t0_wall
        jax.block_until_ready((logits_last, new_kv, new_states))
        dur = time.perf_counter() - t0_wall
        self.metrics["prefill_s"] += dur
        self.metrics["prefill_tokens"] += n_tokens
        self.metrics["prefill_pad_tokens"] += padded - n_tokens
        self.metrics["prefill_calls"] += 1
        self.trace.emit("prefill_call", rid=req.rid, slot=slot, dur=dur,
                        ts=t0_wall, tokens=n_tokens, pad=padded - n_tokens,
                        rows=1)

        self.kv.arrays = new_kv
        self.states = jax.tree_util.tree_map(
            lambda big, new: big.at[:, slot:slot + 1].set(new.astype(big.dtype)),
            self.states, new_states)
        return self._commit_grant_row(
            st, start, n_tokens,
            np.asarray(jax.device_get(logits_last))[0] if last else None, last)

    def _commit_grant_row(self, st: RequestState, start: int, n_tokens: int,
                          logits_row, last: bool) -> Optional[int]:
        """Post-forward bookkeeping for one grant (single or packed row):
        commit tokens to the allocator, advance prefill progress, and — for a
        prompt-finishing grant — sample the first token from ``logits_row``
        ((V,) fp32 of the last real position), stamp TTFT and (re)build the
        speculative self-draft."""
        req = st.request
        slot = st.slot
        self.alloc.commit(req.rid, n_tokens)
        st.prefilled = start + n_tokens
        self.lengths[slot] = st.prefilled
        self.metrics["prefill_grants"] += 1
        self.registry.histogram("grant_size").observe(n_tokens)
        if start > 0:
            self.metrics["resumed_grants"] += 1
        # scheduler-issued grants can be dropped and re-issued (packmate
        # eviction, deferred sharing) — the commit is the countable event
        self.trace.emit("grant_commit", rid=req.rid, slot=slot, start=start,
                        n=n_tokens, last=last)
        if not last:
            return None
        tok = sample(logits_row[:self.cfg.vocab_size], req.sampling,
                     step=len(st.generated))
        self.metrics["prefill_samples"] += 1
        first = st.t_first < 0
        if first:
            st.t_first = time.perf_counter()
            ttft = st.t_first - st.t_submit
            self.metrics["ttft_sum"] += ttft
            self.metrics["ttft_n"] += 1
            self.registry.histogram("ttft").observe(ttft)
        self.trace.emit("sample", rid=req.rid, slot=slot, first=first)
        if self.spec_k:
            # (re)build the self-draft over everything resident — after a
            # recompute preemption that includes the already-generated tokens
            from repro.serving.speculative import BigramDraft
            d = BigramDraft()
            d.observe([int(t) for t in self._resident_tokens(st)] + [int(tok)])
            self._drafts[slot] = d
        st.generated.append(tok)
        self.last_tokens[slot] = tok
        st.finish_check()
        return tok

    def _run_pack(self, group: List[Tuple], padded: int,
                  events: List[Tuple[int, int]]) -> None:
        """Execute a pack of prepped grants as ONE batched forward call.

        ``group``: [(st, start, n_tokens, padded, last), ...] sharing the
        same padded length.  The row count is padded up to a row bucket so
        the jitted closure is keyed on (length bucket, row bucket); pad rows
        carry empty block tables and n_real 0 (fully masked, scratch-routed).
        """
        R = len(group)
        rows = round_to_bucket(R, self._row_buckets)
        T = padded
        toks = np.zeros((rows, T), np.int32)
        starts = np.zeros(rows, np.int32)
        n_reals = np.zeros(rows, np.int32)
        bts = np.full((rows, self.max_blocks), -1, np.int32)
        for r, (st, start, n, _, _last) in enumerate(group):
            toks_all = self._resident_tokens(st)
            toks[r, :n] = toks_all[start:start + n]
            starts[r] = start
            n_reals[r] = n
            bts[r] = self.alloc.block_table(st.request.rid, self.max_blocks)
        fn = self._get_prefill_batched(T, rows,
                                       all_fresh=bool(np.all(starts == 0)))
        t0_wall = time.perf_counter()
        with self._mesh_ctx(), jaxprof.annotate(f"prefill/T={T}x{rows}"):
            logits_last, new_kv = fn(self.params, jnp.asarray(toks),
                                     self.kv.arrays, jnp.asarray(bts),
                                     jnp.asarray(starts), jnp.asarray(n_reals))
        self.metrics["prefill_dispatch_s"] += time.perf_counter() - t0_wall
        jax.block_until_ready((logits_last, new_kv))
        dur = time.perf_counter() - t0_wall
        n_total = int(n_reals.sum())
        self.metrics["prefill_s"] += dur
        self.metrics["prefill_tokens"] += n_total
        self.metrics["prefill_pad_tokens"] += rows * T - n_total
        self.metrics["prefill_pad_rows"] += rows - R
        self.metrics["prefill_calls"] += 1
        self.trace.emit("prefill_call", dur=dur, ts=t0_wall, tokens=n_total,
                        pad=rows * T - n_total, rows=R)
        self.kv.arrays = new_kv
        logits_np = None
        if any(p[4] for p in group):
            logits_np = np.asarray(jax.device_get(logits_last))
        for r, (st, start, n, _, last) in enumerate(group):
            tok = self._commit_grant_row(
                st, start, n, logits_np[r] if last else None, last)
            if tok is not None:
                events.append((st.request.rid, tok))
                if st.done:
                    self._finish(st)

    def _finish(self, st: RequestState) -> None:
        # decode_tokens is tallied where tokens are produced (_decode_phase),
        # NOT here: the prefill-sampled first token is a prefill_samples
        # event, and in-flight requests must not vanish from the count
        self.metrics["completed"] += 1
        self.trace.emit("finish", rid=st.request.rid, slot=st.slot)
        self._release_pages(st.request.rid)
        if self.prefix_cache is not None:
            self.prefix_cache.forget(st.request.rid)
        self.scheduler.forget(st.request.rid)
        self._finished.append(st)
        self._by_rid.pop(st.request.rid, None)
        self.slots[st.slot] = None
        self.lengths[st.slot] = 0
        self.last_tokens[st.slot] = 0
        self._drafts[st.slot] = None
        st.slot = -1

    def _prep_grant(self, g) -> Optional[Tuple]:
        """Per-grant pre-work shared by the batch-1 and packed paths: prefix-
        sharing retry, page allocation growth and copy-on-write (both may
        evict).  Returns (st, start, n_tokens, padded, last) ready to run, or
        None when the grant dissolved (its request was preempted by an
        earlier grant's eviction, or same-step sharing covered it fully)."""
        st = self._by_rid.get(g.rid)
        if st is None or st.slot < 0:
            return None                       # preempted by an earlier grant
        start, end = g.start, g.start + g.n_tokens
        if start == 0 and st.prefilled == 0:
            # retry prefix sharing: a donor granted EARLIER this step (batch-1
            # mode: already ran; packed mode: earlier pack) has committed its
            # first chunks by now
            self._try_share_prefix(st)
            start = st.prefilled
            if end <= start:                  # grant fully covered by sharing
                return None
        if not self._ensure_pages(g.rid, end) or \
                not self._cow_range(g.rid, start, end):
            # unreachable once add_request validated pool capacity; a
            # silent skip here would spin run_until_complete forever
            raise RuntimeError(
                f"page pool too small for request {g.rid}'s prefill chunk "
                f"even after evicting; increase ServingConfig.num_pages")
        # the scheduler owns grant rounding (g.padded); re-round only
        # when same-step prefix sharing shrank the grant, and never pad
        # patch-carrying grants (the scheduler is model-agnostic)
        n = end - start
        if st.request.patches is not None:
            padded = n
        elif start == g.start and n == g.n_tokens:
            padded = g.padded or n
        else:
            padded = self._pad_len(st, n)
        return st, start, n, padded, g.last

    def _prefill_phase(self, events: List[Tuple[int, int]]) -> None:
        # prefill target = sum(chunk_plan): the prompt at admission, or
        # prompt+generated after a recompute preemption
        pending = [(s.request.rid, s.prefilled, s.chunk_plan)
                   for s in self.slots
                   if s is not None and s.prefilled < sum(s.chunk_plan)]
        grants = self.scheduler.grant_prefill(pending)
        if not self._batch_prefill:
            for g in grants:
                prep = self._prep_grant(g)
                if prep is None:
                    continue
                st, start, n, padded, last = prep
                tok = self._run_grant(st, start, n, padded, last)
                if tok is not None:
                    events.append((st.request.rid, tok))
                    if st.done:
                        self._finish(st)
            return
        # packed path: the scheduler groups compatible grants (same padded
        # length, policy order — scheduler.pack_grants); each pack runs as
        # ONE forward call.  Prep runs pack-by-pack in policy order, so
        # eviction/CoW semantics match the sequential path; a prep that
        # evicts a packmate drops it from the pack (slot check below), and
        # same-step sharing that SHRANK a grant re-buckets it into a
        # sub-group of its own padded length.  A fresh grant that could
        # prefix-share with a PACKMATE is deferred to a follow-up sub-pack:
        # sharing adopts only COMMITTED tokens, and packmates commit together
        # after the call — running donor and sharee in one call would
        # silently lose the share that the sequential path gets.
        for pack in self.scheduler.pack_grants(grants,
                                               max_rows=self.max_batch):
            ready, deferred = [], []
            for g in pack:
                if self._defer_for_packmate_sharing(g, ready):
                    self.trace.emit("defer", rid=g.rid)
                    deferred.append(g)
                    continue
                prep = self._prep_grant(g)
                if prep is not None:
                    ready.append(prep)
            self._run_groups(ready, events)
            if deferred:
                # donors committed above; the normal grant-time sharing
                # retry inside _prep_grant now engages for the sharees
                self._run_groups(
                    [p for g in deferred
                     if (p := self._prep_grant(g)) is not None], events)

    def _defer_for_packmate_sharing(self, g, prepped: List[Tuple]) -> bool:
        """True if fresh grant ``g`` shares its first KV page's worth of
        prompt with an earlier member of the SAME pack — the only case where
        packing would lose a prefix share the batch-1 path gets (cross-pack
        donors have committed by the sharee's prep; packmates have not)."""
        if self.prefix_cache is None or not prepped:
            return False
        st = self._by_rid.get(g.rid)
        if st is None or st.slot < 0 or st.prefilled > 0 or g.start != 0:
            return False
        prompt = np.asarray(st.request.prompt, np.int32)
        if len(prompt) < self.ps:
            return False                  # sharing needs a full page match
        head = prompt[:self.ps]
        for p_st, _, _, _, _ in prepped:
            donor = np.asarray(p_st.request.prompt, np.int32)
            if len(donor) >= self.ps and np.array_equal(donor[:self.ps], head):
                return True
        return False

    def _run_groups(self, ready: List[Tuple],
                    events: List[Tuple[int, int]]) -> None:
        """Run prepped grants as packed calls, sub-grouped by their FINAL
        padded length (same-step sharing may have re-bucketed some)."""
        ready = [p for p in ready if p[0].slot >= 0]
        by_len: Dict[int, List[Tuple]] = {}
        for p in ready:
            by_len.setdefault(p[3], []).append(p)
        for padded, group in by_len.items():
            self._run_pack(group, padded, events)

    # accept-length samples the spec gate needs before trusting the
    # histogram mean over the static default (tests monkeypatch this)
    SPEC_GATE_MIN_SAMPLES = 8

    def _spec_window(self, active) -> int:
        """Verify-window width for this decode step: spec_k+1 when every
        active request can speculate (greedy sampling, drafted, and room for
        the whole window below max_len), else 1 (plain decode).  One batched
        call either way — mixed eligibility falls back for the step.

        With a cost model, the gate also weighs the MEASURED K-token verify
        cost against the plain-decode steps it would replace: once the
        ``accept_len`` histogram has enough samples, speculation is skipped
        (K=1) whenever ``verify_cost >= expected_accept * plain_cost``
        (perf/costmodel.CostModel.spec_worth).  Skipping speculation is
        token-neutral — greedy verify == plain decode is the PR 4
        differential invariant — so the gate can only trade speed."""
        if not self.spec_k:
            return 1
        K = self.spec_k + 1
        if self.cost_model is not None:
            hist = self.registry.histogram("accept_len")
            if hist.n >= self.SPEC_GATE_MIN_SAMPLES:
                deepest = pages_for(int(self.lengths.max()) + K, self.ps)
                worth = self.cost_model.spec_worth(K, deepest, hist.mean)
                if worth is False:
                    self.trace.emit("decision", point="spec_gate", chosen=1,
                                    static=K,
                                    expected_accept=float(hist.mean))
                    return 1
        need = 0
        for st in active:
            L = int(self.lengths[st.slot])
            if st.request.sampling.temperature > 0 or \
                    self._drafts[st.slot] is None or L + K > self.max_len:
                return 1
            need += max(0, pages_for(L + K, self.ps)
                        - len(self.alloc.tables.get(st.request.rid, ())))
        # the window must fit WITHOUT eviction: admission only validated the
        # plain-decode watermark, and evicting a request to speculate on
        # another would trade real progress for drafted guesses
        if need > self.alloc.free_pages:
            return 1
        return K

    def _decode_phase(self, events: List[Tuple[int, int]]) -> None:
        active = [s for s in self.slots
                  if s is not None and not s.done and s.generated
                  and s.prefilled >= sum(s.chunk_plan)]
        active = [s for s in active if s.slot >= 0]
        if not active:
            return
        K = self._spec_window(active)
        # grow every decoder's capacity by the window width (may evict; an
        # evicted request drops out of `active` below — filtered by slot, not
        # list.remove, whose __eq__ scan would compare prompt arrays)
        for st in active:
            if st.slot < 0:
                continue
            L = int(self.lengths[st.slot])
            if not self._ensure_pages(st.request.rid, L + K) or \
                    not self._cow_range(st.request.rid, L, L + K):
                raise RuntimeError(
                    f"page pool too small for a {K}-token decode step; "
                    f"increase ServingConfig.num_pages")
        active = [s for s in active if s.slot >= 0]
        if not active:
            return
        B = self.max_batch
        mask = np.zeros(B, bool)
        for st in active:
            mask[st.slot] = True
        bt = np.stack([self.alloc.block_table(s.request.rid, self.max_blocks)
                       if s is not None and mask[i] else
                       np.full(self.max_blocks, -1, np.int32)
                       for i, s in enumerate(self.slots)])
        toks = np.zeros((B, K), np.int32)
        toks[:, 0] = self.last_tokens.astype(np.int32)
        drafts: Dict[int, List[int]] = {}
        if K > 1:
            for st in active:
                i = st.slot
                drafts[i] = self._drafts[i].draft(self.spec_k)
                toks[i, 1:] = drafts[i]
        lens = jnp.asarray(self.lengths.astype(np.int32))
        S = self._kv_splits(K)
        if self._decode_schedule == "batch_split" and len(active) < 2:
            # a single resident request has no second batch half to overlap
            # with — run the sequential closure for this step instead of a
            # batch-split call whose other half is pure scratch work
            decode_fn = self._get_fallback_decode(K, S)
            self.trace.emit("decision", point="decode_schedule",
                            fallback=1, active=len(active), k=int(K))
        else:
            decode_fn = self._get_decode(K, S)
        t0 = time.perf_counter()
        with self._mesh_ctx(), jaxprof.annotate(f"decode/K={K}/S={S}"):
            logits, new_kv, new_states = decode_fn(
                self.params, jnp.asarray(toks), jnp.asarray(bt), lens,
                self.kv.arrays, self.states, jnp.asarray(mask))
        # fence EVERY output inside the timed region: the logits transfer
        # below would otherwise hide the KV-scatter tail and decode_s would
        # report dispatch time (the async view keeps its own counter)
        self.metrics["decode_dispatch_s"] += time.perf_counter() - t0
        jax.block_until_ready((logits, new_kv, new_states))
        dur = time.perf_counter() - t0
        logits = np.asarray(jax.device_get(logits))
        self.metrics["decode_s"] += dur
        self.metrics["decode_calls"] += 1
        self.trace.emit("decode_call", dur=dur, ts=t0, k=K, active=len(active))
        if K > 1:
            self.metrics["spec_calls"] += 1
        self.kv.arrays = new_kv
        self.states = new_states

        rollback: List[Tuple[int, int]] = []      # (page, offset) to unmap
        for st in active:
            i = st.slot
            if K == 1:
                acc = [sample(logits[i, 0][:self.cfg.vocab_size],
                              st.request.sampling, len(st.generated))]
                if self._drafts[i] is not None:
                    # keep the draft's anchor/table fresh across speculation
                    # fallbacks, or re-engaging verifies a stale successor
                    self._drafts[i].observe([int(acc[0])])
            else:
                # greedy accept: longest matching prefix of the drafted
                # window, plus the model's bonus token when all drafts hit
                from repro.serving.speculative import accept_greedy
                argmaxes = logits[i, :, :self.cfg.vocab_size].argmax(axis=-1)
                budget = st.request.sampling.max_new_tokens - len(st.generated)
                acc = accept_greedy(drafts[i], argmaxes)[:max(budget, 1)]
                self.metrics["spec_tokens"] += len(acc)
                self.registry.histogram("accept_len").observe(len(acc))
                self._drafts[i].observe([int(t) for t in acc])
                # rejected window positions: their KV was scattered but they
                # are NOT committed — invalidate their pos entries so no
                # pos-driven consumer can ever see them as live
                L = int(self.lengths[i])
                table = self.alloc.tables[st.request.rid]
                for pos in range(L + len(acc), L + K):
                    rollback.append((table[pos // self.ps], pos % self.ps))
            self.alloc.commit(st.request.rid, len(acc))
            self.metrics["decode_tokens"] += len(acc)
            self.trace.emit("accept", rid=st.request.rid, slot=i, n=len(acc),
                            spec=K > 1)
            self.registry.histogram("tpot").observe(dur / len(acc))
            for tok in acc:
                st.generated.append(int(tok))
                events.append((st.request.rid, int(tok)))
            self.lengths[i] += len(acc)
            self.last_tokens[i] = int(acc[-1])
            st.finish_check()
            if st.done:
                self._finish(st)
        if rollback:
            self.trace.emit("spec_rollback", n=len(rollback))
            pg = jnp.asarray([p for p, _ in rollback], jnp.int32)
            off = jnp.asarray([o for _, o in rollback], jnp.int32)
            new_kv = dict(self.kv.arrays)
            new_kv["pos"] = new_kv["pos"].at[pg, off].set(-1)
            self.kv.arrays = new_kv

    # ------------------------------------------------------------------
    def step(self) -> List[Tuple[int, int]]:
        """One engine iteration: admission -> budgeted prefill chunks ->
        batched decode.  Returns (rid, token) events."""
        events: List[Tuple[int, int]] = []
        self.metrics["steps"] += 1
        if self.scheduler.runs_prefill:
            self._admit()
            self._prefill_phase(events)
        if self.scheduler.runs_decode:
            self._decode_phase(events)
        used = self.alloc.used_pages
        frag = self.alloc.fragmentation()
        self.registry.gauge("pool_occupancy").set(used)
        self.registry.gauge("free_list_fragmentation").set(frag)
        self.metrics["peak_used_pages"] = max(self.metrics["peak_used_pages"],
                                              used)
        self.trace.emit("pool", used=used, free=self.alloc.free_pages,
                        frag=frag)
        return events

    def run_until_complete(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            self.step()
            if not self.scheduler.waiting and \
                    all(s is None for s in self.slots):
                break
        for st in self._finished:
            out[st.request.rid] = st.generated
        return out

    # ------------------------------------------------------------------
    # disaggregated serving: detach / attach (serving/disagg.py)
    # ------------------------------------------------------------------
    def detach_requests(self, rids: List[int]) -> "Any":
        """Export ``rids``' KV pages + lifecycle state as a ``PageTransfer``
        and REMOVE the requests from this engine (slots cleared, pages freed,
        scheduler/prefix-cache entries dropped).

        The requests must be resident (slot >= 0) with their prompts fully
        committed — the disagg router migrates exactly that set.  Pages shared
        across the detached group are exported once (sharing survives the
        move); pages shared with a request that STAYS are copied by the
        export, and the stayer keeps its originals.  The transfer is pure
        host state — numpy payloads, plain-python records — so the receiving
        engine can live on another mesh."""
        from repro.serving.disagg import PageTransfer, RequestRecord
        t0 = time.perf_counter()
        blob = self.pool.export_pages(rids)
        records = []
        for rid in rids:
            st = self._by_rid[rid]
            slot = st.slot
            assert slot >= 0, f"detach of non-resident request {rid}"
            assert st.prefilled >= sum(st.chunk_plan), \
                f"detach of mid-prefill request {rid}"
            d = self._drafts[slot]
            records.append(RequestRecord(
                request=st.request, generated=list(st.generated),
                prompt_len=st.prompt_len, prefilled=st.prefilled,
                chunk_plan=tuple(st.chunk_plan), t_submit=st.t_submit,
                t_first=st.t_first, last_token=int(self.last_tokens[slot]),
                draft_table=dict(d.table) if d is not None else None,
                draft_last=d.last if d is not None else -1))
            self.trace.emit("detach", rid=rid, slot=slot)
            self._release_pages(rid)
            if self.prefix_cache is not None:
                self.prefix_cache.forget(rid)
            self.scheduler.forget(rid)
            self._by_rid.pop(rid, None)
            self.slots[slot] = None
            self.lengths[slot] = 0
            self.last_tokens[slot] = 0
            self._drafts[slot] = None
            st.slot = -1
        us = (time.perf_counter() - t0) * 1e6
        # one span per transfer, n = DISTINCT pages moved (a page shared by
        # several detached requests counts once) — replay reconstructs
        # migrations/migrated_pages from exactly these events
        self.trace.emit("migrate", n=blob["n_pages"], rids=len(rids), us=us)
        self.metrics["migrations"] += 1
        self.metrics["migrated_pages"] += blob["n_pages"]
        self.metrics["migration_us"] += us
        return PageTransfer(records=records, blob=blob)

    def attach_requests(self, transfer: "Any") -> None:
        """Adopt a ``PageTransfer``: import its pages into this pool and
        install the requests into free slots, decode-ready.

        Raises ``OutOfPages`` — atomically, nothing mutated — when the free
        list can't host the transfer's distinct pages; the router keeps the
        transfer queued and retries (defer-and-retry, never preemption: an
        attach must not evict a decode-resident request to make room).
        Free slots must cover the records (the router checks first)."""
        free = [i for i, s in enumerate(self.slots) if s is None]
        assert len(free) >= len(transfer.records), \
            (len(free), len(transfer.records))
        t0 = time.perf_counter()
        self.pool.import_pages(transfer.blob)   # may raise OutOfPages: atomic
        from repro.serving.speculative import BigramDraft
        for rec in transfer.records:
            rid = rec.request.rid
            slot = free.pop(0)
            st = RequestState(request=rec.request, slot=slot,
                              generated=list(rec.generated),
                              prompt_len=rec.prompt_len,
                              t_submit=rec.t_submit)
            st.prefilled = rec.prefilled
            st.chunk_plan = tuple(rec.chunk_plan)
            st.t_first = rec.t_first
            self.slots[slot] = st
            self._by_rid[rid] = st
            # committed tokens came over in the blob's lengths; the first
            # generated token is NOT in KV yet (it is the next decode input)
            self.lengths[slot] = self.alloc.tokens(rid)
            self.last_tokens[slot] = rec.last_token
            if self.spec_k and rec.draft_table is not None:
                d = BigramDraft()
                d.table = dict(rec.draft_table)
                d.last = rec.draft_last
                self._drafts[slot] = d
            # arrival bookkeeping without queueing: pick_victim/order need a
            # key for migrated-in rids (router attaches in policy order)
            self.scheduler.register(rid, priority=rec.request.priority)
            self.trace.emit("attach", rid=rid, slot=slot)
        self.metrics["migration_us"] += (time.perf_counter() - t0) * 1e6

    def accepted_per_call(self) -> float:
        """Mean tokens emitted per speculative verify call (>= 1 once any
        verify ran; 0.0 when speculation never triggered).  The accept-rate
        metric tracked per push by benchmarks/ci_smoke.py."""
        if not self.metrics["spec_calls"]:
            return 0.0
        return self.metrics["spec_tokens"] / self.metrics["spec_calls"]

    # ------------------------------------------------------------------
    def page_stats(self) -> Dict[str, Any]:
        s = self.alloc.stats()
        s["kv_bytes_live"] = self.kv.kv_bytes(self.alloc)
        s["kv_bytes_reserved"] = self.kv.total_bytes()
        return s

"""Configuration system for the ISO reproduction framework.

Frozen dataclasses so configs are hashable (usable as jit static args) and a
string registry so launchers can select ``--arch <id>``.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

# Block kinds understood by models/decoder.py.
BLOCK_ATTN_MLP = "attn_mlp"          # classic transformer block
BLOCK_ATTN_MOE = "attn_moe"          # attention + MoE FFN
BLOCK_HYBRID = "hybrid"              # parallel attention + mamba heads (hymba)
BLOCK_MLSTM = "mlstm"                # xLSTM matrix-memory block
BLOCK_SLSTM = "slstm"                # xLSTM scalar-memory block


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # experts padded up so the expert axis shards over the model axis
    shared_expert_d_ff: int = 0      # optional dense shared expert (granite/kimi style)

    def padded_experts(self, shards: int) -> int:
        return int(math.ceil(self.num_experts / shards) * shards)


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16              # per-channel recurrent state (mamba N)
    conv_dim: int = 4                # depthwise conv width (stubbed as identity-ish proj)
    expand: int = 2                  # inner expansion factor


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    block_pattern: Tuple[str, ...] = (BLOCK_ATTN_MLP,)  # tiled over layers
    qk_norm: bool = False
    rope_theta: float = 1e6
    rms_eps: float = 1e-6
    norm_type: str = "rms"           # rms | ln
    mlp_type: str = "swiglu"         # swiglu | gelu
    pos_type: str = "rope"           # rope | sinusoidal | none
    tie_embeddings: bool = False
    sliding_window: int = 0          # 0 = full attention; >0 enables window variant
    attn_impl: str = "dense"         # dense | blockwise (flash-style XLA scan)
    attn_block_k: int = 1024
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_frames: int = 1500       # stub frontend sequence length
    # vlm
    num_patches: int = 0             # stub vision tokens prepended to text
    # residual-stream wiring: "standard" (block k reads the residual as of
    # block k-1) or "ladder" (Ladder-residual, PAPERS.md arXiv 2501.06589:
    # stage k reads the residual as of stage k-2, so stage k-1's TP
    # all-reduce completes behind stage k's compute).  Ladder is a DIFFERENT
    # model function — a train-from-scratch/adapted architecture, not a
    # schedule — and applies to prefill and decode consistently
    # (core/iso.run_layer ladder=True / run_stack_decode_ladder).  Build
    # ladder twins of registered configs with ``ladder_variant``.
    residual_wiring: str = "standard"
    source: str = ""                 # citation bracket from the assignment

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def block_kind(self, layer_idx: int) -> str:
        return self.block_pattern[layer_idx % len(self.block_pattern)]

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    # ---- parameter counting (for roofline MODEL_FLOPS = 6 N D) ----
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        mlp_mats = 3 if self.mlp_type == "swiglu" else 2
        for l in range(self.num_layers):
            kind = self.block_kind(l)
            if kind in (BLOCK_ATTN_MLP, BLOCK_ATTN_MOE, BLOCK_HYBRID,
                        "dec_block"):
                attn = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
                total += attn
            if kind == "dec_block":         # cross-attention + MLP
                total += d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
                total += mlp_mats * d * self.d_ff
            if kind == BLOCK_ATTN_MLP:
                total += mlp_mats * d * self.d_ff
            elif kind == BLOCK_ATTN_MOE:
                m = self.moe
                n_e = m.top_k if active_only else m.num_experts
                total += 3 * d * m.d_ff_expert * n_e
                total += d * m.num_experts            # router
                if m.shared_expert_d_ff:
                    total += 3 * d * m.shared_expert_d_ff
            elif kind == BLOCK_HYBRID:
                s = self.ssm
                inner = s.expand * d
                total += d * inner * 2 + inner * d + inner * (2 * s.state_dim + 1)
                total += 3 * d * self.d_ff
            elif kind == BLOCK_MLSTM:
                inner = 2 * d
                total += d * inner * 3 + inner * d + 3 * d * inner // 2
            elif kind == BLOCK_SLSTM:
                total += 4 * d * d + 4 * d * d  # recurrent + input gates
            total += 2 * d  # norms
        for _ in range(self.encoder_layers):
            total += 4 * d * d + 2 * d * self.d_ff + 2 * d
        return int(total)


# ---------------------------------------------------------------------------
# Parallelism / runtime configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParallelConfig:
    data: int = 16
    model: int = 16
    pods: int = 1                    # >1 adds the leading "pod" axis
    seq_parallel: bool = False       # beyond-paper: RS+AG instead of AR

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.pods > 1 else ("data", "model")

    @property
    def mesh_shape(self) -> Tuple[int, ...]:
        if self.pods > 1:
            return (self.pods, self.data, self.model)
        return (self.data, self.model)

    @property
    def batch_axes(self):
        return ("pod", "data") if self.pods > 1 else ("data",)

    @property
    def num_devices(self) -> int:
        return self.pods * self.data * self.model


@dataclass(frozen=True)
class ISOConfig:
    """The paper's technique, as a first-class runtime feature."""
    enabled: bool = True
    num_chunks: int = 2              # paper: 2; >2 is our beyond-paper extension
    split_fractions: Tuple[float, ...] = ()   # empty -> policy decides
    split_policy: str = "even"       # even | asymmetric | adaptive | auto
    quantized_comm: bool = False     # int8 collectives (paper's 4090 path)
    min_chunk_tokens: int = 256      # below this, ISO is skipped (decode etc.)
    chunk_align: int = 128           # chunk-length multiple (MXU alignment)


@dataclass(frozen=True)
class ServingConfig:
    """Paged-KV continuous-batching engine (serving/paged_engine.py).

    The scheduler admits requests by splitting their prompts with
    ``core/chunking.split_chunks`` (the ISO chunk is the scheduling quantum)
    and interleaves prefill chunks with batched decode under a per-step
    prefill token budget (Sarathi-style chunked prefill)."""
    page_size: int = 16              # tokens per KV page
    num_pages: int = 0               # 0 -> max_batch * ceil(max_len/page_size)
    prefill_token_budget: int = 512  # max prefill tokens per engine step
    scheduler_policy: str = "fcfs"   # fcfs | priority
    max_batch: int = 8               # decode batch width (slot count)
    max_len: int = 512               # per-request token capacity
    # TP decode: batch-split ISO schedule — each half's all-reduce hides
    # behind the other half's attention (core/iso.run_stack_decode_overlap)
    decode_overlap: bool = True
    # copy-on-write prefix sharing: requests with a common prompt prefix map
    # the same KV pages (refcounted); attention-only stacks, off for
    # recurrent families (their per-slot state cannot be shared)
    prefix_sharing: bool = True
    # grant-size bucketing: pad every prefill grant up to a bucket length
    # (powers of two by default — core/chunking.grant_buckets) so the engine
    # compiles O(#buckets) prefill closures instead of one per distinct grant
    # length.  Padded tail tokens are masked out of attention and KV scatter.
    # Attention-only stacks; recurrent families run unbucketed (pad tokens
    # would advance their SSM/xLSTM state).
    grant_bucketing: bool = True
    grant_buckets: Tuple[int, ...] = ()   # empty -> power-of-two ladder
    min_grant_bucket: int = 16
    # batched multi-request prefill grants: grants sharing a (bucket-padded)
    # length are packed into ONE forward call per scheduler tick instead of
    # N batch-1 calls — the prefill-phase analogue of batched decode
    # (TokenWeave: batch tokens across requests before overlapping
    # communication).  Per-row pos_offset/prefix_len/valid_len ride through
    # StageCtx into the paged flash-prefill kernel; compiled closures are
    # keyed on (bucket, row-bucket).  Attention-only stacks without patch
    # embeddings (recurrent families stay batch-1: their per-slot state
    # cannot be stacked under heterogeneous grant lengths).  NOTE: for MoE
    # stacks, router capacity is computed over the PACKED token set, so
    # under tight capacity_factor drops may differ from batch-1 (the
    # standard batched-MoE serving semantics).
    prefill_batching: bool = True
    # speculative decoding (paper §Discussion): greedy-only self-drafting.
    # spec_k > 0 verifies a (spec_k+1)-token window [last, d1..d_k] per slot
    # through the paged flash-decode kernel; accepted tokens commit, rejected
    # window positions roll back by pos invalidation.  Attention-only stacks
    # (a K-token step would advance recurrent SSM/xLSTM state K times).
    spec_k: int = 0
    # split-KV (sequence-parallel) flash-decode: partition each request's page
    # walk into S contiguous spans computed as independent grid steps, folded
    # by a partial-softmax reduce kernel (kernels/flash_decode.py).  0 = auto
    # (split by decode_split_factor only when the deepest resident request
    # spans >= decode_split_min_pages pages), 1 = sequential walk, >1 forces
    # that split count.  Decode closures are compile-keyed on (K, S).
    decode_kv_splits: int = 0
    decode_split_factor: int = 4     # S chosen when auto mode decides to split
    decode_split_min_pages: int = 16 # auto splits only at/past this page depth
    # decode collective schedule (core/iso.py).  "auto": batch_split under a
    # mesh with decode_overlap on (max_batch >= 2), sequential otherwise.
    # Explicit values force one of "sequential" | "batch_split" |
    # "cross_block" (deferred reduces resolve at the next stage top, riding
    # the scan carry across block boundaries — token-identical to
    # sequential, built for the latency-hiding scheduler below).  Ladder-
    # wired configs (ModelConfig.residual_wiring="ladder") ignore this: the
    # wiring fixes the driver, and ``decode_overlap`` picks deferred vs
    # immediate collectives inside it.
    decode_schedule: str = "auto"
    # append the XLA async-collective / latency-hiding-scheduler flag recipe
    # (SNIPPETS.md set_platform) to XLA_FLAGS via
    # launch/mesh.enable_latency_hiding.  ONLY effective when set before the
    # first jax backend init — launch/serve.py applies it right after arg
    # parsing; engines cannot apply it retroactively.
    latency_hiding: bool = False
    # observability (src/repro/obs): the typed metrics registry is ALWAYS on
    # (counter bumps are host-side nanoseconds); this flag gates the
    # structured trace-event ring (scheduler/allocator/engine narration,
    # exportable as a Chrome/Perfetto trace — docs/observability.md)
    observability: bool = True
    trace_events: int = 65536        # trace ring capacity (oldest dropped)
    # measured cost model (perf/costmodel.py): a profiled alpha-beta +
    # kernel-timing table that lets the engine/scheduler CHOOSE split counts,
    # chunk sizes, pack widths and the spec gate instead of obeying the
    # static defaults above.  ``cost_table`` is "" (off), "auto" (the bundled
    # per-platform table under perf/tables/) or an explicit path; any load
    # failure — missing file, malformed table, wrong platform/mesh — falls
    # back to the static defaults with one ``warning`` trace event.
    # ``cost_model`` injects an already-built CostModel directly (tests,
    # autotune --verify); excluded from hash/eq so Config stays usable as a
    # jit static arg.
    cost_table: str = ""
    cost_model: Optional[object] = field(default=None, compare=False,
                                         repr=False, hash=False)
    # disaggregated prefill/decode serving (serving/disagg.py): run TWO
    # PagedEngines — one that only prefills, one that only decodes — with a
    # page-migration protocol in between (a finished-prefill request's pages,
    # block table, pos metadata, generated tokens and draft state ship to the
    # decode pool as a PageTransfer).  The phases have opposite compute/
    # communication profiles, so production fleets split them onto separate
    # replicas; single-process/two-mesh here so the differential battery can
    # prove token equality.  Attention-only stacks (recurrent per-slot state
    # does not migrate yet).
    disagg: bool = False
    # decode-side pool pages (0 = same sizing rule as ``num_pages``); the
    # prefill side keeps ``num_pages``.  A full decode pool DEFERS migration
    # (requests queue on the prefill side, bounded-backoff retry) — it never
    # preempts a decode-resident request and never loses tokens.
    decode_pool_pages: int = 0
    # max requests migrated per router step (0 = every ready request);
    # batching migrations preserves CoW sharing among the batch — pages
    # shared by two migrating requests transfer ONCE.
    migrate_batch: int = 0


@dataclass(frozen=True)
class RuntimeConfig:
    mode: str = "serve"              # serve | train
    dtype: str = "bfloat16"
    seq_len: int = 4096
    global_batch: int = 256
    # training
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    max_steps: int = 1000
    grad_clip: float = 1.0
    remat: bool = True
    grad_comm_int8: bool = False     # int8 data-parallel gradient all-reduce
    zero1: bool = False              # shard optimizer state over the data axis
    unroll_layers: bool = False      # unroll the layer loop (dry-run cost probes)
    # serving
    max_decode_steps: int = 64
    page_size: int = 256


@dataclass(frozen=True)
class Config:
    model: ModelConfig
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    iso: ISOConfig = field(default_factory=ISOConfig)
    runtime: RuntimeConfig = field(default_factory=RuntimeConfig)
    serving: ServingConfig = field(default_factory=ServingConfig)

    def replace(self, **kw) -> "Config":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Padding helpers (TP divisibility — see DESIGN.md §4)
# ---------------------------------------------------------------------------

def pad_to_multiple(x: int, m: int) -> int:
    return int(math.ceil(x / m) * m) if m > 1 else x


def padded_vocab(cfg: ModelConfig, shards: int) -> int:
    return pad_to_multiple(cfg.vocab_size, max(shards * 128, 2048))


def padded_heads(n_heads: int, shards: int) -> int:
    return pad_to_multiple(n_heads, shards)


def effective_kv_heads(n_kv: int, shards: int) -> int:
    """vLLM GQA rule: replicate KV heads up to the TP degree when tp > kv."""
    if n_kv >= shards:
        return pad_to_multiple(n_kv, shards)
    return shards


def padded_ff(d_ff: int, shards: int) -> int:
    return pad_to_multiple(d_ff, shards * 128) if d_ff else 0


# ---------------------------------------------------------------------------
# Input shape assignments
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def ladder_variant(cfg: ModelConfig, name: str = "") -> ModelConfig:
    """Ladder-residual twin of a standard-wired config: same shapes and
    parameter layout, residual stream rewired (``residual_wiring="ladder"``)
    so each stage's TP all-reduce hides behind the next stage's compute.
    Attention-style stacks only — every stage must end in a reduce
    (models/blocks.pattern_all_reduces)."""
    from repro.models.blocks import pattern_all_reduces
    assert cfg.residual_wiring == "standard", cfg.name
    assert all(k in (BLOCK_ATTN_MLP, BLOCK_ATTN_MOE) for k in
               cfg.block_pattern) and pattern_all_reduces(cfg.block_pattern), \
        f"ladder wiring needs an all-reducing attention stack: {cfg.name}"
    return dataclasses.replace(cfg, name=name or f"ladder-{cfg.name}",
                               residual_wiring="ladder")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_model_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (populates the registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs():
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)

"""PartitionSpec rules: Megatron TP + data(+pod) parallel + expert parallel.

Layers stay sharding-agnostic; models apply ``maybe_shard`` constraints with the
specs produced here.  When no mesh is active (CPU unit tests) everything no-ops.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.config import ParallelConfig


def _mesh_active() -> bool:
    try:
        m = jax.sharding.get_abstract_mesh()
        return m is not None and not m.empty
    except Exception:
        return False


def maybe_shard(x, spec: Optional[P]):
    """with_sharding_constraint if a mesh is active, else identity."""
    if spec is None or not _mesh_active():
        return x
    return jax.lax.with_sharding_constraint(x, spec)


@dataclass(frozen=True)
class Shardings:
    """Activation + weight PartitionSpecs for one ParallelConfig."""
    batch: Tuple[str, ...]           # ("pod","data") or ("data",)
    tp: str = "model"
    seq_parallel: bool = False

    # ---- activations ----
    @property
    def act(self) -> P:              # (batch, seq, d_model) replicated hidden
        return P(self.batch, None, None)

    @property
    def act_sp(self) -> P:           # sequence-parallel residual stream
        return P(self.batch, self.tp, None)

    @property
    def act_heads(self) -> P:        # (batch, seq, heads, head_dim)
        return P(self.batch, None, self.tp, None)

    @property
    def act_ff(self) -> P:           # (batch, seq, d_ff) column-parallel
        return P(self.batch, None, self.tp)

    @property
    def logits(self) -> P:           # (batch, seq, vocab)
        return P(self.batch, None, self.tp)

    @property
    def kv_cache(self) -> P:         # (batch, seq, kv_heads_eff, head_dim)
        return P(self.batch, None, self.tp, None)

    @property
    def kv_cache_seq(self) -> P:     # long-context batch=1: shard the seq dim
        return P(None, self.tp, None, None)

    @property
    def ssm_state(self) -> P:        # (batch, inner, state) — inner column-parallel
        return P(self.batch, self.tp, None)

    # ---- weights ----
    @property
    def w_col(self) -> P:            # (d_model, sharded_out)
        return P(None, self.tp)

    @property
    def w_row(self) -> P:            # (sharded_in, d_model)
        return P(self.tp, None)

    @property
    def w_replicated(self) -> P:
        return P()

    @property
    def embed(self) -> P:            # (vocab, d_model) vocab-sharded
        return P(self.tp, None)

    @property
    def w_expert_col(self) -> P:     # (experts, d_model, d_ff)
        return P(self.tp, None, None)

    @property
    def w_expert_row(self) -> P:     # (experts, d_ff, d_model)
        return P(self.tp, None, None)

    @property
    def norm(self) -> P:
        return P(None)


def make_shardings(parallel: ParallelConfig) -> Shardings:
    return Shardings(batch=parallel.batch_axes, seq_parallel=parallel.seq_parallel)


def param_spec_tree(params, shardings: Shardings, spec_fn):
    """Map a spec-assignment function over a param pytree (used by launchers)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, x: spec_fn(path, x, shardings), params
    )

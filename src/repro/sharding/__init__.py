from repro.sharding.specs import Shardings, make_shardings, maybe_shard  # noqa: F401

"""Measured alpha-beta cost model: profiled tables drive serving decisions.

ISO's core decision — where to split work so compute hides communication —
was static config until this module: ``decode_split_factor``,
``decode_split_min_pages``, ``min_grant_bucket``-sized chunks, pack widths
and the spec-K gate were all hand-tuned defaults.  The cost model replaces
the constants with MEASUREMENTS, the way "Demystifying the Communication
Characteristics for Distributed Transformer Models" profiles collectives:

  * ``measure_alpha_beta`` — timed psum sweeps over message sizes, fenced
    with the PR-6 timing discipline (``block_until_ready`` inside the timed
    region), least-squares fit of  ``t(n) = alpha + beta * n``  where alpha
    is the collective's latency and beta its inverse bandwidth;
  * ``measure_prefill_buckets`` — wall time of the engine's real jitted
    prefill closures per (grant bucket x row bucket);
  * ``measure_decode_depths`` — wall time of the decode closures per
    (K, S) over page-depth buckets (K = verify-window width, S = split-KV
    span count).

``autotune`` packages the three sweeps into a VERSIONED per-platform JSON
table (``src/repro/perf/tables/<platform>_tp<tp>.json``), and ``CostModel``
turns a loaded table into the four serving decisions:

  * ``decode_splits``  — S for the flash-decode page walk, by modeled
    critical-path time instead of the fixed depth threshold;
  * ``grant_cap``      — prefill chunk size (tokens per grant), by modeled
    time-per-token over the bucket ladder;
  * ``pack_rows``      — pack width for batched prefill grants, by modeled
    time-per-grant over the row ladder;
  * ``spec_worth``     — speculate or not, modeled verify cost vs expected
    accept length (from the PR-6 ``accept_len`` histogram).

Every decision degrades gracefully: no table, a table for a different
platform/mesh, or a malformed table falls back to the static defaults with
a single ``warning`` trace event, and each model-driven decision is logged
as a ``decision`` trace event (point, chosen, static, inputs) so the replay
oracle and the Perfetto export show WHY a split was chosen.  Decisions are
pure table lookups — no wall-clock reads — so identical table + traffic
yields an identical decision sequence (tests/test_costmodel.py pins this).

    python -m repro.perf.costmodel --validate table.json   # schema check
"""
from __future__ import annotations

import json
import math
import os
import statistics
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

SCHEMA = "costmodel-v1"
TABLES_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "tables")

# message sizes (bytes) for the alpha-beta psum sweep
AB_SIZES = (1 << 10, 1 << 13, 1 << 16, 1 << 19, 1 << 21)
AB_SIZES_SMOKE = (1 << 10, 1 << 16, 1 << 20)


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------

def _median_fenced(call, iters: int, warmup: int) -> float:
    """PR-6 timing discipline: the timed region fences on EVERY output, so
    the measurement is execution time, never dispatch time."""
    import jax
    for _ in range(max(1, warmup)):
        jax.block_until_ready(call())
    samples = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        samples.append(time.perf_counter() - t0)
    return statistics.median(samples)


def fit_linear(samples: Sequence[Tuple[float, float]]) -> Tuple[float, float, float]:
    """Least-squares fit ``t = alpha + beta * x`` over (x, t) samples.

    Returns (alpha, beta, r2); alpha is clamped at >= 0 (a negative
    intercept is measurement noise, and a negative latency would make every
    downstream time estimate nonsense).  Degenerate inputs (one point, or
    all x equal) fit beta = 0.
    """
    xs = [float(x) for x, _ in samples]
    ts = [float(t) for _, t in samples]
    n = len(xs)
    assert n >= 1, "fit_linear needs at least one sample"
    mx, mt = sum(xs) / n, sum(ts) / n
    sxx = sum((x - mx) ** 2 for x in xs)
    if n < 2 or sxx == 0.0:
        return max(0.0, mt), 0.0, 1.0
    sxt = sum((x - mx) * (t - mt) for x, t in zip(xs, ts))
    beta = sxt / sxx
    alpha = mt - beta * mx
    stt = sum((t - mt) ** 2 for t in ts)
    if stt == 0.0:
        r2 = 1.0
    else:
        ss_res = sum((t - (alpha + beta * x)) ** 2 for x, t in zip(xs, ts))
        r2 = 1.0 - ss_res / stt
    return max(0.0, alpha), max(0.0, beta), r2


def measure_alpha_beta(mesh=None, axis: str = "model",
                       sizes: Sequence[int] = AB_SIZES,
                       iters: int = 8, warmup: int = 3) -> Dict[str, Any]:
    """Profile the mesh's all-reduce: latency (alpha, s) and inverse
    bandwidth (beta, s/byte) from a timed psum sweep over message sizes.

    With a mesh, each probe is a replicated ``psum`` over ``axis`` inside
    ``shard_map`` — the same collective the serving stack issues.  Without
    one (single-device), there is no wire: the sweep times a jitted
    element-wise touch of the same buffers, so alpha captures dispatch
    latency and beta the memory-system inverse bandwidth — a degenerate but
    honest stand-in that keeps the table schema identical across platforms.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat

    dtype = jnp.float32
    isz = jnp.zeros((), dtype).itemsize
    samples = []
    raw = []
    for nbytes in sizes:
        n = max(1, int(nbytes) // isz)
        x = jnp.zeros((n,), dtype)
        if mesh is not None:
            fn = jax.jit(compat.shard_map(
                lambda v: jax.lax.psum(v, axis), mesh=mesh,
                in_specs=P(), out_specs=P(), check_vma=False))

            def call(fn=fn, x=x):
                with mesh:
                    return fn(x)
        else:
            fn = jax.jit(lambda v: v + jnp.float32(1.0))

            def call(fn=fn, x=x):
                return fn(x)
        t = _median_fenced(call, iters, warmup)
        actual = n * isz
        samples.append((actual, t))
        raw.append({"bytes": int(actual), "t_s": t})
    alpha, beta, r2 = fit_linear(samples)
    return {"alpha_s": alpha, "beta_s_per_byte": beta, "r2": r2,
            "collective": "psum" if mesh is not None else "local",
            "samples": raw}


def measure_prefill_buckets(engine, buckets: Optional[Sequence[int]] = None,
                            rows: Optional[Sequence[int]] = None,
                            iters: int = 3, warmup: int = 1
                            ) -> Dict[str, float]:
    """Wall time (us) of the engine's real jitted prefill closures per
    (grant bucket x row bucket), keyed ``"<T>x<R>"``.

    Inputs are synthetic (zero tokens, fake block tables over real pool
    pages, one-page resident prefix so the paged kernel path is exercised);
    outputs are fenced and DISCARDED — the engine's KV/state arrays are
    never reassigned, so the probe leaves the engine untouched.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    buckets = tuple(buckets if buckets is not None
                    else (engine._buckets or (engine.sv.prefill_token_budget,)))
    rows = tuple(rows if rows is not None else engine._row_buckets)
    ps, MB = engine.ps, engine.max_blocks
    out: Dict[str, float] = {}
    for T in buckets:
        for R in rows:
            if R > 1 and not engine._batch_prefill:
                continue
            toks = jnp.zeros((R, T), jnp.int32)
            # every row resumes after a one-page resident prefix, through a
            # fake block table over the first pool pages (outputs discarded)
            need = -(-(ps + T) // ps)
            if R * need > engine.alloc.num_pages or need > MB:
                continue
            bt = np.full((R, MB), -1, np.int32)
            for r in range(R):
                bt[r, :need] = np.arange(r * need, (r + 1) * need,
                                         dtype=np.int32)
            starts = jnp.full((R,), ps, jnp.int32)
            n_reals = jnp.full((R,), T, jnp.int32)
            bt_j = jnp.asarray(bt)
            if engine._batch_prefill:
                fn = engine._get_prefill_batched(T, R, all_fresh=False)

                def call():
                    with engine._mesh_ctx():
                        return fn(engine.params, toks, engine.kv.arrays,
                                  bt_j, starts, n_reals)
            else:
                fn = engine._get_prefill(T, 0, resumed=True)
                tk1 = jnp.zeros((1, T), jnp.int32)

                def call():
                    with engine._mesh_ctx():
                        return fn(engine.params, tk1, None, engine.kv.arrays,
                                  jax.tree_util.tree_map(
                                      lambda a: a[:, :1], engine.states),
                                  bt_j[:1], jnp.int32(ps), jnp.int32(T))
            out[f"{T}x{R}"] = _median_fenced(call, iters, warmup) * 1e6
    return out


def measure_decode_depths(engine, Ks: Sequence[int] = (1,),
                          Ss: Sequence[int] = (1, 2, 4),
                          depths: Sequence[int] = (2, 8),
                          iters: int = 3, warmup: int = 1
                          ) -> Dict[str, float]:
    """Wall time (us) of decode closures per (K, S) over page-depth buckets,
    keyed ``"<K>/<S>/<pages>"``.  K is the verify-window width (1 = plain
    decode, spec_k+1 = speculative verify), S the split-KV span count, depth
    the resident page count per request.  Closures are built directly
    (``_build_decode_fn``) and cached locally — ``engine._decode_fns`` stays
    pinned to real traffic for the CI compile-guard lane."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    B, ps, MB = engine.max_batch, engine.ps, engine.max_blocks
    fns: Dict[Tuple[int, int], Any] = {}
    out: Dict[str, float] = {}
    for depth in depths:
        d = min(int(depth), MB, max(1, engine.alloc.num_pages // B))
        L = d * ps - max(Ks)                   # window fits in the last page
        if L <= 0:
            continue
        bt = np.full((B, MB), -1, np.int32)
        for b in range(B):
            bt[b, :d] = np.arange(b * d, (b + 1) * d, dtype=np.int32)
        bt_j = jnp.asarray(bt)
        lens = jnp.full((B,), L, jnp.int32)
        mask = jnp.ones((B,), bool)
        for K in Ks:
            toks = jnp.zeros((B, K), jnp.int32)
            for S in Ss:
                if S > d:
                    continue                   # span wider than the walk
                if (K, S) not in fns:
                    fns[(K, S)] = engine._build_decode_fn(
                        K, schedule=engine._decode_schedule, ctx=engine._ctx,
                        kv_splits=S)
                fn = fns[(K, S)]

                def call(fn=fn, toks=toks):
                    with engine._mesh_ctx():
                        return fn(engine.params, toks, bt_j, lens,
                                  engine.kv.arrays, engine.states, mask)
                out[f"{K}/{S}/{d}"] = _median_fenced(call, iters, warmup) * 1e6
    return out


# ---------------------------------------------------------------------------
# autotune: measurements -> versioned per-platform table
# ---------------------------------------------------------------------------

def autotune(config, params, mesh=None, *, smoke: bool = False,
             Ks: Optional[Sequence[int]] = None,
             log=lambda msg: None) -> Dict[str, Any]:
    """Run the full offline profile for ``config`` on the current backend
    and return a schema-valid cost table (see ``validate_table``).

    Builds a throwaway ``PagedEngine`` (imported lazily — this module must
    stay importable from ``serving/``), sweeps the alpha-beta probe and both
    kernel-timing grids, and stamps platform/mesh identity so loaders can
    refuse a table measured elsewhere.  ``smoke`` shrinks every sweep to a
    CI-sized subset (same schema, fewer points).
    """
    import jax

    from repro.serving.paged_engine import PagedEngine

    engine = PagedEngine(config, params, mesh=mesh)
    sv = config.serving
    spec_K = (sv.spec_k + 1) if sv.spec_k else 3
    Ks = tuple(Ks) if Ks else (1, spec_K)
    if smoke:
        ab_sizes, ab_iters = AB_SIZES_SMOKE, 5
        buckets = (engine._buckets or (64,))[:3]
        rows = tuple(r for r in engine._row_buckets if r <= 4)
        Ss, depths, k_iters = (1, 2, 4), (2, 8), 3
    else:
        ab_sizes, ab_iters = AB_SIZES, 8
        buckets, rows = engine._buckets, engine._row_buckets
        Ss = (1, 2, 4, 8)
        depths = tuple(sorted({2, 4, 8, 16, min(32, engine.max_blocks)}))
        k_iters = 5
    log(f"alpha-beta sweep: {len(ab_sizes)} sizes, mesh={'yes' if mesh else 'no'}")
    ab = measure_alpha_beta(mesh=mesh, sizes=ab_sizes, iters=ab_iters)
    log(f"  alpha={ab['alpha_s']:.3e}s beta={ab['beta_s_per_byte']:.3e}s/B "
        f"r2={ab['r2']:.3f}")
    log(f"prefill sweep: buckets={tuple(buckets or ())} rows={rows}")
    prefill = measure_prefill_buckets(engine, buckets=buckets, rows=rows,
                                      iters=k_iters)
    log(f"decode sweep: K={Ks} S={Ss} depths={depths}")
    decode = measure_decode_depths(engine, Ks=Ks, Ss=Ss, depths=depths,
                                   iters=k_iters)
    return {
        "schema": SCHEMA,
        "version": 1,
        "platform": jax.default_backend(),
        "mesh": {"tp": engine.tp},
        "model": config.model.name,
        "page_size": engine.ps,
        "alpha_beta": ab,
        "prefill_us": prefill,
        "decode_us": decode,
    }


# ---------------------------------------------------------------------------
# table schema
# ---------------------------------------------------------------------------

def validate_table(doc: Any) -> List[str]:
    """Structural validation of a cost table; returns problems (empty=valid).
    The CI autotune-table lane runs this on every emitted table, and
    ``load_cost_model`` refuses (-> static defaults) anything that fails."""
    problems: List[str] = []
    if not isinstance(doc, dict):
        return ["table is not a JSON object"]
    if doc.get("schema") != SCHEMA:
        problems.append(f"schema is {doc.get('schema')!r}, want {SCHEMA!r}")
    if not isinstance(doc.get("version"), int) or doc.get("version", 0) < 1:
        problems.append("version must be an int >= 1")
    if not isinstance(doc.get("platform"), str) or not doc.get("platform"):
        problems.append("platform must be a non-empty string")
    mesh = doc.get("mesh")
    if not (isinstance(mesh, dict) and isinstance(mesh.get("tp"), int)
            and mesh["tp"] >= 1):
        problems.append("mesh.tp must be an int >= 1")
    ab = doc.get("alpha_beta")
    if not isinstance(ab, dict):
        problems.append("alpha_beta missing")
    else:
        for k in ("alpha_s", "beta_s_per_byte"):
            v = ab.get(k)
            if not isinstance(v, (int, float)) or not math.isfinite(v) or v < 0:
                problems.append(f"alpha_beta.{k} must be a finite number >= 0")
    for section, nkeys in (("prefill_us", 2), ("decode_us", 3)):
        d = doc.get(section)
        if not isinstance(d, dict):
            problems.append(f"{section} missing")
            continue
        for key, v in d.items():
            parts = key.replace("x", "/").split("/")
            ok = len(parts) == nkeys and all(p.isdigit() and int(p) >= 1
                                             for p in parts)
            if not ok:
                problems.append(f"{section}[{key!r}]: malformed key")
            if not isinstance(v, (int, float)) or not math.isfinite(v) or v <= 0:
                problems.append(f"{section}[{key!r}]: timing must be > 0")
    return problems


def default_table_path(platform: str, tp: int) -> str:
    return os.path.join(TABLES_DIR, f"{platform}_tp{tp}.json")


def write_table(doc: Dict[str, Any], path: str) -> str:
    problems = validate_table(doc)
    assert not problems, f"refusing to write an invalid cost table: {problems}"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


# ---------------------------------------------------------------------------
# the model: pure table lookups -> serving decisions
# ---------------------------------------------------------------------------

def _interp(points: Sequence[Tuple[int, float]], x: int) -> float:
    """Piecewise-linear interpolation over sorted (x, y); clamps below the
    first point, extrapolates the last segment's slope above the last (a
    deeper page walk keeps paying the per-page marginal cost)."""
    if len(points) == 1:
        return points[0][1]
    if x <= points[0][0]:
        return points[0][1]
    for (x0, y0), (x1, y1) in zip(points, points[1:]):
        if x <= x1:
            return y0 + (y1 - y0) * (x - x0) / (x1 - x0)
    (x0, y0), (x1, y1) = points[-2], points[-1]
    return max(0.0, y1 + (y1 - y0) * (x - x1) / (x1 - x0))


class CostModel:
    """Serving decisions from a measured cost table.

    Every method is a pure function of the table and its arguments — no
    clocks, no randomness — so a fixed table and traffic stream produce a
    deterministic decision sequence.  Every method returns ``None`` when the
    table lacks the data to decide; callers then use the static default
    (the graceful-degradation contract tests/test_costmodel.py pins).
    """

    def __init__(self, table: Dict[str, Any]):
        problems = validate_table(table)
        if problems:
            raise ValueError(f"invalid cost table: {problems[:3]}")
        self.table = table
        self.platform: str = table["platform"]
        self.tp: int = table["mesh"]["tp"]
        ab = table["alpha_beta"]
        self.alpha_s: float = float(ab["alpha_s"])
        self.beta_s_per_byte: float = float(ab["beta_s_per_byte"])
        # decode_us "K/S/pages" -> {(K, S): sorted [(pages, us)]}
        self._decode: Dict[Tuple[int, int], List[Tuple[int, float]]] = {}
        for key, us in table["decode_us"].items():
            k, s, d = (int(p) for p in key.split("/"))
            self._decode.setdefault((k, s), []).append((d, float(us)))
        for pts in self._decode.values():
            pts.sort()
        # prefill_us "TxR" -> {T: {R: us}}
        self._prefill: Dict[int, Dict[int, float]] = {}
        for key, us in table["prefill_us"].items():
            t, r = (int(p) for p in key.split("x"))
            self._prefill.setdefault(t, {})[r] = float(us)

    @classmethod
    def from_file(cls, path: str) -> "CostModel":
        with open(path) as f:
            return cls(json.load(f))

    def matches(self, platform: str, tp: int) -> bool:
        return self.platform == platform and self.tp == tp

    # ---- primitives -------------------------------------------------------
    def collective_s(self, nbytes: int) -> float:
        """Modeled all-reduce time for an ``nbytes`` message (alpha-beta)."""
        return self.alpha_s + self.beta_s_per_byte * max(0, nbytes)

    def decode_us(self, K: int, S: int, depth_pages: int) -> Optional[float]:
        pts = self._decode.get((K, S))
        if not pts:
            return None
        return _interp(pts, max(1, depth_pages))

    def prefill_us(self, bucket: int, rows: int = 1) -> Optional[float]:
        return self._prefill.get(bucket, {}).get(rows)

    # ---- decisions --------------------------------------------------------
    def decode_splits(self, depth_pages: int, K: int = 1,
                      max_splits: int = 0) -> Optional[int]:
        """Split count S minimising modeled decode time at this page depth.
        Ties break toward the smaller S (less reduce work, fewer compiled
        closures).  None when the table has no timings for this K."""
        cands = sorted(s for (k, s) in self._decode if k == K)
        if max_splits:
            cands = [s for s in cands if s <= max_splits]
        best, best_t = None, float("inf")
        for s in cands:
            if s > max(1, depth_pages):
                continue                      # span wider than the walk
            t = self.decode_us(K, s, depth_pages)
            if t is not None and t < best_t:
                best, best_t = s, t
        return best

    def grant_cap(self, buckets: Optional[Sequence[int]] = None
                  ) -> Optional[int]:
        """Prefill chunk cap (tokens per grant): the bucket with the best
        modeled time-per-token at row width 1.  A bigger grant past this
        bucket buys no amortisation the measurements can see.  None when no
        single-row bucket was measured (or ``buckets`` filters them out)."""
        best, best_eff = None, float("inf")
        for t, by_rows in sorted(self._prefill.items()):
            if buckets is not None and t not in buckets:
                continue
            us = by_rows.get(1)
            if us is None:
                continue
            eff = us / t
            if eff < best_eff:
                best, best_eff = t, eff
        return best

    def pack_rows(self, padded: int) -> Optional[int]:
        """Pack width for batched prefill grants of ``padded`` tokens: the
        measured row bucket with the best modeled time-per-grant, at the
        nearest measured length bucket.  None with no multi-row data."""
        if not self._prefill:
            return None
        t = min(self._prefill, key=lambda b: abs(math.log(b / max(padded, 1))))
        by_rows = self._prefill[t]
        best, best_eff = None, float("inf")
        for r, us in sorted(by_rows.items()):
            eff = us / r
            if eff < best_eff:
                best, best_eff = r, eff
        return best

    def spec_worth(self, K: int, depth_pages: int,
                   expected_accept: float) -> Optional[bool]:
        """Is a K-token speculative verify worth it at this depth, given the
        expected accept length?  Worth when the verify call costs less than
        the ``expected_accept`` plain decode steps it replaces.  None when
        either K's timings are missing from the table."""
        def best_t(k):
            ts = [self.decode_us(k, s, depth_pages)
                  for (kk, s) in self._decode if kk == k]
            ts = [t for t in ts if t is not None]
            return min(ts) if ts else None
        t_verify = best_t(K)
        t_plain = best_t(1)
        if t_verify is None or t_plain is None:
            return None
        return t_verify < max(expected_accept, 1.0) * t_plain


# ---------------------------------------------------------------------------
# loading (the graceful-degradation boundary)
# ---------------------------------------------------------------------------

def load_cost_model(spec: str, *, platform: str, tp: int,
                    trace=None) -> Optional[CostModel]:
    """Resolve ``ServingConfig.cost_table`` into a CostModel, or None.

    ``spec`` is ``"auto"`` (the bundled per-platform table under
    ``perf/tables/``) or an explicit path.  EVERY failure mode — missing
    file, unreadable JSON, schema violation, platform/mesh mismatch — emits
    exactly one ``warning`` trace event and returns None, so the engine
    falls back to its static defaults instead of dying or mis-deciding from
    someone else's measurements.
    """
    path = default_table_path(platform, tp) if spec == "auto" else spec

    def warn(reason: str) -> None:
        if trace is not None:
            trace.emit("warning", what="cost_table", reason=reason, path=path)

    if not os.path.exists(path):
        warn("missing")
        return None
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        warn(f"unreadable: {e}")
        return None
    problems = validate_table(doc)
    if problems:
        warn(f"invalid: {problems[0]}")
        return None
    model = CostModel(doc)
    if not model.matches(platform, tp):
        warn(f"mismatch: table is {model.platform}/tp{model.tp}, "
             f"engine is {platform}/tp{tp}")
        return None
    return model


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--validate", metavar="TABLE.json", required=True,
                    help="validate a cost table against the schema")
    args = ap.parse_args(argv)
    with open(args.validate) as f:
        doc = json.load(f)
    problems = validate_table(doc)
    if problems:
        for p in problems:
            print(f"INVALID: {p}")
        return 1
    print(f"{args.validate}: schema-valid {SCHEMA} "
          f"({doc['platform']}/tp{doc['mesh']['tp']}, "
          f"{len(doc['prefill_us'])} prefill + {len(doc['decode_us'])} "
          f"decode points)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

from repro.perf.model import (HW, HW_PROFILES, layer_costs,  # noqa: F401
                              simulate_pipeline, simulate_iso_fractions,
                              prefill_time, speedup_table)
from repro.perf.costmodel import (CostModel, autotune,  # noqa: F401
                                  default_table_path, fit_linear,
                                  load_cost_model, measure_alpha_beta,
                                  measure_decode_depths,
                                  measure_prefill_buckets, validate_table,
                                  write_table)

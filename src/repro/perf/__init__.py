from repro.perf.model import (HW, HW_PROFILES, layer_costs,  # noqa: F401
                              simulate_pipeline, simulate_iso_fractions,
                              prefill_time, speedup_table)

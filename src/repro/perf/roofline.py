"""Roofline terms from a dry-run report (EXPERIMENTS.md §Roofline).

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_wire_bytes / (chips x link_bw)

cost_analysis() on the SPMD-partitioned module reports PER-DEVICE numbers, so no
further division by chips is needed; the collective bytes come from the HLO parse
(core/analysis.py), also per device.  MODEL_FLOPS uses 6*N*D for training and
2*N*D for inference (the factor-3 gradient multiplier doesn't apply), with
N = active params for MoE.
"""
from __future__ import annotations

from typing import Any, Dict

from repro.config import InputShape, ModelConfig
from repro.perf.model import HW_PROFILES


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per request
    return 2.0 * n_active * shape.global_batch


def roofline_terms(report: Dict[str, Any], cfg: ModelConfig,
                   shape: InputShape, hw_name: str = "v5e") -> Dict[str, Any]:
    hw = HW_PROFILES[hw_name]
    flops_dev = float(report["flops_per_device"])
    bytes_dev = float(report["bytes_per_device"])
    wire_dev = float(report["collective_wire_bytes_per_device"])
    n_dev = report["devices"]

    compute_s = flops_dev / hw.flops
    memory_s = bytes_dev / hw.hbm_bw
    collective_s = wire_dev / hw.link_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get).replace("_s", "")

    mf = model_flops(cfg, shape)
    useful = mf / max(flops_dev * n_dev, 1.0)
    return {**terms, "bottleneck": bottleneck,
            "model_flops_total": mf,
            "hlo_flops_total": flops_dev * n_dev,
            "useful_flops_ratio": useful}

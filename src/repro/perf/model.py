"""Analytic performance model — an event-driven simulation of the ISO pipeline.

Two resources, exactly like the hardware: one compute engine (MXU / SMs) and one
communication channel (ICI / NVLink / PCIe).  Baseline serialises them; ISO
pipelines chunks so the channel works while the other chunk computes.  The model
also carries the paper's empirical frictions: the NCCL "SM steal" compute penalty
while a collective is in flight (A800: 15-20%; ~0 on 4090; ~0 on TPU where the DMA
engines are independent), and optional int8 wire traffic (the 4090 mitigation).

This is how EXPERIMENTS.md reproduces Table 1 without GPUs, and what the "auto"
split policy optimises over.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

from repro.config import ModelConfig


@dataclass(frozen=True)
class HW:
    name: str
    flops: float                 # effective matmul FLOP/s per device
    hbm_bw: float                # bytes/s per device
    link_bw: float               # effective all-reduce wire bytes/s per device
    comm_penalty: float = 0.0    # compute slowdown while a collective is in flight
    comm_dtype_bytes: float = 2.0


HW_PROFILES: Dict[str, HW] = {
    # TPU v5e (the production target): DMA decoupled from MXU -> no penalty
    "v5e": HW("v5e", flops=197e12, hbm_bw=819e9, link_bw=50e9, comm_penalty=0.0),
    # paper's platforms (effective numbers tuned to the paper's observed ratios)
    "a800": HW("a800", flops=250e12, hbm_bw=2039e9, link_bw=160e9,
               comm_penalty=0.18),
    # link_bw calibrated so the 30b/tp4/8k comm share is ~75% (paper Fig 2a)
    "4090": HW("4090", flops=220e12, hbm_bw=1008e9, link_bw=10e9,
               comm_penalty=0.0),
}


# ---------------------------------------------------------------------------
# per-chunk stage costs
# ---------------------------------------------------------------------------

def layer_costs(cfg: ModelConfig, a: int, b: int, hw: HW, tp: int,
                int8_comm: bool = False) -> Dict[str, float]:
    """Times for one layer's stages on the chunk spanning tokens [a, b).

    Returns {"attn": s, "mlp": s, "comm": s} (comm = ONE all-reduce of the
    chunk's activations).
    """
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    s_c = b - a
    proj = 2.0 * s_c * d * hd * (2 * hq + 2 * hkv)           # qkv + o
    attn_quad = 2.0 * 2.0 * hq * hd * (b * b - a * a) / 2.0  # scores + pv
    if cfg.sliding_window:
        w = cfg.sliding_window
        pairs = sum(min(t + 1, w) for t in (a, b - 1)) / 2.0 * s_c
        attn_quad = 2.0 * 2.0 * hq * hd * pairs
    if cfg.moe is not None:
        ff_flops = 2.0 * 3.0 * d * cfg.moe.d_ff_expert * cfg.moe.top_k * s_c
        ff_flops += 2.0 * 3.0 * d * cfg.moe.shared_expert_d_ff * s_c
        ff_flops += 2.0 * d * cfg.moe.num_experts * s_c      # router
    else:
        ff_flops = 2.0 * 3.0 * d * cfg.d_ff * s_c
    t_attn = (proj + attn_quad) / tp / hw.flops
    t_mlp = ff_flops / tp / hw.flops
    wire = 2.0 * (tp - 1) / tp * s_c * d * \
        (1.0 if int8_comm else hw.comm_dtype_bytes)
    t_comm = wire / hw.link_bw
    return {"attn": t_attn, "mlp": t_mlp, "comm": t_comm}


# ---------------------------------------------------------------------------
# event-driven pipeline simulation
# ---------------------------------------------------------------------------

def simulate_pipeline(units: List[Tuple[float, int]], comm_times: List[float],
                      penalty: float) -> float:
    """units: [(compute_time, chunk_id)] in ISO order; after unit i its collective
    (comm_times[i]) is enqueued on the serial channel.  A unit may start only when
    the previous collective OF ITS OWN CHUNK's previous stage has completed —
    which in the ISO order is comm[i - n_chunks]: the interleave distance is the
    number of chunks.  Baseline (1 chunk) degenerates to full serialisation.

    ``penalty`` models the paper's observation that an in-flight NCCL collective
    steals SMs: compute is slowed by ``penalty`` only DURING comm/compute
    overlap.  Implemented as a two-pass approximation: simulate, measure the
    total overlapped duration, charge ``penalty x overlap`` on top.
    """
    n = len(units)
    comp_free = 0.0
    comm_free = 0.0
    comm_done = [0.0] * n
    comp_iv: List[Tuple[float, float]] = []
    comm_iv: List[Tuple[float, float]] = []
    n_chunks = len({c for _, c in units})
    for i, (t, _c) in enumerate(units):
        dep = comm_done[i - n_chunks] if i - n_chunks >= 0 else 0.0
        start = max(comp_free, dep)
        comp_free = start + t
        comp_iv.append((start, comp_free))
        c_start = max(comm_free, comp_free)
        comm_done[i] = c_start + comm_times[i]
        comm_iv.append((c_start, comm_done[i]))
        comm_free = comm_done[i]
    makespan = max(comp_free, comm_free)
    if penalty:
        overlap = 0.0
        j = 0
        for cs, ce in comp_iv:
            for ms, me in comm_iv:
                lo, hi = max(cs, ms), min(ce, me)
                if hi > lo:
                    overlap += hi - lo
        makespan += penalty * overlap
    return makespan


def _stage_units(cfg: ModelConfig, lengths: Sequence[int], hw: HW, tp: int,
                 int8_comm: bool):
    """Build the per-layer (unit, comm) lists in ISO order."""
    bounds = []
    acc = 0
    for l in lengths:
        bounds.append((acc, acc + l))
        acc += l
    units, comms = [], []
    for stage in ("attn", "mlp"):
        for ci, (a, b) in enumerate(bounds):
            c = layer_costs(cfg, a, b, hw, tp, int8_comm)
            units.append((c[stage], ci))
            comms.append(c["comm"])
    return units, comms


def prefill_time(cfg: ModelConfig, seq_len: int, hw_name: str, tp: int, *,
                 lengths: Sequence[int] = None, int8_comm: bool = False,
                 iso: bool = True) -> float:
    """Total prefill latency for one request (batch 1, the paper's metric)."""
    hw = HW_PROFILES[hw_name]
    if not iso or lengths is None or len(lengths) <= 1:
        c = layer_costs(cfg, 0, seq_len, hw, tp, int8_comm)
        per_layer = c["attn"] + c["mlp"] + 2 * c["comm"]
        return cfg.num_layers * per_layer
    units, comms = _stage_units(cfg, lengths, hw, tp, int8_comm)
    # steady state: the pipeline wraps across layers, so simulate L layers' units
    all_units = units * cfg.num_layers
    all_comms = comms * cfg.num_layers
    return simulate_pipeline(all_units, all_comms, hw.comm_penalty)


def simulate_iso_fractions(cfg: ModelConfig, lengths: Sequence[int],
                           hw_name: str = "v5e", tp: int = 16) -> float:
    seq = sum(lengths)
    return prefill_time(cfg, seq, hw_name, tp, lengths=lengths)


def speedup_table(cfg: ModelConfig, hw_name: str, tp: int,
                  prompt_lengths: Sequence[int], *, int8_comm: bool = False,
                  fractions: Tuple[float, float] = (0.5, 0.5)) -> Dict[int, float]:
    """% reduction in prefill duration (paper Table 1 cell format)."""
    out = {}
    for s in prompt_lengths:
        base = prefill_time(cfg, s, hw_name, tp, iso=False, int8_comm=int8_comm)
        lengths = [int(s * f) for f in fractions[:-1]]
        lengths.append(s - sum(lengths))
        t_iso = prefill_time(cfg, s, hw_name, tp, lengths=lengths,
                             int8_comm=int8_comm)
        out[s] = 100.0 * (1.0 - t_iso / base)
    return out

"""Paged flash-decode kernel (Pallas/TPU): attention over block tables.

Single-token decode against the paged KV pool (serving/kvcache.py) WITHOUT
gathering pages into a dense cache first — the kernel walks each request's
block table page by page, carrying the online-softmax state (max, denom,
accumulator) in VMEM scratch, masked by the request's resident length.

Layout (mirrors PagedKVCache, minus the period dim which the caller scans):

    q            (B, Hq, hd)        one decode token per request
    k/v pages    (N, ps, Hkv, hd)   page pool, N includes the scratch page
    block_tables (B, MB) int32      page ids, -1 pad (sanitised to 0 here)
    lengths      (B,)    int32      tokens resident; the decode token sits at
                                    position lengths[b] (NOT in the pool yet)

Grid is (batch, kv_head, page) with the page dimension iterated sequentially
(minor-most), exactly like the k-block dimension of kernels/flash_prefill.py.
The block table and lengths ride in via ``PrefetchScalarGridSpec`` scalar
prefetch, so the k/v BlockSpec index maps can resolve ``page -> pool slot``
before the kernel body runs (the TPU DMA pattern for paged attention).  GQA is
handled by blocking queries as (Hkv, group): every grid step attends one kv
head's whole query group.

The kernel returns the *partial* softmax state ``(out, m, l)`` over the paged
keys only; the caller folds the decode token's own (k, v) in with one more
online-softmax step (see layers/attention.attn_decode_paged_partial).  That
split keeps the pool read-only inside the kernel — the new token's KV is
scattered to its page afterwards by the model driver.

``interpret=True`` (the default) runs the same kernel under the Pallas
interpreter — the CPU-container fallback, mirroring flash_prefill.py.  On real
TPU hardware ``ps`` and ``hd`` should be multiples of the (8, 128) register
tile; the tiny test shapes rely on interpret mode's laxness.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref,
                   o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr, *,
                   page_size: int, window: int, num_pages: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                 # (group, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)              # (ps, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)

    hd = q.shape[-1]
    s = jnp.dot(q, k.T) * (hd ** -0.5)                  # (group, ps)

    length = len_ref[b]                                 # tokens resident
    k_pos = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    mask = k_pos < length                               # causal: q sits at L
    if window:
        mask &= k_pos > length - window
    # explicit mask multiply (not just -inf fill): a fully-masked page keeps
    # m at NEG_INF and exp(0)=1 would otherwise leak weight per masked key
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                 # (group, 1)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur) * mask
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(p, v)
    m_scr[...] = m_cur

    @pl.when(j == num_pages - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)
        m_ref[0, 0] = m_scr[...].astype(m_ref.dtype)
        l_ref[0, 0] = l.astype(l_ref.dtype)


def flash_decode(q, k_pages, v_pages, block_tables, lengths, *,
                 window: int = 0, interpret: bool = True):
    """Paged flash attention for one decode token per request.

    q: (B, Hq, hd); k_pages/v_pages: (N, ps, Hkv, hd); block_tables: (B, MB)
    int32 (-1 pad); lengths: (B,) int32 resident token counts.

    Returns ``(out, m, l)`` fp32 partial softmax state over the paged keys:
    out (B, Hq, hd) = acc / l, m (B, Hq, 1) running max, l (B, Hq, 1) running
    denominator.  Rows with ``lengths == 0`` come back as (0, NEG_INF, 0) —
    the caller's merge with the current token then gives it weight 1.
    """
    B, Hq, hd = q.shape
    N, ps, Hkv, _ = k_pages.shape
    MB = block_tables.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv

    # pad table entries (-1) alias page 0; they are always masked because a
    # request's pages cover positions [0, lengths) contiguously
    bt = jnp.clip(block_tables, 0, N - 1).astype(jnp.int32)
    qg = q.reshape(B, Hkv, group, hd)

    kernel = functools.partial(_decode_kernel, page_size=ps, window=window,
                               num_pages=MB)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                     # block_tables, lengths
        grid=(B, Hkv, MB),
        in_specs=[
            pl.BlockSpec((1, 1, group, hd),
                         lambda b, h, j, bt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, j, bt, ln: (bt[b, j], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, j, bt, ln: (bt[b, j], 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, group, hd),
                         lambda b, h, j, bt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, group, 1),
                         lambda b, h, j, bt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, group, 1),
                         lambda b, h, j, bt, ln: (b, h, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),   # running max
            pltpu.VMEM((group, 1), jnp.float32),   # running denom
            pltpu.VMEM((group, hd), jnp.float32),  # running accumulator
        ],
    )
    out, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, group, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, group, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, group, 1), jnp.float32),
        ],
        interpret=interpret,
    )(bt, lengths.astype(jnp.int32), qg, k_pages, v_pages)
    return (out.reshape(B, Hq, hd), m.reshape(B, Hq, 1), l.reshape(B, Hq, 1))


def merge_partial_softmax(out_p, m_p, l_p, s_new, v_new):
    """Fold extra key/value pairs into a flash partial-softmax state.

    out_p (B,Hq,hd), m_p/l_p (B,Hq,1): kernel output.  s_new (B,Hq,K) raw
    (scaled) scores of K extra keys; v_new (B,Hq,K,hd) their values.  Returns
    the final normalised attention output (B, Hq, hd) in fp32.
    """
    m_tot = jnp.maximum(m_p, jnp.max(s_new, axis=-1, keepdims=True))
    alpha = jnp.exp(m_p - m_tot)                        # (B,Hq,1)
    w_new = jnp.exp(s_new - m_tot)                      # (B,Hq,K)
    l_tot = l_p * alpha + jnp.sum(w_new, axis=-1, keepdims=True)
    acc = out_p * (l_p * alpha) + jnp.einsum(
        "bhk,bhkd->bhd", w_new, v_new.astype(jnp.float32))
    return acc / jnp.maximum(l_tot, 1e-30)

"""Paged flash-decode kernel (Pallas/TPU): attention over block tables.

Decode against the paged KV pool (serving/kvcache.py) WITHOUT gathering pages
into a dense cache first — the kernel walks each request's block table page by
page, carrying the online-softmax state (max, denom, accumulator) in VMEM
scratch, masked by the request's resident length.

Layout (mirrors PagedKVCache, minus the period dim which the caller scans):

    q            (B, Hq, hd)        one decode token per request, OR
                 (B, K, Hq, hd)     a K-token speculative verify window
    k/v pages    (N, ps, Hkv, hd)   page pool, N includes the scratch page
    block_tables (B, MB) int32      page ids, -1 pad (sanitised to 0 here)
    lengths      (B,)    int32      tokens resident; window token qi sits at
                                    position lengths[b] + qi (NOT in the pool)

Grid is (batch, kv_head, split, page): the page walk of each request is
partitioned into ``kv_splits`` contiguous spans of ``ceil(MB / kv_splits)``
pages (the Flash-Decoding sequence-parallel structure: a split grid axis over
the KV length, per-span online-softmax partials, then a second reduce kernel
folding the spans).  The page dimension stays minor-most and sequential
WITHIN a span — exactly the old walk — but spans are independent grid slots,
so a long-context request's walk no longer serializes over its whole block
table while batchmates idle.  Each span emits its own ``(out, m, l)`` partial
into a ``(B, Hkv, S, ...)`` buffer; ``_decode_reduce_kernel`` then folds the
S span states with the same merge rule as
``layers.attention.merge_softmax_states`` (disjoint-key-set softmax union),
so the caller-side contract is unchanged at every S.  ``kv_splits=1``
degenerates to the sequential walk and skips the reduce entirely.

The block table and lengths ride in via ``PrefetchScalarGridSpec`` scalar
prefetch, so the k/v BlockSpec index maps can resolve ``page -> pool slot``
before the kernel body runs (the TPU DMA pattern for paged attention).  GQA is
handled by blocking queries as (Hkv, group): every grid step attends one kv
head's whole query group.  The K>1 verify window rides in the SAME grid: query
rows are laid out (Hkv, group*K) with row ``g*K + qi``, so the per-position
sliding-window shift is an iota-mod inside the kernel body and the page walk
is shared by all K positions.

Pages entirely past a request's resident length (``j * ps >= length``) are
skipped with a ``pl.when`` body guard rather than paying a fully-masked
matmul: a dead page leaves (m, l, acc) bit-identically unchanged (alpha =
exp(0) = 1, p = 0), so the guard is a pure cost saving
(``guard_dead_pages=False`` keeps the unguarded body for the parity
regression).  A span whose every page is dead emits the neutral state
``(0, NEG_INF, 0)`` and vanishes in the reduce.

The kernel returns the *partial* softmax state ``(out, m, l)`` over the paged
keys only; the caller folds the window's own (k, v) — lower-triangular among
the K new tokens — in with one more softmax merge (see
layers/attention.attn_decode_paged_partial).  That split keeps the pool
read-only inside the kernel — the new tokens' KV is scattered to their pages
afterwards by the model driver.  All paged keys sit at positions < length <=
length + qi, so causality over the pool reduces to the validity mask; the
per-query causal structure lives entirely in the intra-window merge.

``interpret=True`` (the default) runs the same kernel under the Pallas
interpreter — the CPU-container fallback, mirroring flash_prefill.py.  When
compiled for real TPU hardware (``interpret=False``) the (8, 128) register
tile alignment is ASSERTED up front (``check_tpu_tile_alignment``); the tiny
test shapes rely on interpret mode's laxness.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def check_tpu_tile_alignment(ps: int, hd: int, kernel: str) -> None:
    """Real-TPU (8, 128) register-tile alignment for the paged kernels.

    The fp32 VPU/MXU tile is (sublane 8, lane 128): the page token axis must
    be a sublane multiple and the head dim a lane multiple or Mosaic pads
    every page load.  Only enforced when compiling for hardware — interpret
    mode (the CPU-container fallback) is layout-lax by design and the tiny
    test shapes depend on that.
    """
    if ps % 8 != 0 or hd % 128 != 0:
        raise ValueError(
            f"{kernel}: page_size={ps} must be a multiple of 8 (sublane) and "
            f"head_dim={hd} a multiple of 128 (lane) to match the TPU "
            f"(8, 128) register tile when interpret=False; pad the pool "
            f"layout or run under the interpreter")


def _decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref,
                   o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr, *,
                   page_size: int, window: int, pages_per_split: int,
                   k_tokens: int, guard_dead_pages: bool):
    b = pl.program_id(0)
    split = pl.program_id(2)
    jj = pl.program_id(3)                      # page index WITHIN the span
    j = split * pages_per_split + jj           # global page-walk index
    length = len_ref[b]                        # tokens resident

    @pl.when(jj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _page_body():
        q = q_ref[0, 0].astype(jnp.float32)             # (group*K, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)          # (ps, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)

        hd = q.shape[-1]
        s = jnp.dot(q, k.T) * (hd ** -0.5)              # (group*K, ps)

        k_pos = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        # validity doubles as causality: every paged key sits at a position
        # < length <= length + qi for all K window queries
        mask = k_pos < length
        if window:
            # per-query window shift: row r = g*K + qi queries pos L + qi
            qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % k_tokens
            mask &= k_pos > length + qi - window
        # explicit mask multiply (not just -inf fill): a fully-masked page
        # keeps m at NEG_INF and exp(0)=1 would otherwise leak weight per
        # masked key
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                             # (group*K, 1)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur) * mask
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(p, v)
        m_scr[...] = m_cur

    if guard_dead_pages:
        # skip pages entirely past the resident tokens: a dead page leaves
        # (m, l, acc) bit-identically unchanged, so this is pure cost saving
        pl.when(j * page_size < length)(_page_body)
    else:
        _page_body()

    @pl.when(jj == pages_per_split - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0, 0, 0] = (acc_scr[...]
                          / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)
        m_ref[0, 0, 0] = m_scr[...].astype(m_ref.dtype)
        l_ref[0, 0, 0] = l.astype(l_ref.dtype)


def _decode_reduce_kernel(o_ref, m_ref, l_ref, o_out, m_out, l_out):
    """Fold the S per-span partials into one state — the second phase of
    Flash-Decoding.  Same math as ``layers.attention.merge_softmax_states``
    flattened over the span axis: spans cover disjoint key-position ranges,
    so ``m = max_s m_s``, each span reweights by ``w_s = exp(m_s - m) * l_s``
    and a neutral span (m_s = NEG_INF, l_s = 0) contributes exactly nothing
    (NEG_INF is finite, so even an all-empty row folds to (0, NEG_INF, 0)
    without NaNs)."""
    m_s = m_ref[0, 0]                                   # (S, gk, 1)
    o_s = o_ref[0, 0]                                   # (S, gk, hd)
    m = jnp.max(m_s, axis=0)                            # (gk, 1)
    w = jnp.exp(m_s - m[None]) * l_ref[0, 0]            # (S, gk, 1)
    l = jnp.sum(w, axis=0)                              # (gk, 1)
    o_out[0, 0] = jnp.sum(o_s * w, axis=0) / jnp.maximum(l, 1e-30)
    m_out[0, 0] = m
    l_out[0, 0] = l


def _decode_reduce(out, m, l, *, interpret: bool = True):
    """(B, Hkv, S, gk, ·) span partials -> (B, Hkv, gk, ·) folded state."""
    B, Hkv, S, gk, hd = out.shape
    return pl.pallas_call(
        _decode_reduce_kernel,
        grid=(B, Hkv),
        in_specs=[
            pl.BlockSpec((1, 1, S, gk, hd), lambda b, h: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, S, gk, 1), lambda b, h: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, S, gk, 1), lambda b, h: (b, h, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, gk, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, gk, 1), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, gk, 1), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, gk, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, gk, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, gk, 1), jnp.float32),
        ],
        interpret=interpret,
    )(out, m, l)


def flash_decode(q, k_pages, v_pages, block_tables, lengths, *,
                 window: int = 0, kv_splits: int = 1,
                 guard_dead_pages: bool = True, interpret: bool = True):
    """Paged flash attention for a decode/verify window per request.

    q: (B, Hq, hd) single-token decode, or (B, K, Hq, hd) a K-token
    speculative verify window (token qi at position ``lengths[b] + qi``);
    k_pages/v_pages: (N, ps, Hkv, hd); block_tables: (B, MB) int32 (-1 pad);
    lengths: (B,) int32 resident token counts.

    ``kv_splits`` partitions each request's page walk into S contiguous
    spans run as independent grid slots (sequence-parallel Flash-Decoding);
    the per-span partials are folded by a second reduce kernel, so the
    result is the same partial state at every S (clamped to the table
    width; S=1 is the sequential walk, no reduce).  ``guard_dead_pages``
    skips pages past ``ceil(length/ps)`` (bit-identical — regression-pinned).

    Returns ``(out, m, l)`` fp32 partial softmax state over the paged keys:
    out = acc / l, m the running max, l the running denominator — shaped
    (B, Hq, hd)/(B, Hq, 1) for 3-D q and (B, K, Hq, hd)/(B, K, Hq, 1) for
    4-D q.  Rows with ``lengths == 0`` come back as (0, NEG_INF, 0) — the
    caller's merge with the window's own keys then gives them weight 1.
    """
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]                                 # K = 1
    B, K, Hq, hd = q.shape
    N, ps, Hkv, _ = k_pages.shape
    MB = block_tables.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    gk = group * K
    if not interpret:
        check_tpu_tile_alignment(ps, hd, "flash_decode")

    S = max(1, min(int(kv_splits), MB))
    pps = -(-MB // S)                                  # pages per span

    # pad table entries (-1) alias page 0; they are always masked because a
    # request's pages cover positions [0, lengths) contiguously
    bt = jnp.clip(block_tables, 0, N - 1).astype(jnp.int32)
    if S * pps > MB:
        # ragged last span: the extra walk positions j >= MB alias page 0
        # and sit at key positions >= MB*ps >= length, so the validity mask
        # always hides them
        bt = jnp.pad(bt, ((0, 0), (0, S * pps - MB)))
    # query-row layout r = g*K + qi (the kernel recovers qi as iota % K)
    qg = q.reshape(B, K, Hkv, group, hd).transpose(0, 2, 3, 1, 4)
    qg = qg.reshape(B, Hkv, gk, hd)

    kernel = functools.partial(_decode_kernel, page_size=ps, window=window,
                               pages_per_split=pps, k_tokens=K,
                               guard_dead_pages=guard_dead_pages)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                     # block_tables, lengths
        grid=(B, Hkv, S, pps),
        in_specs=[
            pl.BlockSpec((1, 1, gk, hd),
                         lambda b, h, s, jj, bt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, s, jj, bt, ln:
                         (bt[b, s * pps + jj], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, s, jj, bt, ln:
                         (bt[b, s * pps + jj], 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, gk, hd),
                         lambda b, h, s, jj, bt, ln: (b, h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, gk, 1),
                         lambda b, h, s, jj, bt, ln: (b, h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, gk, 1),
                         lambda b, h, s, jj, bt, ln: (b, h, s, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((gk, 1), jnp.float32),      # running max
            pltpu.VMEM((gk, 1), jnp.float32),      # running denom
            pltpu.VMEM((gk, hd), jnp.float32),     # running accumulator
        ],
    )
    out, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, S, gk, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, S, gk, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, S, gk, 1), jnp.float32),
        ],
        interpret=interpret,
    )(bt, lengths.astype(jnp.int32), qg, k_pages, v_pages)

    if S == 1:
        out, m, l = out[:, :, 0], m[:, :, 0], l[:, :, 0]
    else:
        out, m, l = _decode_reduce(out, m, l, interpret=interpret)

    def unrow(t, last):
        t = t.reshape(B, Hkv, group, K, last).transpose(0, 3, 1, 2, 4)
        t = t.reshape(B, K, Hq, last)
        return t[:, 0] if squeeze else t

    return unrow(out, hd), unrow(m, 1), unrow(l, 1)

"""Paged flash-decode kernel (Pallas/TPU): attention over block tables.

Decode against the paged KV pool (serving/kvcache.py) WITHOUT gathering pages
into a dense cache first — the kernel walks each request's block table page by
page, carrying the online-softmax state (max, denom, accumulator) in VMEM
scratch, masked by the request's resident length.

Layout (mirrors PagedKVCache, minus the period dim which the caller scans):

    q            (B, Hq, hd)        one decode token per request, OR
                 (B, K, Hq, hd)     a K-token speculative verify window
    k/v pages    (N, ps, Hkv, hd)   page pool, N includes the scratch page
    block_tables (B, MB) int32      page ids, -1 pad (sanitised to 0 here)
    lengths      (B,)    int32      tokens resident; window token qi sits at
                                    position lengths[b] + qi (NOT in the pool)

Grid is (batch, kv_head, page) with the page dimension iterated sequentially
(minor-most), exactly like the k-block dimension of kernels/flash_prefill.py.
The block table and lengths ride in via ``PrefetchScalarGridSpec`` scalar
prefetch, so the k/v BlockSpec index maps can resolve ``page -> pool slot``
before the kernel body runs (the TPU DMA pattern for paged attention).  GQA is
handled by blocking queries as (Hkv, group): every grid step attends one kv
head's whole query group.  The K>1 verify window rides in the SAME grid: query
rows are laid out (Hkv, group*K) with row ``g*K + qi``, so the per-position
sliding-window shift is an iota-mod inside the kernel body and the page walk
is shared by all K positions.

The kernel returns the *partial* softmax state ``(out, m, l)`` over the paged
keys only; the caller folds the window's own (k, v) — lower-triangular among
the K new tokens — in with one more softmax merge (see
layers/attention.attn_decode_paged_partial).  That split keeps the pool
read-only inside the kernel — the new tokens' KV is scattered to their pages
afterwards by the model driver.  All paged keys sit at positions < length <=
length + qi, so causality over the pool reduces to the validity mask; the
per-query causal structure lives entirely in the intra-window merge.

``interpret=True`` (the default) runs the same kernel under the Pallas
interpreter — the CPU-container fallback, mirroring flash_prefill.py.  On real
TPU hardware ``ps`` and ``hd`` should be multiples of the (8, 128) register
tile; the tiny test shapes rely on interpret mode's laxness.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref,
                   o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr, *,
                   page_size: int, window: int, num_pages: int,
                   k_tokens: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                 # (group*K, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)              # (ps, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)

    hd = q.shape[-1]
    s = jnp.dot(q, k.T) * (hd ** -0.5)                  # (group*K, ps)

    length = len_ref[b]                                 # tokens resident
    k_pos = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    # validity doubles as causality: every paged key sits at a position
    # < length <= length + qi for all K window queries
    mask = k_pos < length
    if window:
        # per-query window shift: row r = g*K + qi queries position L + qi
        qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % k_tokens
        mask &= k_pos > length + qi - window
    # explicit mask multiply (not just -inf fill): a fully-masked page keeps
    # m at NEG_INF and exp(0)=1 would otherwise leak weight per masked key
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                 # (group, 1)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur) * mask
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(p, v)
    m_scr[...] = m_cur

    @pl.when(j == num_pages - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)
        m_ref[0, 0] = m_scr[...].astype(m_ref.dtype)
        l_ref[0, 0] = l.astype(l_ref.dtype)


def flash_decode(q, k_pages, v_pages, block_tables, lengths, *,
                 window: int = 0, interpret: bool = True):
    """Paged flash attention for a decode/verify window per request.

    q: (B, Hq, hd) single-token decode, or (B, K, Hq, hd) a K-token
    speculative verify window (token qi at position ``lengths[b] + qi``);
    k_pages/v_pages: (N, ps, Hkv, hd); block_tables: (B, MB) int32 (-1 pad);
    lengths: (B,) int32 resident token counts.

    Returns ``(out, m, l)`` fp32 partial softmax state over the paged keys:
    out = acc / l, m the running max, l the running denominator — shaped
    (B, Hq, hd)/(B, Hq, 1) for 3-D q and (B, K, Hq, hd)/(B, K, Hq, 1) for
    4-D q.  Rows with ``lengths == 0`` come back as (0, NEG_INF, 0) — the
    caller's merge with the window's own keys then gives them weight 1.
    """
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]                                 # K = 1
    B, K, Hq, hd = q.shape
    N, ps, Hkv, _ = k_pages.shape
    MB = block_tables.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    gk = group * K

    # pad table entries (-1) alias page 0; they are always masked because a
    # request's pages cover positions [0, lengths) contiguously
    bt = jnp.clip(block_tables, 0, N - 1).astype(jnp.int32)
    # query-row layout r = g*K + qi (the kernel recovers qi as iota % K)
    qg = q.reshape(B, K, Hkv, group, hd).transpose(0, 2, 3, 1, 4)
    qg = qg.reshape(B, Hkv, gk, hd)

    kernel = functools.partial(_decode_kernel, page_size=ps, window=window,
                               num_pages=MB, k_tokens=K)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                     # block_tables, lengths
        grid=(B, Hkv, MB),
        in_specs=[
            pl.BlockSpec((1, 1, gk, hd),
                         lambda b, h, j, bt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, j, bt, ln: (bt[b, j], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, j, bt, ln: (bt[b, j], 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, gk, hd),
                         lambda b, h, j, bt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, gk, 1),
                         lambda b, h, j, bt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, gk, 1),
                         lambda b, h, j, bt, ln: (b, h, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((gk, 1), jnp.float32),      # running max
            pltpu.VMEM((gk, 1), jnp.float32),      # running denom
            pltpu.VMEM((gk, hd), jnp.float32),     # running accumulator
        ],
    )
    out, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, gk, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, gk, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, gk, 1), jnp.float32),
        ],
        interpret=interpret,
    )(bt, lengths.astype(jnp.int32), qg, k_pages, v_pages)

    def unrow(t, last):
        t = t.reshape(B, Hkv, group, K, last).transpose(0, 3, 1, 2, 4)
        t = t.reshape(B, K, Hq, last)
        return t[:, 0] if squeeze else t

    return unrow(out, hd), unrow(m, 1), unrow(l, 1)

"""Flash-attention prefill kernel (Pallas/TPU) with chunked-prefill support.

The exact primitive ISO needs: queries of ONE sequence chunk attending to
``prefix KV + own KV`` with a causal offset (``q_start``) — plus optional
sliding-window masking for the long-context configs.

TPU adaptation of the CUDA flash algorithm (DESIGN.md §2): the grid is
(batch, q_head, q_blocks, k_blocks) with the k dimension iterated sequentially
(minor-most), carrying the running (max, sum, acc) in VMEM scratch; BlockSpec
tiles are (block_q x head_dim) / (block_k x head_dim), multiples of the (8,128)
TPU register tile, so the MXU sees aligned matmuls and the working set stays in
VMEM.  GQA is folded into the k/v index_map (q head h reads kv head h // group).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, q_start: int, k_len: int,
                  causal: bool, window: int, num_k_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                  # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    hd = q.shape[-1]
    s = jnp.dot(q, k.T) * (hd ** -0.5)                   # (bq, bk)

    q_pos = q_start + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos < k_len
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                  # (bq, 1)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)                               # (bq, bk)
    l_cur = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_cur = acc_scr[...] * alpha + jnp.dot(p, v)

    m_scr[...] = m_cur
    l_scr[...] = l_cur
    acc_scr[...] = acc_cur

    @pl.when(ik == num_k_blocks - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_prefill(q, k, v, *, q_start: int = 0, causal: bool = True,
                  window: int = 0, block_q: int = 128, block_k: int = 128,
                  interpret: bool = True):
    """q: (B,Hq,Sq,hd); k,v: (B,Hkv,Sk,hd) — prefix KV concatenated in front.

    Returns (B,Hq,Sq,hd).  Handles GQA via head-index folding; pads Sq/Sk to the
    block sizes internally.
    """
    B, Hq, Sq, hd = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv

    sq_p = math.ceil(Sq / block_q) * block_q
    sk_p = math.ceil(Sk / block_k) * block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - Sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - Sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - Sk), (0, 0)))
    nq, nk = sq_p // block_q, sk_p // block_k

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, q_start=q_start,
        k_len=Sk, causal=causal, window=window, num_k_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
            pltpu.VMEM((block_q, hd), jnp.float32),  # running accumulator
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :Sq]

"""Fused per-row symmetric int8 quantization kernel (Pallas/TPU).

The compute half of the paper's int8 communication path: quantize the partial
activations right before they hit the wire (core/quantized_collectives.py).  One
pass over the tile computes the row abs-max and emits int8 + fp32 scales; tiles
are (block_rows x d) so a row never straddles tiles.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                  # (br, d)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def quantize_int8(x, *, block_rows: int = 256, interpret: bool = True):
    """x: (..., D) -> (int8 (..., D), fp32 scales (..., 1)) per-row abs-max."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = math.prod(orig_shape[:-1])
    x2 = x.reshape(rows, d)
    br = min(block_rows, max(8, rows))
    rows_p = math.ceil(rows / br) * br
    x2 = jnp.pad(x2, ((0, rows_p - rows), (0, 0)))

    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(rows_p // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                   pl.BlockSpec((br, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows_p, d), jnp.int8),
                   jax.ShapeDtypeStruct((rows_p, 1), jnp.float32)],
        interpret=interpret,
    )(x2)
    return (q[:rows].reshape(orig_shape),
            s[:rows].reshape(*orig_shape[:-1], 1))

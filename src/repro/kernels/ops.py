"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True when no TPU is present (this container validates
kernel bodies on CPU via the Pallas interpreter); on real TPUs pass
``interpret=False`` (or rely on the default, which auto-detects).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import flash_prefill as _fp
from repro.kernels import int8_quant as _iq
from repro.kernels import rmsnorm as _rn
from repro.kernels import swiglu as _sg


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("q_start", "causal", "window",
                                             "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, q_start: int = 0, causal: bool = True,
                    window: int = 0, block_q: int = 128, block_k: int = 128,
                    interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    return _fp.flash_prefill(q, k, v, q_start=q_start, causal=causal,
                             window=window, block_q=block_q, block_k=block_k,
                             interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quantize_int8(x, *, block_rows: int = 256, interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    return _iq.quantize_int8(x, block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rms_norm(x, gamma, *, eps: float = 1e-6, block_rows: int = 256,
             interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    return _rn.rms_norm(x, gamma, eps=eps, block_rows=block_rows,
                        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols",
                                             "interpret"))
def swiglu(gate, up, *, block_rows: int = 256, block_cols: int = 512,
           interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    return _sg.swiglu(gate, up, block_rows=block_rows, block_cols=block_cols,
                      interpret=interpret)

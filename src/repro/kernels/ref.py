"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_prefill_ref(q, k, v, *, q_start: int = 0, causal: bool = True,
                      window: int = 0):
    """q: (B,Hq,Sq,hd); k,v: (B,Hkv,Sk,hd)."""
    B, Hq, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * (hd ** -0.5)
    q_pos = q_start + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32)).astype(q.dtype)


def paged_decode_ref(q, k_pages, v_pages, block_tables, lengths, *,
                     window: int = 0):
    """Oracle for kernels/flash_decode.py: gather pages dense, full softmax.

    q: (B,Hq,hd); k_pages/v_pages: (N,ps,Hkv,hd); block_tables: (B,MB) int32
    (-1 pad); lengths: (B,).  Returns the PAGED-KEYS-ONLY attention output
    (B,Hq,hd) fp32 — the kernel's ``acc/l`` before the current token is merged.
    Rows with lengths == 0 return zeros.
    """
    B, Hq, hd = q.shape
    N, ps, Hkv, _ = k_pages.shape
    MB = block_tables.shape[1]
    group = Hq // Hkv
    idx = jnp.clip(block_tables, 0, N - 1)
    kd = k_pages[idx].reshape(B, MB * ps, Hkv, hd)      # (B, L, Hkv, hd)
    vd = v_pages[idx].reshape(B, MB * ps, Hkv, hd)
    kr = jnp.repeat(kd, group, axis=2).astype(jnp.float32)
    vr = jnp.repeat(vd, group, axis=2).astype(jnp.float32)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), kr) * (hd ** -0.5)
    k_pos = jnp.arange(MB * ps, dtype=jnp.int32)[None, :]
    mask = k_pos < lengths[:, None]
    if window:
        mask &= k_pos > (lengths[:, None] - window)
    s = jnp.where(mask[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhs,bshd->bhd", p, vr)


def paged_verify_ref(q, k_pages, v_pages, block_tables, lengths, *,
                     window: int = 0):
    """Oracle for the K-token verify mode of kernels/flash_decode.py: gather
    pages dense, masked softmax, return the kernel's PARTIAL state over paged
    keys only (the window's own keys are merged by the layer, not the kernel).

    q: (B,K,Hq,hd) — window token qi queries position ``lengths[b] + qi``;
    k_pages/v_pages: (N,ps,Hkv,hd); block_tables: (B,MB) int32 (-1 pad);
    lengths: (B,) resident token counts.  Returns ``(out, m, l)`` fp32:
    out (B,K,Hq,hd) = acc/l (zeros where a row attends nothing), m (B,K,Hq,1)
    the masked row max (NEG_INF when empty), l the softmax denominator at m.
    """
    NEG_INF = -1e30
    B, K, Hq, hd = q.shape
    N, ps, Hkv, _ = k_pages.shape
    MB = block_tables.shape[1]
    group = Hq // Hkv
    idx = jnp.clip(block_tables, 0, N - 1)
    kd = k_pages[idx].reshape(B, MB * ps, Hkv, hd)
    vd = v_pages[idx].reshape(B, MB * ps, Hkv, hd)
    kr = jnp.repeat(kd, group, axis=2).astype(jnp.float32)
    vr = jnp.repeat(vd, group, axis=2).astype(jnp.float32)
    s = jnp.einsum("bkhd,bshd->bkhs", q.astype(jnp.float32),
                   kr) * (hd ** -0.5)
    k_pos = jnp.arange(MB * ps, dtype=jnp.int32)[None, None, :]
    mask = k_pos < lengths[:, None, None]               # (B, 1, S)
    if window:
        q_abs = (lengths[:, None] + jnp.arange(K, dtype=jnp.int32)[None]
                 )[:, :, None]                          # (B, K, 1)
        mask = mask & (k_pos > q_abs - window)
    else:
        mask = jnp.broadcast_to(mask, (B, K, MB * ps))
    mask = mask[:, :, None, :]                          # (B, K, 1, S)
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m) * mask
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkhs,bshd->bkhd", p, vr) / jnp.maximum(l, 1e-30)
    return out, m, l


def paged_decode_split_ref(q, k_pages, v_pages, block_tables, lengths, *,
                           kv_splits: int, window: int = 0):
    """Split-parametrized oracle for the sequence-parallel (split-KV) mode of
    kernels/flash_decode.py: compute an independent masked-softmax partial
    per contiguous page span, then fold the spans left-to-right with the
    ``merge_softmax_states`` rule (disjoint-key-set softmax union) — the
    same two-phase structure as the kernel, but in pure jnp, so span
    boundaries are provable at every S.

    q: (B,K,Hq,hd) (or (B,Hq,hd), squeezed like the kernel); spans cover
    page-walk indices ``[s*ceil(MB/S), (s+1)*ceil(MB/S))``.  Returns
    ``(out, m, l)`` fp32 partial state shaped like ``paged_verify_ref``
    (3-D q squeezes the K axis).  An empty span is (0, NEG_INF, 0) and
    contributes nothing to the fold; rows with lengths == 0 stay
    (0, NEG_INF, 0) through every span.
    """
    NEG_INF = -1e30
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    B, K, Hq, hd = q.shape
    N, ps, Hkv, _ = k_pages.shape
    MB = block_tables.shape[1]
    group = Hq // Hkv
    S = max(1, min(int(kv_splits), MB))
    pps = -(-MB // S)
    idx = jnp.clip(block_tables, 0, N - 1)
    kd = k_pages[idx].reshape(B, MB * ps, Hkv, hd)
    vd = v_pages[idx].reshape(B, MB * ps, Hkv, hd)
    kr = jnp.repeat(kd, group, axis=2).astype(jnp.float32)
    vr = jnp.repeat(vd, group, axis=2).astype(jnp.float32)
    s_all = jnp.einsum("bkhd,bshd->bkhs", q.astype(jnp.float32),
                       kr) * (hd ** -0.5)
    k_pos = jnp.arange(MB * ps, dtype=jnp.int32)[None, None, :]
    base = k_pos < lengths[:, None, None]               # (B, 1, S_keys)
    if window:
        q_abs = (lengths[:, None] + jnp.arange(K, dtype=jnp.int32)[None]
                 )[:, :, None]
        base = base & (k_pos > q_abs - window)
    else:
        base = jnp.broadcast_to(base, (B, K, MB * ps))

    def span_partial(lo, hi):
        span = (k_pos >= lo) & (k_pos < hi)
        mask = (base & span)[:, :, None, :]             # (B, K, 1, S_keys)
        sc = jnp.where(mask, s_all, NEG_INF)
        m = jnp.max(sc, axis=-1, keepdims=True)
        p = jnp.exp(sc - m) * mask
        l = jnp.sum(p, axis=-1, keepdims=True)
        out = jnp.einsum("bkhs,bshd->bkhd", p, vr) / jnp.maximum(l, 1e-30)
        return out, m, l

    out, m, l = span_partial(0, pps * ps)
    for sp in range(1, S):
        o_b, m_b, l_b = span_partial(sp * pps * ps, (sp + 1) * pps * ps)
        # merge_softmax_states, kept in partial (out, m, l) form so the
        # fold can continue (the layer primitive returns only the output)
        m_u = jnp.maximum(m, m_b)
        w_a = jnp.exp(m - m_u) * l
        w_b = jnp.exp(m_b - m_u) * l_b
        l_u = w_a + w_b
        out = (out * w_a + o_b * w_b) / jnp.maximum(l_u, 1e-30)
        m, l = m_u, l_u
    if squeeze:
        out, m, l = out[:, 0], m[:, 0], l[:, 0]
    return out, m, l


def paged_prefill_ref(q, k_pages, v_pages, block_tables, prefix_lens,
                      q_starts, *, window: int = 0):
    """Oracle for kernels/flash_prefill_paged.py: gather the prefix dense,
    full softmax, return the kernel's partial state over paged keys only.

    q: (B,Hq,Sq,hd); k_pages/v_pages: (N,ps,Hkv,hd); block_tables: (B,MB)
    int32 (-1 pad); prefix_lens: (B,) valid prefix tokens; q_starts: (B,)
    absolute position of each row's first query.  Like the kernel, every
    per-row input is heterogeneous: rows model independently-resumed packed
    grants (batched multi-request prefill), including fresh rows with
    ``prefix_len == 0`` whose state comes back neutral ``(0, NEG_INF, 0)``.
    Scalars broadcast to (B,) for convenience.  Returns ``(out, m, l)``
    fp32: out = acc/l (zeros where the row attends nothing), m the masked
    row max (NEG_INF when empty), l the softmax denominator at m.
    """
    NEG_INF = -1e30
    B, Hq, Sq, hd = q.shape
    N, ps, Hkv, _ = k_pages.shape
    MB = block_tables.shape[1]
    group = Hq // Hkv
    prefix_lens = jnp.broadcast_to(jnp.asarray(prefix_lens, jnp.int32), (B,))
    q_starts = jnp.broadcast_to(jnp.asarray(q_starts, jnp.int32), (B,))
    idx = jnp.clip(block_tables, 0, N - 1)
    kd = k_pages[idx].reshape(B, MB * ps, Hkv, hd)
    vd = v_pages[idx].reshape(B, MB * ps, Hkv, hd)
    kr = jnp.repeat(kd, group, axis=2).astype(jnp.float32)
    vr = jnp.repeat(vd, group, axis=2).astype(jnp.float32)
    s = jnp.einsum("bhqd,bshd->bhqs", q.astype(jnp.float32),
                   kr) * (hd ** -0.5)
    k_pos = jnp.arange(MB * ps, dtype=jnp.int32)[None, None, None, :]
    mask = k_pos < prefix_lens[:, None, None, None]
    if window:
        q_pos = (q_starts[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None]
                 )[:, None, :, None]
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m) * mask
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqs,bshd->bhqd", p, vr) / jnp.maximum(l, 1e-30)
    return out, m, l


def quantize_int8_ref(x):
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def rms_norm_ref(x, gamma, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)
            ).astype(x.dtype)


def swiglu_ref(gate, up):
    gf = gate.astype(jnp.float32)
    return (gf * jax.nn.sigmoid(gf) * up.astype(jnp.float32)).astype(gate.dtype)

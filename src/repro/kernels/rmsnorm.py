"""Fused RMSNorm kernel (Pallas/TPU) — the pre-collective norm in every block."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                  # (br, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rms_norm(x, gamma, *, eps: float = 1e-6, block_rows: int = 256,
             interpret: bool = True):
    """x: (..., D), gamma: (D,)."""
    import functools
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = math.prod(orig_shape[:-1])
    x2 = x.reshape(rows, d)
    br = min(block_rows, max(8, rows))
    rows_p = math.ceil(rows / br) * br
    x2 = jnp.pad(x2, ((0, rows_p - rows), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows_p // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_p, d), x.dtype),
        interpret=interpret,
    )(x2, gamma)
    return out[:rows].reshape(orig_shape)

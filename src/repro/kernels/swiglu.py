"""Fused SwiGLU activation kernel (Pallas/TPU): silu(gate) * up in one VMEM pass
(saves one HBM round-trip of the (tokens x d_ff) intermediate on the MLP path)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _swiglu_kernel(g_ref, u_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    o_ref[...] = (g * jax.nn.sigmoid(g) * u).astype(o_ref.dtype)


def swiglu(gate, up, *, block_rows: int = 256, block_cols: int = 512,
           interpret: bool = True):
    """gate, up: (..., F) -> silu(gate) * up."""
    orig_shape = gate.shape
    f = orig_shape[-1]
    rows = math.prod(orig_shape[:-1])
    g2 = gate.reshape(rows, f)
    u2 = up.reshape(rows, f)
    br = min(block_rows, max(8, rows))
    bc = min(block_cols, f)
    rows_p = math.ceil(rows / br) * br
    cols_p = math.ceil(f / bc) * bc
    g2 = jnp.pad(g2, ((0, rows_p - rows), (0, cols_p - f)))
    u2 = jnp.pad(u2, ((0, rows_p - rows), (0, cols_p - f)))

    out = pl.pallas_call(
        _swiglu_kernel,
        grid=(rows_p // br, cols_p // bc),
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j)),
                  pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows_p, cols_p), gate.dtype),
        interpret=interpret,
    )(g2, u2)
    return out[:rows, :f].reshape(orig_shape)

"""Paged flash-prefill kernel (Pallas/TPU): chunk queries over block tables.

The prefill-side sibling of kernels/flash_decode.py.  A resumed prefill chunk
(the ISO scheduling quantum of serving/scheduler.py) attends its request's
page-resident KV prefix IN PLACE — no dense gather of the prefix before the
call — walking the block table page by page with an online softmax.

Layout (mirrors PagedKVCache, minus the period dim which the caller scans):

    q            (B, Hq, Sq, hd)    one prefill chunk per request (Sq may be
                                    bucket-padded; pad rows produce garbage
                                    that the caller masks/ignores)
    k/v pages    (N, ps, Hkv, hd)   page pool, N includes the scratch page
    block_tables (B, MB) int32      page ids, -1 pad (sanitised to 0 here)
    prefix_lens  (B,)    int32      valid paged-prefix tokens: key position
                                    ``j*ps + o`` is attended iff < prefix_len
    q_starts     (B,)    int32      absolute position of q[:, :, 0]

All three scalar-prefetched inputs are fully HETEROGENEOUS per row — each
batch row walks its own block table with its own prefix length and its own
query start.  That is the batched multi-request grant layout
(serving/paged_engine.py packs several requests' prefill grants into one
call): a fresh request rides as a row with ``prefix_len == 0`` (every page
masked, the output is the neutral partial state ``(0, NEG_INF, 0)``) next to
resumed rows at arbitrary depths, and the sliding-window mask anchors at each
row's own ``q_start``.  Nothing couples rows: the grid's batch dimension
indexes all per-row state, so a packed call is bit-identical per row to B
single-row calls (asserted in tests/test_flash_prefill_paged.py).

Grid is (batch, kv_head, q_block, page) with the page dimension iterated
sequentially (minor-most), exactly like the k-block dimension of
kernels/flash_prefill.py.  Block tables / prefix lengths / query starts ride
in via ``PrefetchScalarGridSpec`` scalar prefetch so the k/v BlockSpec index
maps resolve ``page -> pool slot`` before the kernel body runs (the TPU DMA
pattern for paged attention).  GQA is handled by blocking queries as
(Hkv, group, block_q): every grid step attends one kv head's whole query
group for one query block.

A request's pages cover positions [0, prefix_len) contiguously, so key
positions are pure arithmetic (``j*ps + offset``) — no gathered position
array.  The ``prefix_len`` mask also implements the prefix-sharing rule
(donor KV beyond the shared prefix sits at positions >= prefix_len) and
causality against the prefix is implied (every prefix position < q_start
<= q_pos); only the sliding window needs the per-row query position.

The kernel returns the *partial* softmax state ``(out, m, l)`` over the paged
prefix only; the caller folds the chunk's intra-call attention (earlier ISO
chunks of the same grant + the chunk itself, causal) in with one dense
partial-softmax merge — see layers/attention.attn_prefill_paged_partial.
That split keeps the pool read-only inside the kernel; the chunk's KV is
scattered to its pages afterwards by the engine.

``interpret=True`` (the default) runs the same kernel under the Pallas
interpreter — the CPU-container fallback, mirroring flash_decode.py.  When
compiled for real TPU hardware (``interpret=False``) the (8, 128) register
tile alignment of ``ps``/``hd`` and the sublane alignment of ``block_q`` are
ASSERTED up front (flash_decode.check_tpu_tile_alignment); tiny test shapes
rely on interpret mode's laxness.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _prefill_kernel(bt_ref, len_ref, qs_ref, q_ref, k_ref, v_ref,
                    o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr, *,
                    page_size: int, block_q: int, window: int,
                    num_pages: int):
    b = pl.program_id(0)
    iq = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                 # (group, bq, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)              # (ps, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)

    group, bq, hd = q.shape
    q2 = q.reshape(group * bq, hd)
    s = jnp.dot(q2, k.T) * (hd ** -0.5)                 # (group*bq, ps)

    prefix_len = len_ref[b]                             # valid prefix tokens
    k_pos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    # causality vs the prefix is implied: every valid prefix position is
    # < q_start <= q_pos.  Only the window mask needs the query position.
    mask = k_pos < prefix_len
    if window:
        row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        q_pos = qs_ref[b] + iq * block_q + jax.lax.rem(row, bq)
        mask &= k_pos > q_pos - window
    # explicit mask multiply (not just -inf fill): a fully-masked page keeps
    # m at NEG_INF and exp(0)=1 would otherwise leak weight per masked key
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                 # (group*bq, 1)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur) * mask
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(p, v)
    m_scr[...] = m_cur

    @pl.when(j == num_pages - 1)
    def _finish():
        l = l_scr[...]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)
        o_ref[0, 0] = out.reshape(group, bq, hd).astype(o_ref.dtype)
        m_ref[0, 0] = m_scr[...].reshape(group, bq, 1).astype(m_ref.dtype)
        l_ref[0, 0] = l.reshape(group, bq, 1).astype(l_ref.dtype)


def flash_prefill_paged(q, k_pages, v_pages, block_tables, prefix_lens,
                        q_starts, *, window: int = 0, block_q: int = 128,
                        interpret: bool = True):
    """Paged flash attention of one prefill chunk against its KV prefix.

    q: (B, Hq, Sq, hd); k_pages/v_pages: (N, ps, Hkv, hd); block_tables:
    (B, MB) int32 (-1 pad); prefix_lens: (B,) int32 valid prefix tokens;
    q_starts: (B,) int32 absolute position of each row's first query.

    Returns ``(out, m, l)`` fp32 partial softmax state over the paged prefix:
    out (B, Hq, Sq, hd) = acc / l, m (B, Hq, Sq, 1) running max, l
    (B, Hq, Sq, 1) running denominator.  Rows with ``prefix_lens == 0`` come
    back as (0, NEG_INF, 0) — the caller's merge with the chunk's own
    attention then reduces to plain causal self-attention.
    """
    B, Hq, Sq, hd = q.shape
    N, ps, Hkv, _ = k_pages.shape
    MB = block_tables.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    if not interpret:
        from repro.kernels.flash_decode import check_tpu_tile_alignment
        check_tpu_tile_alignment(ps, hd, "flash_prefill_paged")
        if block_q % 8 != 0:
            raise ValueError(
                f"flash_prefill_paged: block_q={block_q} must be a sublane "
                f"(8) multiple when compiled for hardware")

    block_q = min(block_q, max(8, Sq))
    sq_p = math.ceil(Sq / block_q) * block_q
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - Sq), (0, 0)))
    nq = sq_p // block_q

    # pad table entries (-1) alias page 0; they are always masked because a
    # request's pages cover positions [0, prefix_len) contiguously
    bt = jnp.clip(block_tables, 0, N - 1).astype(jnp.int32)
    qg = qp.reshape(B, Hkv, group, sq_p, hd)

    kernel = functools.partial(_prefill_kernel, page_size=ps, block_q=block_q,
                               window=window, num_pages=MB)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,            # block_tables, prefix_lens, q_starts
        grid=(B, Hkv, nq, MB),
        in_specs=[
            pl.BlockSpec((1, 1, group, block_q, hd),
                         lambda b, h, i, j, bt, ln, qs: (b, h, 0, i, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, i, j, bt, ln, qs: (bt[b, j], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, i, j, bt, ln, qs: (bt[b, j], 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, group, block_q, hd),
                         lambda b, h, i, j, bt, ln, qs: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, group, block_q, 1),
                         lambda b, h, i, j, bt, ln, qs: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, group, block_q, 1),
                         lambda b, h, i, j, bt, ln, qs: (b, h, 0, i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((group * block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((group * block_q, 1), jnp.float32),   # running denom
            pltpu.VMEM((group * block_q, hd), jnp.float32),  # running acc
        ],
    )
    out, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, group, sq_p, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, group, sq_p, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, group, sq_p, 1), jnp.float32),
        ],
        interpret=interpret,
    )(bt, prefix_lens.astype(jnp.int32), q_starts.astype(jnp.int32),
      qg, k_pages, v_pages)
    return (out.reshape(B, Hq, sq_p, hd)[:, :, :Sq],
            m.reshape(B, Hq, sq_p, 1)[:, :, :Sq],
            l.reshape(B, Hq, sq_p, 1)[:, :, :Sq])

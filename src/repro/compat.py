"""JAX version-compat shims.

The repo targets recent JAX, but must degrade gracefully on older installs
(e.g. 0.4.x, where ``jax.sharding.AxisType`` and the ``axis_types=`` kwarg of
``jax.make_mesh`` don't exist yet).  Centralising the fallbacks here keeps
version probes out of the hot modules.
"""
from __future__ import annotations

from typing import Sequence

import jax

# jax.sharding.AxisType landed after 0.4.x; None signals "explicit axis types
# unsupported — build plain meshes".
AxisType = getattr(jax.sharding, "AxisType", None)

# jax.shard_map was promoted out of jax.experimental after 0.4.x.
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:                                          # pragma: no cover - version dep
    from jax.experimental.shard_map import shard_map as _sm

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
        # old API named the (already-default-True) check kwarg differently
        if check_vma is not None:
            kw["check_rep"] = check_vma
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def axis_size(axis_name: str):
    """``jax.lax.axis_size`` (new JAX) or the classic ``psum(1, axis)`` idiom
    (old JAX) — both constant-fold inside shard_map bodies."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)          # pragma: no cover - version dep


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalised to one dict.

    Old JAX returns a list with one dict per program; new JAX returns the
    dict directly. Either may be empty/None on some backends."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def jit_cache_size(fn) -> int:
    """Number of compiled executables living in a ``jax.jit`` wrapper's cache.

    The CI compile-guard lane uses this as a compile counter: each cache
    entry is one (re)compilation of the jitted closure.  ``_cache_size`` is
    the stable-in-practice accessor on both 0.4.x and current JAX; fall back
    to 1 (the closure exists, so it compiled at least once) if a future
    release renames it.
    """
    probe = getattr(fn, "_cache_size", None)
    if probe is None:                          # pragma: no cover - version dep
        return 1
    try:
        return int(probe())
    except Exception:                          # pragma: no cover - version dep
        return 1


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, devices=None):
    """``jax.make_mesh`` with ``axis_types=Auto`` where supported.

    Older JAX has neither the kwarg nor the enum; auto mode is the default
    there, so dropping the argument is behaviour-preserving.
    """
    kw = {"devices": devices} if devices is not None else {}
    if AxisType is not None:
        try:
            return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                                 axis_types=(AxisType.Auto,) * len(axis_names),
                                 **kw)
        except TypeError:                      # enum exists but kwarg doesn't
            pass
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)

"""int8-quantized all-reduce — the paper's "communication dominates" mitigation.

The paper (4090 path) converts fp16 traffic to int8, halving wire bytes and cutting
the communication share from ~75% to ~50%.  On TPU we realise the same 2x with an
all-to-all + local-reduce + all-gather decomposition where BOTH wire phases carry
int8 payloads (the reduction itself accumulates in fp32 locally, so there is no
int8-summation overflow):

    1. split the partial along its last dim into tp shards; per-shard symmetric
       int8 quantization (per-row abs-max scales, fp16-ish fp32 scalars);
    2. all_to_all the int8 shards (wire: (n-1)/n * bytes(int8));
    3. local dequant + fp32 sum -> this device's slice of the reduced tensor;
    4. re-quantize the slice, all_gather int8 + scales (wire: (n-1)/n * bytes(int8));
    5. dequant, concat -> replicated result.

Total wire bytes ~= 2*(n-1)/n * size * 1B  vs  bf16 ring all-reduce
2*(n-1)/n * size * 2B  ==> exactly the paper's 2x.  A Pallas kernel
(`kernels/int8_quant.py`) provides the fused quantize step on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    """Per-row (last-dim) symmetric abs-max quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def quantized_pmean(g, axes, sizes) -> "jnp.ndarray":
    """int8 data-parallel gradient mean (the §Perf collective-term lever for
    giant-model training).  Applies ``quantized_psum`` per mesh axis (flattening
    the trailing dims so the last-dim split rule holds), then divides."""
    orig = g.shape
    flat = g.reshape(-1)
    n_total = 1
    for axis, n in zip(axes, sizes):
        n_total *= n
        pad = (-flat.shape[0]) % n
        fp = jnp.pad(flat, (0, pad))
        fp = quantized_psum(fp, axis, n)
        flat = fp[:flat.shape[0]] if pad else fp
    return (flat / n_total).reshape(orig).astype(g.dtype)


def quantized_psum(x, axis: str, tp: int):
    """Drop-in for ``lax.psum(x, axis)`` with int8 wire traffic.

    x: (..., D) with D % tp == 0, identical shape on every shard.
    """
    if tp == 1:
        return x
    d = x.shape[-1]
    assert d % tp == 0, (d, tp)
    xs = x.reshape(*x.shape[:-1], tp, d // tp)          # split last dim
    q, scale = quantize_int8(xs)                        # (..., tp, d/tp), (..., tp, 1)
    # wire phase 1: exchange shards
    q_t = jax.lax.all_to_all(q, axis, split_axis=q.ndim - 2, concat_axis=q.ndim - 2)
    s_t = jax.lax.all_to_all(scale, axis, split_axis=scale.ndim - 2,
                             concat_axis=scale.ndim - 2)
    # local fp32 reduce of the tp contributions for my slice
    part = jnp.sum(dequantize_int8(q_t, s_t), axis=-2)  # (..., d/tp) fp32
    # wire phase 2: re-quantize + all_gather
    q2, s2 = quantize_int8(part)
    q2_g = jax.lax.all_gather(q2, axis, axis=q2.ndim - 1, tiled=True)
    s2_g = jax.lax.all_gather(s2, axis, axis=s2.ndim - 1, tiled=True)
    # each gathered block of size d/tp shares one scale column
    blocks = q2_g.reshape(*q2_g.shape[:-1], tp, d // tp)
    out = (blocks.astype(jnp.float32) * s2_g[..., None]).reshape(*x.shape)
    return out.astype(x.dtype)

"""HLO-text analysis: collective wire bytes + structural overlap verification.

This is the dry-run "profiler" (no real TPU): it parses lowered/compiled HLO,
sums operand sizes of every collective (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute), converts them to per-device *wire* bytes with
the standard ring-algorithm factors, and — for the overlap check — builds the
def-use graph of each computation to count dot-FLOPs that are neither ancestors
nor descendants of a given collective (= work the latency-hiding scheduler can
hide it behind).  Baseline TP prefill has ~0 hideable FLOPs per collective; ISO
has about one chunk's worth.  EXPERIMENTS.md reports both.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)(\(.*)$")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([^}]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return 2
    first = m.group(1)
    return max(2, len([x for x in first.split(",") if x.strip() != ""]))


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    buffer_bytes: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    wire_bytes: float = 0.0

    def total_count(self) -> int:
        return sum(self.counts.values())


def parse_collectives(hlo: str) -> CollectiveStats:
    """Per-device wire bytes using ring-algorithm factors."""
    st = CollectiveStats()
    for line in hlo.splitlines():
        stripped = line.strip()
        m = _OP_RE.match(stripped)
        if not m:
            continue
        _, type_str, opname, rest = m.groups()
        kind = None
        for c in _COLLECTIVES:
            if opname == c or opname.startswith(c + "-start") or \
                    opname == c + "-start":
                kind = c
                break
        if kind is None:
            continue
        b = _shape_bytes(type_str)
        n = _group_size(stripped)
        st.counts[kind] += 1
        st.buffer_bytes[kind] += b
        if kind == "all-reduce":
            st.wire_bytes += 2.0 * (n - 1) / n * b
        elif kind == "all-gather":
            st.wire_bytes += (n - 1) / n * b          # b = gathered result
        elif kind == "reduce-scatter":
            st.wire_bytes += (n - 1) * b              # b = scattered result
        elif kind == "all-to-all":
            st.wire_bytes += (n - 1) / n * b
        else:                                         # collective-permute
            st.wire_bytes += b
    return st


# ---------------------------------------------------------------------------
# overlap structure: hideable dot-FLOPs per collective
# ---------------------------------------------------------------------------

@dataclass
class _Op:
    name: str
    kind: str
    type_str: str
    operands: List[str]
    line: str


# ---------------------------------------------------------------------------
# StableHLO (lowered, PRE-optimization) overlap metric.
#
# The post-optimization CPU HLO drops ``optimization_barrier`` (the CPU backend
# has no latency-hiding scheduler to protect), which lets the all-reduce
# combiner merge ISO's deliberately-serialised chunk collectives — so the
# compiled CPU module misrepresents what the TPU scheduler would see.  The
# LOWERED StableHLO preserves barriers and per-chunk collectives exactly, so
# the structural overlap check runs there.
# ---------------------------------------------------------------------------

_MLIR_DEF_RE = re.compile(r"^\s*%([\w#]+)(?::\d+)?\s*=\s*(.*)$")
_MLIR_REF_RE = re.compile(r"%([\w#]+)")
_MLIR_COLL = ("all_reduce", "all_gather", "reduce_scatter", "all_to_all",
              "collective_permute")


def overlap_metric_stablehlo(text: str) -> Dict[str, float]:
    """Per-collective hideable dot_generals, from lowered StableHLO MLIR."""
    # split into func bodies
    funcs: Dict[str, List[Tuple[str, str, List[str]]]] = {}
    current, depth = None, 0
    for line in text.splitlines():
        if "func.func" in line:
            m = re.search(r"@([\w\.]+)", line)
            current = m.group(1) if m else "anon"
            funcs[current] = []
            continue
        if current is None:
            continue
        m = _MLIR_DEF_RE.match(line)
        if not m:
            continue
        name, rest = m.groups()
        base = name.split("#")[0]
        kind = "other"
        if "dot_general" in rest or "convolution" in rest:
            kind = "dot"
        else:
            for c in _MLIR_COLL:
                if f"stablehlo.{c}" in rest:
                    kind = c
                    break
            if "optimization_barrier" in rest:
                kind = "barrier"
            elif "stablehlo.while" in rest:
                kind = "while"
        refs = [r.split("#")[0] for r in _MLIR_REF_RE.findall(rest)]
        funcs[current].append((base, kind, refs))

    best_name, best = None, []
    for fname, ops in funcs.items():
        n_c = sum(1 for _, k, _ in ops if k in _MLIR_COLL)
        if n_c > sum(1 for _, k, _ in best if k in _MLIR_COLL):
            best_name, best = fname, ops
    if not best:
        return {"collectives": 0, "avg_hideable_dots": 0.0,
                "hideable_fraction": 0.0, "total_dots": 0}

    by_name = {o[0]: i for i, o in enumerate(best)}
    preds = [[by_name[r] for r in refs if r in by_name] for _, _, refs in best]
    n = len(best)
    succs = [[] for _ in range(n)]
    for i, ps in enumerate(preds):
        for p in ps:
            succs[p].append(i)
    dots = [i for i, o in enumerate(best) if o[1] == "dot"]
    colls = [i for i, o in enumerate(best) if o[1] in _MLIR_COLL]
    if not colls:
        return {"collectives": 0, "avg_hideable_dots": 0.0,
                "hideable_fraction": 0.0, "total_dots": len(dots)}

    def reach(start_edges, i):
        out, stack = set(), list(start_edges[i])
        while stack:
            j = stack.pop()
            if j in out:
                continue
            out.add(j)
            stack.extend(start_edges[j])
        return out

    counts = []
    for a in colls:
        anc = reach(preds, a)
        desc = reach(succs, a)
        counts.append(sum(1 for d in dots if d not in anc and d not in desc))
    avg = sum(counts) / len(counts)
    return {"collectives": len(colls), "avg_hideable_dots": avg,
            "hideable_fraction": avg / max(len(dots), 1),
            "computation": best_name, "total_dots": len(dots),
            "per_collective": counts}


def _parse_computations(hlo: str) -> Dict[str, List[_Op]]:
    comps: Dict[str, List[_Op]] = {}
    current = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY") or (line.rstrip().endswith("{")
                                        and ("(" in line) and "=" not in line.split("(")[0]):
            header = line.split("(")[0].strip().lstrip("%")
            current = header.split()[-1] if header else "anon"
            comps[current] = []
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        m = _OP_RE.match(line.strip())
        if not m:
            continue
        name, type_str, opname, rest = m.groups()
        args_part = rest.split("(", 1)[1] if "(" in rest else ""
        args_part = args_part.split(")", 1)[0]
        operands = _OPERAND_RE.findall(args_part)
        comps[current].append(_Op(name, opname, type_str, operands, line))
    return comps


def _dot_flops(op: _Op) -> float:
    """Rough: 2 * prod(result dims) * contraction dim (from first operand)."""
    shapes = _SHAPE_RE.findall(op.type_str)
    if not shapes:
        return 0.0
    dims = [int(x) for x in shapes[0][1].split(",") if x]
    out = math.prod(dims) if dims else 1
    return 2.0 * out * 128.0  # contraction dim unknown from type alone; proxy


def overlap_metric(hlo: str) -> Dict[str, float]:
    """For the computation with the most all-reduces: fraction of dot ops that
    are dataflow-independent of each collective (hideable), averaged."""
    comps = _parse_computations(hlo)
    best_name, best = None, []
    for name, ops in comps.items():
        n_ar = sum(1 for o in ops if o.kind.startswith("all-reduce"))
        if n_ar > sum(1 for o in best if o.kind.startswith("all-reduce")):
            best_name, best = name, ops
    if not best:
        return {"collectives": 0, "avg_hideable_dots": 0.0,
                "hideable_fraction": 0.0}

    by_name = {o.name: i for i, o in enumerate(best)}
    n = len(best)
    # ancestors via bitsets would be heavy; use reachability with memo on DAG
    preds = [[by_name[x] for x in o.operands if x in by_name] for o in best]
    succs = [[] for _ in range(n)]
    for i, ps in enumerate(preds):
        for p in ps:
            succs[p].append(i)

    import functools
    import sys
    sys.setrecursionlimit(100000)

    anc_memo: Dict[int, set] = {}

    def ancestors(i: int) -> set:
        if i in anc_memo:
            return anc_memo[i]
        out = set()
        stack = list(preds[i])
        while stack:
            j = stack.pop()
            if j in out:
                continue
            out.add(j)
            stack.extend(preds[j])
        anc_memo[i] = out
        return out

    # post-optimization HLO wraps dots in fusion ops: weight each fusion call by
    # the dot count of its fused computation
    _CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
    dots_per_comp = {cname: sum(1 for o in ops
                                if o.kind in ("dot", "convolution"))
                     for cname, ops in comps.items()}

    def dot_weight(op: _Op) -> int:
        if op.kind in ("dot", "convolution"):
            return 1
        if op.kind == "fusion":
            m = _CALLS_RE.search(op.line)
            if m:
                return dots_per_comp.get(m.group(1), 0)
        return 0

    weights = [dot_weight(o) for o in best]
    dots = [i for i, w in enumerate(weights) if w > 0]
    ars = [i for i, o in enumerate(best) if o.kind.startswith("all-reduce")
           or o.kind in _COLLECTIVES]
    if not ars:
        return {"collectives": 0, "avg_hideable_dots": 0.0,
                "hideable_fraction": 0.0}

    hideable_counts = []
    for a in ars:
        a_anc = ancestors(a)
        desc = set()
        stack = list(succs[a])
        while stack:
            j = stack.pop()
            if j in desc:
                continue
            desc.add(j)
            stack.extend(succs[j])
        h = sum(weights[d] for d in dots
                if d not in a_anc and d not in desc and d != a)
        hideable_counts.append(h)
    avg = sum(hideable_counts) / len(hideable_counts)
    total_dots = sum(weights)
    frac = avg / max(total_dots, 1)
    return {"collectives": len(ars), "avg_hideable_dots": avg,
            "hideable_fraction": frac, "computation": best_name,
            "total_dots": total_dots}

"""Deferred TP collectives — the mechanism behind ISO.

In XLA-land there is no ``ncclAllReduceAsync``: collectives become
``all-reduce-start/done`` pairs and the latency-hiding scheduler overlaps an
in-flight collective with any *dataflow-independent* compute.  The baseline TP
transformer has no such independent compute (the residual add right after o_proj /
down_proj consumes the all-reduce result).  ISO creates it, by interleaving a second
sequence chunk.  This module packages the pattern:

    pend = psum_start(partial_c0, ctx)            # defer the collective
    other = attn(chunk1)                          # independent overlap work
    reduced, (other,) = psum_wait(pend, (other,)) # collective + ordering pin

``psum_wait`` performs the actual ``lax.psum`` and then ties its result to the
overlap outputs with ``jax.lax.optimization_barrier``.  The barrier does two jobs:

  1. it stops XLA's all-reduce *combiner* pass from merging consecutive chunk
     collectives into one (a merged collective would wait for both chunks' compute,
     destroying the pipeline) — after the barrier, chunk 1's collective input
     depends on chunk 0's collective result, which also matches the serial
     communication channel of real hardware;
  2. it pins the program-order the paper's Figure 1(d) prescribes, so the schedule
     survives CSE/motion passes.

The caller MUST thread the re-bound overlap outputs (second return value) into
downstream uses — that is what establishes the cross-chunk dependency chain.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AxisCtx:
    """Names/sizes of the mesh axes as seen inside shard_map.

    ``tp_axis=None`` means single-device execution (unit tests, oracles): all
    collectives degrade to identity.
    """
    tp_axis: Optional[str] = None
    tp: int = 1
    dp_axes: Tuple[str, ...] = ()
    quantized_comm: bool = False

    def axis_index(self):
        if self.tp_axis is None:
            return jnp.int32(0)
        return jax.lax.axis_index(self.tp_axis)


@dataclass
class Pending:
    """A collective that has been *issued* (dataflow-wise) but not awaited."""
    partial: jnp.ndarray
    ctx: AxisCtx

    @property
    def noop(self) -> bool:
        return self.ctx.tp_axis is None


def psum_start(partial, ctx: AxisCtx) -> Pending:
    return Pending(partial, ctx)


def _reduce(x, ctx: AxisCtx):
    if ctx.tp_axis is None:
        return x
    if ctx.quantized_comm:
        from repro.core.quantized_collectives import quantized_psum
        return quantized_psum(x, ctx.tp_axis, ctx.tp)
    return jax.lax.psum(x, ctx.tp_axis)


@jax.custom_jvp
def _self_barrier(x):
    """``optimization_barrier`` on a single value, differentiation-transparent.

    ``optimization_barrier`` has no JVP rule (this jaxlib), and the trailing
    reduce of a pattern-final stage sits inside the *training* forward pass
    too (run_stack_prefill -> flush_pending).  The barrier only pins the
    forward schedule; the tangent/cotangent of an identity is the identity,
    so differentiation passes through unbarriered."""
    return jax.lax.optimization_barrier((x,))[0]


@_self_barrier.defjvp
def _self_barrier_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return _self_barrier(x), t


def psum_wait(pend: Pending, overlap_outputs: Sequence = ()) -> Tuple:
    """Complete the collective; pin it against the overlap work.

    Returns (reduced, rebound_overlap_outputs).  Downstream code must use the
    rebound versions (see module docstring).

    With no overlap outputs the reduce is still SELF-barriered (unless the
    ctx is a no-op): a bare trailing ``lax.psum`` is fair game for XLA's
    all-reduce combiner/motion passes, which may merge it with a neighbouring
    collective and re-serialize a schedule the caller deliberately staged
    (e.g. the cross-block decode pending that resolves at the next stage
    top).  The barrier keeps each reduce an independent schedulable unit.
    """
    reduced = _reduce(pend.partial, pend.ctx)
    if not overlap_outputs:
        if pend.noop:
            return reduced, ()            # identity reduce: nothing to pin
        return _self_barrier(reduced), ()
    flat, tree = jax.tree_util.tree_flatten(tuple(overlap_outputs))
    pinned = jax.lax.optimization_barrier((reduced, *flat))
    return pinned[0], jax.tree_util.tree_unflatten(tree, list(pinned[1:]))


def psum_now(partial, ctx: AxisCtx):
    """Immediate (baseline, non-overlapped) reduce."""
    return _reduce(partial, ctx)


def dp_psum(x, ctx: AxisCtx):
    """Data-parallel reduction (gradients, loss) over the data(+pod) axes."""
    if not ctx.dp_axes:
        return x
    return jax.lax.psum(x, ctx.dp_axes)

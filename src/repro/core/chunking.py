"""Sequence-split policies (paper §3.2 and §6 "Discussion").

All splits are static Python ints (jit shape requirement).  Policies:

  even        two equal halves (paper's default);
  asymmetric  fixed fractions, default (0.6, 0.4) — paper's fix for the second
              chunk's heavier attention (it attends to the whole prefix);
  adaptive    cost-balanced split: solve for the boundary where the two chunks'
              (attention + MLP) FLOPs match, using the quadratic attention term
              (paper Figure 3's idea, in closed form);
  auto        pick the fraction that minimises simulated pipeline time under the
              analytic performance model (beyond-paper: ties into perf/model.py);
  multi-chunk any policy generalises to num_chunks > 2 (beyond-paper — deeper
              pipeline, smaller exposed head/tail bubbles).
"""
from __future__ import annotations

import math
from typing import Sequence, Tuple

from repro.config import ISOConfig, ModelConfig


def _round_to(x: int, m: int) -> int:
    return max(m, int(round(x / m)) * m)


def _normalize(lengths: Sequence[int], seq_len: int, align: int) -> Tuple[int, ...]:
    out = [max(align, _round_to(l, align)) for l in lengths[:-1]]
    used = sum(out)
    if used >= seq_len:                      # degenerate: fall back to even
        n = len(lengths)
        base = seq_len // n
        if base >= align:                    # keep alignment when possible
            base = (base // align) * align
        out = [base] * (n - 1)
        used = base * (n - 1)
    return tuple(out) + (seq_len - used,)


def even_split(seq_len: int, n: int, align: int = 128) -> Tuple[int, ...]:
    return _normalize([seq_len / n] * n, seq_len, align)


def fraction_split(seq_len: int, fractions: Sequence[float], align: int = 128
                   ) -> Tuple[int, ...]:
    return _normalize([f * seq_len for f in fractions], seq_len, align)


def adaptive_split(seq_len: int, n: int, cfg: ModelConfig, align: int = 128
                   ) -> Tuple[int, ...]:
    """Equalise per-chunk cost  c(a,b) = alpha*(b^2-a^2)/2 + beta*(b-a)  where the
    quadratic term is attention over the prefix and the linear term is the dense
    (QKV/O + MLP) compute per token."""
    d, hq = cfg.d_model, cfg.num_heads
    hd = cfg.resolved_head_dim
    # per-token-pair attention flops ~ 2 * 2 * Hq * hd ; per-token dense flops:
    alpha = 4.0 * hq * hd
    ff = cfg.d_ff or (cfg.moe.d_ff_expert * cfg.moe.top_k if cfg.moe else d * 4)
    beta = 2.0 * d * (hq * hd * 2 + cfg.num_kv_heads * hd * 2) + 6.0 * d * ff
    total = alpha * seq_len ** 2 / 2 + beta * seq_len
    per = total / n
    bounds = [0]
    for _ in range(n - 1):
        a = bounds[-1]
        # solve alpha*(b^2-a^2)/2 + beta*(b-a) = per  for b
        A, B, C = alpha / 2, beta, -(per + alpha * a * a / 2 + beta * a)
        b = (-B + math.sqrt(B * B - 4 * A * C)) / (2 * A)
        bounds.append(min(b, seq_len))
    lengths = [bounds[i + 1] - bounds[i] for i in range(n - 1)] + [seq_len - bounds[-1]]
    return _normalize(lengths, seq_len, align)


def auto_split(seq_len: int, n: int, cfg: ModelConfig, hw_name: str = "v5e",
               tp: int = 16, align: int = 128) -> Tuple[int, ...]:
    """Search fractions minimising the simulated ISO pipeline time."""
    from repro.perf.model import simulate_iso_fractions
    best, best_t = even_split(seq_len, n, align), float("inf")
    if n != 2:
        cands = [even_split(seq_len, n, align), adaptive_split(seq_len, n, cfg, align)]
    else:
        cands = [fraction_split(seq_len, (f, 1 - f), align)
                 for f in (0.35, 0.4, 0.45, 0.5, 0.55, 0.6, 0.65, 0.7)]
        cands.append(adaptive_split(seq_len, 2, cfg, align))
    for c in cands:
        t = simulate_iso_fractions(cfg, c, hw_name=hw_name, tp=tp)
        if t < best_t:
            best, best_t = c, t
    return best


def grant_buckets(max_tokens: int, min_bucket: int = 16,
                  explicit: Sequence[int] = ()) -> Tuple[int, ...]:
    """Grant-size buckets for compile-stable chunked prefill.

    The paged engine pads every prefill grant up to the next bucket length so
    ``PagedEngine._prefill_fns`` compiles one closure per bucket (times the
    row bucket under batched multi-request grants, where the same ladder with
    ``min_bucket=1`` also pads the PACK's row count) instead of one per
    distinct grant length/shape — the compile count is bounded by
    O(#buckets x #row_buckets) regardless of traffic.  Default: powers of two from
    ``min_bucket``, with the top bucket capped at ``max_tokens`` (any grant
    is at most the request's whole prompt, itself <= max_len).  ``explicit``
    overrides the ladder; it must still cover ``max_tokens``.
    """
    if explicit:
        out = tuple(sorted(set(int(b) for b in explicit)))
        assert out[0] >= 1 and out[-1] >= max_tokens, \
            f"explicit buckets {out} do not cover max_tokens={max_tokens}"
        return out
    b, out = max(1, min_bucket), []
    while b < max_tokens:
        out.append(b)
        b *= 2
    # cap the top bucket at max_tokens: no grant can exceed it, and a full
    # power-of-two top would pad the largest grants up to ~2x
    out.append(min(b, max_tokens))
    return tuple(out)


def round_to_bucket(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n (buckets ascending; asserts coverage)."""
    for b in buckets:
        if n <= b:
            return b
    raise AssertionError(f"grant of {n} tokens exceeds largest bucket "
                         f"{buckets[-1]}")


def split_chunks(seq_len: int, iso: ISOConfig, cfg: ModelConfig, *,
                 align: int = 0, tp: int = 16, hw_name: str = "v5e"
                 ) -> Tuple[int, ...]:
    """Main entry: chunk lengths for a prefill of ``seq_len`` tokens."""
    if (not iso.enabled or iso.num_chunks <= 1
            or seq_len < iso.min_chunk_tokens * iso.num_chunks):
        return (seq_len,)
    align = align or iso.chunk_align
    n = iso.num_chunks
    if iso.split_fractions:
        return fraction_split(seq_len, iso.split_fractions, align)
    if iso.split_policy == "even":
        return even_split(seq_len, n, align)
    if iso.split_policy == "asymmetric":
        fr = [0.6, 0.4] if n == 2 else [1.0 / n] * n
        return fraction_split(seq_len, fr, align)
    if iso.split_policy == "adaptive":
        return adaptive_split(seq_len, n, cfg, align)
    if iso.split_policy == "auto":
        return auto_split(seq_len, n, cfg, hw_name=hw_name, tp=tp, align=align)
    raise ValueError(f"unknown split policy {iso.split_policy!r}")

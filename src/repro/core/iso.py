"""The ISO scheduler — interleaved per-chunk execution of a transformer stack.

Baseline TP prefill executes, per layer:   compute -> all-reduce -> compute -> …
with nothing to hide the collectives behind.  ISO splits the sequence into chunks
and walks the (stage x chunk) grid in the order of paper Figure 1(d):

    unit order:  (s1,c0) (s1,c1) (s2,c0) (s2,c1) | next layer (s1,c0) …

At every unit we FIRST compute the unit's partial (dataflow-independent of the
previous unit's pending collective — that's the overlap), THEN complete the pending
collective via ``psum_wait`` (which barrier-pins the ordering, see core/overlap.py)
and apply its residual.  The pending collective crosses layer boundaries, so the
last chunk's MLP all-reduce hides behind the next layer's first attention.

Sequential cross-chunk state (KV prefix, SSM/mLSTM/sLSTM carries) is threaded
chunk-to-chunk within each layer — the paper's "preserve the order of attention
calculations between the two micro-batches".

The same machinery with ``chunks=1`` IS the baseline — benchmarked against ISO in
benchmarks/overlap_micro.py.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.overlap import AxisCtx, Pending, psum_start, psum_wait
from repro.models.blocks import BLOCK_STAGES, StageCtx
from repro.layers import attention as attn_lib


@dataclass
class PipeState:
    """Scan-carry of the layer pipeline."""
    xs: Tuple[jnp.ndarray, ...]            # per-chunk hidden states
    pend_partial: Optional[jnp.ndarray]    # unreduced partial of the last unit
    pend_base: Optional[jnp.ndarray]       # its residual base

    def tree(self):
        return (self.xs, self.pend_partial, self.pend_base)


def _init_seq_state(kind: str) -> Any:
    return (None, None) if kind == "hybrid" else None


def run_layer(p_layer, kind: str, state: PipeState, sctx: StageCtx,
              ctx: AxisCtx, layer_cache=None,
              pattern_ends_reduce: bool = True,
              starts: Sequence[int] = (0,)) -> Tuple[PipeState, Dict]:
    """Run one layer over all chunks in ISO order; returns extras for caches."""
    stages = BLOCK_STAGES[kind]
    n_chunks = len(state.xs)
    xs = list(state.xs)
    pend_partial, pend_base = state.pend_partial, state.pend_base
    pend_chunk = n_chunks - 1                 # invariant at layer entry

    kv_chunks: List = [None] * n_chunks
    extras_out: Dict[str, Any] = {}
    seq_state = _init_seq_state(kind)

    # whisper-style bidirectional attention: chunks attend to the FULL sequence,
    # so K/V are projected once per layer from all chunks before the unit loop.
    if sctx.mode == "encode" and kind in ("attn_mlp",):
        from repro.layers.norms import norm as _norm
        xn_full = jnp.concatenate(
            [_norm(p_layer["norm1"], xc, sctx.cfg.norm_type, sctx.cfg.rms_eps)
             for xc in xs], axis=1)
        seq_state = attn_lib.cross_kv(p_layer["attn"], xn_full, sctx.cfg)

    for s_idx, (fn, reduces) in enumerate(stages):
        for c in range(n_chunks):
            # baseline (1 chunk) — or any unit whose own chunk still owes a
            # residual: resolve the pending collective FIRST (serial schedule,
            # paper Figure 1(a)).  With >=2 chunks this branch never triggers:
            # the interleave resolves (s-1,c) during unit (s-1,c+1).
            if pend_partial is not None and pend_chunk == c:
                pend = psum_start(pend_partial, ctx)
                reduced, _ = psum_wait(pend)
                xs[pend_chunk] = pend_base + reduced
                pend_partial = pend_base = None
            out, seq_state_new, extras = fn(
                p_layer, xs[c], starts[c], seq_state, sctx, layer_cache)
            # resolve the pending collective, hidden behind this unit's compute
            if pend_partial is not None:
                pend = psum_start(pend_partial, ctx)
                reduced, rebound = psum_wait(pend, (out, seq_state_new))
                out, seq_state_new = rebound
                xs[pend_chunk] = pend_base + reduced
                pend_partial = pend_base = None
            seq_state = seq_state_new
            if "kv" in extras:
                kv_chunks[c] = extras["kv"]
            for k in ("ssm", "mlstm", "slstm", "moe_aux"):
                if k in extras:
                    if k == "moe_aux":
                        extras_out[k] = extras_out.get(k, 0.0) + extras[k]
                    else:
                        extras_out[k] = extras[k]
            if reduces:
                pend_partial, pend_base, pend_chunk = out, xs[c], c
            else:
                xs[c] = xs[c] + out
        # stage boundary: reset only per-stage state kinds that don't carry over
        if s_idx + 1 < len(stages):
            seq_state = _init_seq_state(kind)

    if kv_chunks[0] is not None:
        ks = jnp.concatenate([kv[0] for kv in kv_chunks], axis=1)
        vs = jnp.concatenate([kv[1] for kv in kv_chunks], axis=1)
        extras_out["kv_k"], extras_out["kv_v"] = ks, vs

    if not pattern_ends_reduce:
        # flush within the layer so the scan carry stays typed (xlstm periods
        # ending in sLSTM carry pending=None naturally; mixed cases flush here)
        if pend_partial is not None and not _kind_reduces_last(kind):
            pend = psum_start(pend_partial, ctx)
            reduced, _ = psum_wait(pend)
            xs[pend_chunk] = pend_base + reduced
            pend_partial = pend_base = None

    new_state = PipeState(tuple(xs), pend_partial, pend_base)
    return new_state, extras_out


def _kind_reduces_last(kind: str) -> bool:
    return BLOCK_STAGES[kind][-1][1]


def flush_pending(state: PipeState, ctx: AxisCtx) -> Tuple[jnp.ndarray, ...]:
    """Complete the trailing collective after the last layer."""
    xs = list(state.xs)
    if state.pend_partial is not None:
        pend = psum_start(state.pend_partial, ctx)
        reduced, _ = psum_wait(pend)
        xs[-1] = state.pend_base + reduced
    return tuple(xs)


def init_pipe_state(x_chunks: Sequence[jnp.ndarray], pattern: Sequence[str]
                    ) -> PipeState:
    """Zero pending (exact no-op: x += psum(0)) when the pattern ends in a
    reducing stage; None pending otherwise."""
    if _kind_reduces_last(pattern[-1]):
        z = jnp.zeros_like(x_chunks[-1])
        return PipeState(tuple(x_chunks), z, x_chunks[-1] * 0 + x_chunks[-1])
    return PipeState(tuple(x_chunks), None, None)


# ---------------------------------------------------------------------------
# whole-stack drivers
# ---------------------------------------------------------------------------

def run_stack_prefill(params_periods, pattern: Sequence[str], x_chunks,
                      starts: Sequence[int], sctx: StageCtx, ctx: AxisCtx,
                      layer_statics=None, remat: bool = False,
                      unroll: bool = False):
    """Scan over pattern periods.

    params_periods: pytree list, one entry per position in ``pattern``; each leaf
      stacked over periods: (P, ...).
    layer_statics: optional per-position scanned inputs (e.g. whisper cross-KV,
      stacked (P, ...)).
    Returns (x_chunks_final, per_layer_extras list-of-dicts (stacked over P)).
    """
    n_pos = len(pattern)

    def period_body(carry, scanned):
        xs, pend_p, pend_b = carry
        p_layers, statics = scanned
        state = PipeState(xs, pend_p, pend_b)
        extras_list = []
        for i, kind in enumerate(pattern):
            cache_i = statics[i] if statics is not None else None
            state, extras = run_layer(
                p_layers[i], kind, state, sctx, ctx, layer_cache=cache_i,
                pattern_ends_reduce=_kind_reduces_last(pattern[-1]),
                starts=starts)
            extras_list.append(extras)
        return (state.xs, state.pend_partial, state.pend_base), tuple(extras_list)

    body = jax.checkpoint(period_body) if remat else period_body
    state0 = init_pipe_state(x_chunks, pattern)
    carry0 = (state0.xs, state0.pend_partial, state0.pend_base)
    scanned = (params_periods, layer_statics)
    carry, extras = jax.lax.scan(body, carry0, scanned, unroll=unroll or 1)
    final = flush_pending(PipeState(*carry), ctx)
    return final, extras


def run_stack_decode(params_periods, pattern: Sequence[str], x, caches,
                     sctx: StageCtx, ctx: AxisCtx, unroll: bool = False):
    """One-token decode: sequential collectives (paper: overlap doesn't pay at
    decode), cache read+update per layer.  caches: per-position pytrees stacked
    over periods, each with optional k/v (+pos handled by caller), ssm/mlstm/slstm
    states, cross_k/v."""
    from repro.core.overlap import psum_now
    n_pos = len(pattern)

    def period_body(x, scanned):
        p_layers, caches_in = scanned
        caches_out = []
        for i, kind in enumerate(pattern):
            cache_i = caches_in[i]
            new_cache = dict(cache_i) if cache_i is not None else None
            for fn, reduces in BLOCK_STAGES[kind]:
                out, _, extras = fn(p_layers[i], x, 0, _init_seq_state(kind),
                                    sctx, cache_i)
                if reduces:
                    out = psum_now(out, ctx)
                x = x + out
                if "kv" in extras and new_cache is not None and "k" in new_cache:
                    # insert the K new tokens (K=1 decode / K>1 speculative
                    # verify; multi-token inserts must not straddle the ring
                    # boundary — the engine aligns slots)
                    k_new, v_new = extras["kv"]
                    K = k_new.shape[1]
                    slot = (sctx.lengths % new_cache["k"].shape[1]).astype(jnp.int32)
                    upd = lambda c, n, s: jax.vmap(
                        lambda cb, nb, sb: jax.lax.dynamic_update_slice(
                            cb, nb.astype(cb.dtype), (sb, 0, 0)))(c, n, s)
                    new_cache["k"] = upd(new_cache["k"], k_new, slot)
                    new_cache["v"] = upd(new_cache["v"], v_new, slot)
                    if "pos" in new_cache:
                        new_cache["pos"] = jax.vmap(
                            lambda pb, sb, lb: jax.lax.dynamic_update_slice(
                                pb, (lb + jnp.arange(K)).astype(pb.dtype),
                                (sb,)))(new_cache["pos"], slot, sctx.lengths)
                for sk in ("ssm", "mlstm", "slstm"):
                    if sk in extras and new_cache is not None:
                        new_cache[sk] = extras[sk]
            caches_out.append(new_cache)
        return x, tuple(caches_out)

    x, new_caches = jax.lax.scan(period_body, x, (params_periods, caches),
                                 unroll=unroll or 1)
    return x, new_caches

"""The ISO scheduler — interleaved per-chunk execution of a transformer stack.

Baseline TP prefill executes, per layer:   compute -> all-reduce -> compute -> …
with nothing to hide the collectives behind.  ISO splits the sequence into chunks
and walks the (stage x chunk) grid in the order of paper Figure 1(d):

    unit order:  (s1,c0) (s1,c1) (s2,c0) (s2,c1) | next layer (s1,c0) …

At every unit we FIRST compute the unit's partial (dataflow-independent of the
previous unit's pending collective — that's the overlap), THEN complete the pending
collective via ``psum_wait`` (which barrier-pins the ordering, see core/overlap.py)
and apply its residual.  The pending collective crosses layer boundaries, so the
last chunk's MLP all-reduce hides behind the next layer's first attention.

Sequential cross-chunk state (KV prefix, SSM/mLSTM/sLSTM carries) is threaded
chunk-to-chunk within each layer — the paper's "preserve the order of attention
calculations between the two micro-batches".

The same machinery with ``chunks=1`` IS the baseline — benchmarked against ISO in
benchmarks/overlap_micro.py.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.overlap import AxisCtx, Pending, psum_start, psum_wait
from repro.models.blocks import BLOCK_STAGES, StageCtx
from repro.layers import attention as attn_lib


@dataclass
class PipeState:
    """Scan-carry of the layer pipeline."""
    xs: Tuple[jnp.ndarray, ...]            # per-chunk hidden states
    pend_partial: Optional[jnp.ndarray]    # unreduced partial of the last unit
    pend_base: Optional[jnp.ndarray]       # its residual base

    def tree(self):
        return (self.xs, self.pend_partial, self.pend_base)


def _init_seq_state(kind: str) -> Any:
    return (None, None) if kind == "hybrid" else None


def run_layer(p_layer, kind: str, state: PipeState, sctx: StageCtx,
              ctx: AxisCtx, layer_cache=None,
              pattern_ends_reduce: bool = True,
              starts: Sequence[int] = (0,),
              ladder: bool = False) -> Tuple[PipeState, Dict]:
    """Run one layer over all chunks in ISO order; returns extras for caches.

    ``ladder=True`` switches to the Ladder-residual wiring (PAPERS.md,
    arXiv 2501.06589): the pre-resolve branch below is skipped, so every
    stage computes on the residual stream as of TWO stages ago — stage k's
    input is ``x + sum_{j<=k-2} AR(out_j)`` and ``AR(out_{k-1})`` completes
    behind stage k's compute (the existing post-compute resolve).  This is a
    DIFFERENT model function from the standard wiring, not a schedule: it
    must be selected by the config (``ModelConfig.residual_wiring``) for
    prefill and decode consistently.  Ladder runs single-chunk — the lagged
    residual already supplies the overlap window, and an ISO chunk
    interleave would resolve each chunk's pending during the *other* chunk's
    unit, silently restoring the standard wiring per chunk."""
    stages = BLOCK_STAGES[kind]
    n_chunks = len(state.xs)
    assert not ladder or n_chunks == 1, "ladder wiring runs single-chunk"
    assert not ladder or all(r for _, r in stages), \
        "ladder wiring needs every stage reducing (attention-style blocks)"
    xs = list(state.xs)
    pend_partial, pend_base = state.pend_partial, state.pend_base
    pend_chunk = n_chunks - 1                 # invariant at layer entry

    kv_chunks: List = [None] * n_chunks
    extras_out: Dict[str, Any] = {}
    seq_state = _init_seq_state(kind)

    # whisper-style bidirectional attention: chunks attend to the FULL sequence,
    # so K/V are projected once per layer from all chunks before the unit loop.
    if sctx.mode == "encode" and kind in ("attn_mlp",):
        from repro.layers.norms import norm as _norm
        xn_full = jnp.concatenate(
            [_norm(p_layer["norm1"], xc, sctx.cfg.norm_type, sctx.cfg.rms_eps)
             for xc in xs], axis=1)
        seq_state = attn_lib.cross_kv(p_layer["attn"], xn_full, sctx.cfg)

    for s_idx, (fn, reduces) in enumerate(stages):
        for c in range(n_chunks):
            # baseline (1 chunk) — or any unit whose own chunk still owes a
            # residual: resolve the pending collective FIRST (serial schedule,
            # paper Figure 1(a)).  With >=2 chunks this branch never triggers:
            # the interleave resolves (s-1,c) during unit (s-1,c+1).  Ladder
            # wiring skips it on purpose: the stage computes on the lagged
            # residual and the pending resolves AFTER, behind this compute.
            if not ladder and pend_partial is not None and pend_chunk == c:
                pend = psum_start(pend_partial, ctx)
                reduced, _ = psum_wait(pend)
                xs[pend_chunk] = pend_base + reduced
                pend_partial = pend_base = None
            out, seq_state_new, extras = fn(
                p_layer, xs[c], starts[c], seq_state, sctx, layer_cache)
            # resolve the pending collective, hidden behind this unit's compute
            if pend_partial is not None:
                pend = psum_start(pend_partial, ctx)
                reduced, rebound = psum_wait(pend, (out, seq_state_new))
                out, seq_state_new = rebound
                xs[pend_chunk] = pend_base + reduced
                pend_partial = pend_base = None
            seq_state = seq_state_new
            if "kv" in extras:
                kv_chunks[c] = extras["kv"]
            for k in ("ssm", "mlstm", "slstm", "moe_aux"):
                if k in extras:
                    if k == "moe_aux":
                        extras_out[k] = extras_out.get(k, 0.0) + extras[k]
                    else:
                        extras_out[k] = extras[k]
            if reduces:
                pend_partial, pend_base, pend_chunk = out, xs[c], c
            else:
                xs[c] = xs[c] + out
        # stage boundary: reset only per-stage state kinds that don't carry over
        if s_idx + 1 < len(stages):
            seq_state = _init_seq_state(kind)

    if kv_chunks[0] is not None:
        ks = jnp.concatenate([kv[0] for kv in kv_chunks], axis=1)
        vs = jnp.concatenate([kv[1] for kv in kv_chunks], axis=1)
        extras_out["kv_k"], extras_out["kv_v"] = ks, vs

    if not pattern_ends_reduce:
        # flush within the layer so the scan carry stays typed (xlstm periods
        # ending in sLSTM carry pending=None naturally; mixed cases flush here)
        if pend_partial is not None and not _kind_reduces_last(kind):
            pend = psum_start(pend_partial, ctx)
            reduced, _ = psum_wait(pend)
            xs[pend_chunk] = pend_base + reduced
            pend_partial = pend_base = None

    new_state = PipeState(tuple(xs), pend_partial, pend_base)
    return new_state, extras_out


def _kind_reduces_last(kind: str) -> bool:
    return BLOCK_STAGES[kind][-1][1]


def flush_pending(state: PipeState, ctx: AxisCtx) -> Tuple[jnp.ndarray, ...]:
    """Complete the trailing collective after the last layer."""
    xs = list(state.xs)
    if state.pend_partial is not None:
        pend = psum_start(state.pend_partial, ctx)
        reduced, _ = psum_wait(pend)
        xs[-1] = state.pend_base + reduced
    return tuple(xs)


def init_pipe_state(x_chunks: Sequence[jnp.ndarray], pattern: Sequence[str]
                    ) -> PipeState:
    """Zero pending (exact no-op: x += psum(0)) when the pattern ends in a
    reducing stage; None pending otherwise."""
    if _kind_reduces_last(pattern[-1]):
        z = jnp.zeros_like(x_chunks[-1])
        return PipeState(tuple(x_chunks), z, x_chunks[-1] * 0 + x_chunks[-1])
    return PipeState(tuple(x_chunks), None, None)


# ---------------------------------------------------------------------------
# whole-stack drivers
# ---------------------------------------------------------------------------

def run_stack_prefill(params_periods, pattern: Sequence[str], x_chunks,
                      starts: Sequence[int], sctx: StageCtx, ctx: AxisCtx,
                      layer_statics=None, remat: bool = False,
                      unroll: bool = False, ladder: bool = False):
    """Scan over pattern periods.

    params_periods: pytree list, one entry per position in ``pattern``; each leaf
      stacked over periods: (P, ...).
    layer_statics: optional per-position scanned inputs (e.g. whisper cross-KV,
      stacked (P, ...)).
    Returns (x_chunks_final, per_layer_extras list-of-dicts (stacked over P)).

    ``starts`` are CALL-RELATIVE chunk offsets (static ints — the ISO chunk
    split of the call length); each row's absolute position is
    ``sctx.pos_offset + starts[c] + t``.  With batched multi-request grants
    ``sctx.pos_offset`` / ``sctx.lengths`` (paged prefix lens) /
    ``sctx.valid_len`` are per-row (B,) vectors — the SAME (stage x chunk)
    interleave then overlaps the whole packed batch's collectives at once,
    which is exactly why packing pays: one ISO schedule amortised over N
    requests' chunks instead of N serialized batch-1 schedules.
    """
    n_pos = len(pattern)

    def period_body(carry, scanned):
        xs, pend_p, pend_b = carry
        p_layers, statics = scanned
        state = PipeState(xs, pend_p, pend_b)
        extras_list = []
        for i, kind in enumerate(pattern):
            cache_i = statics[i] if statics is not None else None
            state, extras = run_layer(
                p_layers[i], kind, state, sctx, ctx, layer_cache=cache_i,
                pattern_ends_reduce=_kind_reduces_last(pattern[-1]),
                starts=starts, ladder=ladder)
            extras_list.append(extras)
        return (state.xs, state.pend_partial, state.pend_base), tuple(extras_list)

    body = jax.checkpoint(period_body) if remat else period_body
    state0 = init_pipe_state(x_chunks, pattern)
    carry0 = (state0.xs, state0.pend_partial, state0.pend_base)
    scanned = (params_periods, layer_statics)
    carry, extras = jax.lax.scan(body, carry0, scanned, unroll=unroll or 1)
    final = flush_pending(PipeState(*carry), ctx)
    return final, extras


def _apply_decode_cache_update(new_cache, extras, sctx: StageCtx) -> None:
    """Fold one stage's decode extras into its cache (in place).

    Shared by every decode driver so paged scatter / dense ring insert /
    recurrent-state advance stay byte-identical across schedules: page pools
    (``k_pages``/``v_pages``) take the window's KV through the block tables,
    dense ring caches insert the K new tokens at ``lengths % ring``, and
    recurrent states (ssm/mlstm/slstm) are replaced wholesale."""
    if new_cache is None:
        return
    if "kv" in extras and "k_pages" in new_cache:
        _scatter_token_to_pages(new_cache, extras["kv"], sctx.lengths,
                                sctx.block_tables, sctx.decode_mask)
    elif "kv" in extras and "k" in new_cache:
        # insert the K new tokens (K=1 decode / K>1 speculative verify;
        # multi-token inserts must not straddle the ring boundary — the
        # engine aligns slots)
        k_new, v_new = extras["kv"]
        K = k_new.shape[1]
        slot = (sctx.lengths % new_cache["k"].shape[1]).astype(jnp.int32)
        upd = lambda c, n, s: jax.vmap(
            lambda cb, nb, sb: jax.lax.dynamic_update_slice(
                cb, nb.astype(cb.dtype), (sb, 0, 0)))(c, n, s)
        new_cache["k"] = upd(new_cache["k"], k_new, slot)
        new_cache["v"] = upd(new_cache["v"], v_new, slot)
        if "pos" in new_cache:
            new_cache["pos"] = jax.vmap(
                lambda pb, sb, lb: jax.lax.dynamic_update_slice(
                    pb, (lb + jnp.arange(K)).astype(pb.dtype),
                    (sb,)))(new_cache["pos"], slot, sctx.lengths)
    for sk in ("ssm", "mlstm", "slstm"):
        if sk in extras:
            new_cache[sk] = extras[sk]


def run_stack_decode(params_periods, pattern: Sequence[str], x, caches,
                     sctx: StageCtx, ctx: AxisCtx, unroll: bool = False,
                     schedule: str = "sequential"):
    """Decode (x: (B,K,D), K=1 plain / K>1 speculative verify), cache
    read+update per layer.  caches: per-position pytrees stacked over
    periods, each with optional k/v (+pos handled by caller),
    ssm/mlstm/slstm states, cross_k/v.  ``sctx.kv_splits`` > 1 runs each
    paged attention's page walk as that many split-KV spans
    (kernels/flash_decode.py) — static, so it is part of the caller's
    compile key.

    ``schedule``:

    * ``"sequential"`` — immediate ``psum_now`` per reducing stage (paper:
      batch-split overlap doesn't pay at decode without a second chunk).
    * ``"cross_block"`` — every reduce is DEFERRED and resolves at the top
      of the next stage, riding the scan carry across the block/period
      boundary.  The KV page scatter (dataflow-independent of the reduce)
      lands inside the start→wait window, and the window around the
      trailing reduce spans the next period's parameter gathers.  Token
      streams are bit-identical to sequential at fp32 (same reduces, same
      residual adds, in the same order — the barrier is an identity; at
      bf16 the restructured graph may fuse differently and round one ulp
      apart, as any schedule change does); the win is structural: each
      all-reduce becomes an independent schedulable unit
      XLA's latency-hiding scheduler (launch/mesh.enable_latency_hiding)
      can start early and complete late.  Without those flags it is a
      numeric and scheduling no-op.
    """
    from repro.core.overlap import psum_now
    assert schedule in ("sequential", "cross_block"), schedule
    defer = schedule == "cross_block"
    ends_reduce = _kind_reduces_last(pattern[-1])

    def resolve(x, pend):
        if pend is None:
            return x
        reduced, _ = psum_wait(psum_start(pend, ctx))
        return x + reduced

    def period_body(carry, scanned):
        x, pend = carry if defer else (carry, None)
        p_layers, caches_in = scanned
        caches_out = []
        for i, kind in enumerate(pattern):
            cache_i = caches_in[i]
            new_cache = dict(cache_i) if cache_i is not None else None
            for fn, reduces in BLOCK_STAGES[kind]:
                # cross-block: the previous stage's pending resolves HERE,
                # after a window that covered the previous stage's KV
                # scatter (and, across the period boundary, the scan's
                # parameter gathers for this period)
                x = resolve(x, pend)
                pend = None
                out, _, extras = fn(p_layers[i], x, 0, _init_seq_state(kind),
                                    sctx, cache_i)
                if reduces and defer:
                    pend = out                      # defer past the scatter
                elif reduces:
                    x = x + psum_now(out, ctx)
                else:
                    x = x + out
                _apply_decode_cache_update(new_cache, extras, sctx)
            caches_out.append(new_cache)
        if defer:
            assert (pend is not None) == ends_reduce
            return (x, pend), tuple(caches_out)
        return x, tuple(caches_out)

    if defer and ends_reduce:
        # zero pending: the first period's first resolve is an exact no-op
        carry0 = (x, jnp.zeros_like(x))
    elif defer:
        carry0 = (x, None)
    else:
        carry0 = x
    carry, new_caches = jax.lax.scan(period_body, carry0,
                                     (params_periods, caches),
                                     unroll=unroll or 1)
    if defer:
        x, pend = carry
        x = resolve(x, pend)
    else:
        x = carry
    return x, new_caches


def run_stack_decode_ladder(params_periods, pattern: Sequence[str], x, caches,
                            sctx: StageCtx, ctx: AxisCtx,
                            unroll: bool = False, defer: bool = True):
    """Ladder-residual decode (PAPERS.md, arXiv 2501.06589).

    Stage k consumes the residual stream as of stage k-2:

        input_k = x_emb + sum_{j <= k-2} AR(out_j)

    so ``AR(out_{k-1})`` is dataflow-independent of stage k's compute and
    completes behind it — across block AND period boundaries, since the
    pending partial rides the scan carry.  Unlike the batch-split schedule
    this needs no second batch half (works at B=1) and no sequence chunk:
    the lag IS the overlap window.

    This is a different model function from the standard wiring (the RMSNorm
    between stages is nonlinear, so the one-stage lag cannot be folded
    away); it must be selected by the config (``ModelConfig.residual_wiring
    = "ladder"``) consistently for prefill (run_layer ``ladder=True``) and
    decode, or preemption-recompute would diverge from the decode stream.

    ``defer=False`` is the schedule-differential twin: the SAME ladder
    function with every collective resolved immediately (``psum_now``).
    Deferred vs immediate is bit-identical at fp32 — same reduces, same
    residual adds, same order; the barrier is an identity — which is what
    tests/test_ladder.py pins.  Works on paged and dense ring caches (the
    cache fold is shared with ``run_stack_decode``).
    """
    from repro.core.overlap import psum_now
    for kind in pattern:
        assert all(r for _, r in BLOCK_STAGES[kind]), \
            "ladder wiring needs every stage reducing (attention-style blocks)"

    def period_body(carry, scanned):
        x, pend = carry
        p_layers, caches_in = scanned
        caches_out = []
        for i, kind in enumerate(pattern):
            cache_i = caches_in[i]
            new_cache = dict(cache_i) if cache_i is not None else None
            for fn, reduces in BLOCK_STAGES[kind]:
                # compute on the LAGGED residual (excludes the pending reduce)
                out, _, extras = fn(p_layers[i], x, 0, _init_seq_state(kind),
                                    sctx, cache_i)
                # resolve the previous stage's collective behind this compute
                if defer:
                    reduced, (out,) = psum_wait(psum_start(pend, ctx), (out,))
                else:
                    reduced = psum_now(pend, ctx)
                x = x + reduced
                # the scatter sits inside the NEW pending's window (it
                # resolves during the next stage's compute)
                _apply_decode_cache_update(new_cache, extras, sctx)
                pend = out
            caches_out.append(new_cache)
        return (x, pend), tuple(caches_out)

    # zero pending: the first stage's resolve is an exact no-op (x += psum(0))
    carry0 = (x, jnp.zeros_like(x))
    (x, pend), new_caches = jax.lax.scan(period_body, carry0,
                                         (params_periods, caches),
                                         unroll=unroll or 1)
    x = x + psum_now(pend, ctx)               # trailing flush
    return x, new_caches


# ---------------------------------------------------------------------------
# batch-split ISO decode (paged TP serving)
# ---------------------------------------------------------------------------

_BATCHED_STATE_KEYS = ("ssm", "mlstm", "slstm")


def _scatter_token_to_pages(new_cache, kv_new, lengths, block_tables,
                            decode_mask):
    """Scatter the decode window's (k, v) straight into block-table pages.

    kv_new: (B, K, Hkv, hd) — K=1 plain decode, K>1 a speculative verify
    window whose token qi lands at position ``lengths[b] + qi``.  Inactive
    slots (and positions with no capacity) route to the scratch page."""
    from repro.serving.kvcache import window_page_coords
    k_new, v_new = kv_new                               # (B, K, Hkv, hd)
    kp = new_cache["k_pages"]                           # (N+1, ps, Hkv, hd)
    page, off, _, _ = window_page_coords(
        lengths, block_tables, k_new.shape[1], kp.shape[1],
        scratch=kp.shape[0] - 1, decode_mask=decode_mask)
    new_cache["k_pages"] = kp.at[page, off].set(k_new.astype(kp.dtype))
    new_cache["v_pages"] = new_cache["v_pages"].at[page, off].set(
        v_new.astype(kp.dtype))


def _slice_cache_half(cache, lo: int, hi: int):
    """Batch-slice the recurrent leaves of a paged decode cache; the page
    pools (no batch dim — shared across requests) pass through whole."""
    if cache is None:
        return None
    out = {}
    for k, v in cache.items():
        if k in _BATCHED_STATE_KEYS:
            out[k] = jax.tree_util.tree_map(lambda a: a[lo:hi], v)
        else:
            out[k] = v
    return out


def run_stack_decode_overlap(params_periods, pattern: Sequence[str], x, caches,
                             sctx: StageCtx, ctx: AxisCtx,
                             unroll: bool = False):
    """Decode with the ISO schedule extended to the BATCH dimension.

    Figure 1(d) splits a *sequence* into chunks so one chunk's TP all-reduce
    hides behind the other's compute.  At decode there is no sequence to
    split — but a continuous-batching step carries many independent requests,
    so the batch splits instead: requests [0, B/2) and [B/2, B) are the two
    "chunks".  They share no state (separate KV pages, separate recurrent
    slots), so unlike prefill there is no sequential cross-chunk edge to
    respect — each half's deferred ``psum_start`` completes during the other
    half's compute, pinned by ``psum_wait``'s optimization barrier.

    Paged caches only (``k_pages``/``v_pages`` + block tables via ``sctx``):
    the pool is read shared by both halves and the per-half KV scatters are
    threaded functionally half0 -> half1.  With ``ctx.tp_axis=None`` the
    collectives degrade to identity and this is numerically the plain
    ``run_stack_decode`` split in two.  ``sctx.kv_splits`` rides into each
    half's StageCtx through the dataclass replace below, so split-KV
    flash-decode composes with the batch-split schedule unchanged.
    """
    from dataclasses import replace as _dc_replace

    B = x.shape[0]
    if B < 2:
        # a single resident request has no second half to overlap with —
        # degrade to the sequential schedule instead of crashing (the
        # engine normally falls back before reaching here; this keeps
        # direct callers safe too)
        return run_stack_decode(params_periods, pattern, x, caches, sctx,
                                ctx, unroll=unroll)
    B2 = B // 2
    bounds = ((0, B2), (B2, B))

    def sctx_half(lo, hi):
        return _dc_replace(
            sctx, lengths=sctx.lengths[lo:hi],
            block_tables=None if sctx.block_tables is None
            else sctx.block_tables[lo:hi],
            decode_mask=None if sctx.decode_mask is None
            else sctx.decode_mask[lo:hi])

    sctxs = [sctx_half(lo, hi) for lo, hi in bounds]

    # the pending unit's half index is static Python state: the stage/half
    # loops are unrolled, and at every period boundary the pending (if any)
    # is ALWAYS half 1's trailing reduce — so it never needs to ride the
    # scan carry (where it would become a traced, unusable list index)
    ends_reduce = _kind_reduces_last(pattern[-1])

    def period_body(carry, scanned):
        xs, pend_partial, pend_base = carry
        pend_h = 1
        xs = list(xs)
        p_layers, caches_in = scanned
        caches_out = []
        for i, kind in enumerate(pattern):
            cache_i = caches_in[i]
            new_cache = dict(cache_i) if cache_i is not None else None
            assert new_cache is None or "k" not in new_cache, \
                "overlap decode supports paged caches only (k_pages/v_pages)"
            state_halves = [None, None]
            for fn, reduces in BLOCK_STAGES[kind]:
                for h in range(2):
                    lo, hi = bounds[h]
                    # per-half cache view: shared pools read the LATEST
                    # functional version (half0's scatter visible to half1)
                    ch = _slice_cache_half(new_cache, lo, hi)
                    out, _, extras = fn(p_layers[i], xs[h], 0,
                                        _init_seq_state(kind), sctxs[h], ch)
                    # this half's KV scatter is dataflow-independent of the
                    # other half's pending reduce — land it BEFORE the
                    # resolve so it sits inside the overlap window too
                    scattered = "kv" in extras and new_cache is not None \
                        and "k_pages" in new_cache
                    if scattered:
                        _scatter_token_to_pages(
                            new_cache, extras["kv"], sctxs[h].lengths,
                            sctxs[h].block_tables, sctxs[h].decode_mask)
                    # resolve the OTHER half's pending collective behind this
                    # half's compute (unit order of Figure 1(d))
                    if pend_partial is not None:
                        pend = psum_start(pend_partial, ctx)
                        pins = (out,) + ((new_cache["k_pages"],
                                          new_cache["v_pages"])
                                         if scattered else ())
                        reduced, rebound = psum_wait(pend, pins)
                        out = rebound[0]
                        if scattered:
                            new_cache["k_pages"] = rebound[1]
                            new_cache["v_pages"] = rebound[2]
                        xs[pend_h] = pend_base + reduced
                        pend_partial = pend_base = None
                    for sk in _BATCHED_STATE_KEYS:
                        if sk in extras and new_cache is not None:
                            state_halves[h] = state_halves[h] or {}
                            state_halves[h][sk] = extras[sk]
                    if reduces:
                        pend_partial, pend_base, pend_h = out, xs[h], h
                    else:
                        xs[h] = xs[h] + out
            # stitch per-half recurrent states back to full batch
            if new_cache is not None and any(state_halves):
                for sk in _BATCHED_STATE_KEYS:
                    if sk in (state_halves[0] or {}):
                        new_cache[sk] = jax.tree_util.tree_map(
                            lambda a, b: jnp.concatenate([a, b], axis=0),
                            state_halves[0][sk], state_halves[1][sk])
            caches_out.append(new_cache)
        assert (pend_partial is not None) == ends_reduce and \
            (pend_partial is None or pend_h == 1), \
            "period boundary must leave the pending on half 1 (or none)"
        return (tuple(xs), pend_partial, pend_base), tuple(caches_out)

    x_halves = (x[:B2], x[B2:])
    if ends_reduce:
        # steady-state carry: half1 owes a reduce at every period boundary;
        # a zero pending makes the first period an exact no-op resolve
        carry0 = (x_halves, jnp.zeros_like(x_halves[1]), x_halves[1])
    else:
        carry0 = (x_halves, None, None)
    carry, new_caches = jax.lax.scan(period_body, carry0,
                                     (params_periods, caches),
                                     unroll=unroll or 1)
    xs, pend_partial, pend_base = carry
    xs = list(xs)
    if pend_partial is not None:
        pend = psum_start(pend_partial, ctx)
        reduced, _ = psum_wait(pend)
        xs[1] = pend_base + reduced
    return jnp.concatenate(xs, axis=0), new_caches

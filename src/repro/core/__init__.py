# The paper's primary contribution: ISO — intra-sequence overlap of computation
# and communication for LLM inference (Xiao & Su, Baichuan 2024).
from repro.core.overlap import AxisCtx, Pending, psum_now, psum_start, psum_wait  # noqa: F401
from repro.core.chunking import split_chunks  # noqa: F401

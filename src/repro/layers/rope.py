"""Rotary position embeddings with explicit position offsets (chunked prefill needs
each chunk to know its absolute start position)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (S,) or (B, S) absolute token positions."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                       # (D/2,)
    pos = positions.astype(jnp.float32)
    ang = pos[..., None] * inv                       # (..., S, D/2)
    if ang.ndim == 2:                                # (S, D/2) -> broadcast over B
        ang = ang[None]
    cos = jnp.cos(ang)[:, :, None, :]                # (B, S, 1, D/2)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(seq_len: int, d_model: int, offset: int = 0):
    """Whisper-style fixed sinusoid table slice [offset, offset+seq_len)."""
    pos = jnp.arange(offset, offset + seq_len, dtype=jnp.float32)[:, None]
    half = d_model // 2
    inv = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = pos * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)

from repro.layers import attention, heads, mlp, moe, norms, rope, ssm, xlstm  # noqa: F401

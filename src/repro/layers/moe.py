"""Expert-parallel Mixture-of-Experts — local-shard view.

Experts are sharded over the model axis (each shard holds ``E_loc`` experts);
activations arrive replicated across the model axis, so dispatch needs NO all-to-all:
every shard serves the token→expert assignments that land on *its* experts and
returns an unreduced partial output.  The single TP all-reduce that combines the
shards is applied by the caller — it is exactly the collective the ISO scheduler
overlaps (see DESIGN.md §3).

Capacity-based (GShard-style) routing with index scatter/gather instead of the
(T,E,C) one-hot einsum — the one-hot form is O(T·E·C) memory and does not fit
trillion-parameter configs (kimi-k2: E=384).  A ``fori_loop`` over the top-k slots
keeps transient memory at O(T·D) per step.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import MoEConfig


def init_moe(key, d_model: int, mcfg: MoEConfig, tp: int, num_layers: int,
             dtype=jnp.bfloat16) -> dict:
    e_pad = mcfg.padded_experts(tp)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s, so = 0.02, 0.02 / (2 * num_layers) ** 0.5
    f = mcfg.d_ff_expert
    p = {
        "router": (jax.random.normal(k1, (d_model, e_pad), jnp.float32) * s),
        "w_up": (jax.random.normal(k2, (e_pad, d_model, f), jnp.float32) * s).astype(dtype),
        "w_gate": (jax.random.normal(k3, (e_pad, d_model, f), jnp.float32) * s).astype(dtype),
        "w_down": (jax.random.normal(k4, (e_pad, f, d_model), jnp.float32) * so).astype(dtype),
    }
    if mcfg.shared_expert_d_ff:
        ks1, ks2, ks3 = jax.random.split(k1, 3)
        fs = mcfg.shared_expert_d_ff
        p["shared"] = {
            "w_up": (jax.random.normal(ks1, (d_model, fs), jnp.float32) * s).astype(dtype),
            "w_gate": (jax.random.normal(ks2, (d_model, fs), jnp.float32) * s).astype(dtype),
            "w_down": (jax.random.normal(ks3, (fs, d_model), jnp.float32) * so).astype(dtype),
        }
    return p


def route(router_w, x, mcfg: MoEConfig, e_pad: int):
    """Top-k routing in fp32.  x: (T,D) -> weights (T,k), idx (T,k), aux loss."""
    logits = x.astype(jnp.float32) @ router_w          # (T, E_pad)
    # mask padding experts
    valid = jnp.arange(e_pad) < mcfg.num_experts
    logits = jnp.where(valid[None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, mcfg.top_k)          # (T,k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # load-balance aux (Switch): E * mean_e(frac_tokens_e * mean_prob_e)
    onehot = jax.nn.one_hot(idx[:, 0], e_pad, dtype=jnp.float32)
    frac = jnp.mean(onehot, axis=0)
    aux = mcfg.num_experts * jnp.sum(frac * jnp.mean(probs, axis=0))
    return w, idx, aux


def capacity(tokens: int, mcfg: MoEConfig, e_pad: int) -> int:
    return max(4, int(math.ceil(tokens * mcfg.top_k / e_pad * mcfg.capacity_factor)))


def moe_partial(p: dict, x, mcfg: MoEConfig, *, tp: int, expert_offset,
                cap_override: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,D) replicated across model shards.

    Returns (unreduced partial output (B,S,D), aux loss scalar / tp).
    ``expert_offset``: first global expert id owned by this shard (traced ok).
    """
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    e_pad = p["router"].shape[1]
    e_loc = e_pad // tp
    w, idx, aux = route(p["router"], xt, mcfg, e_pad)

    C = cap_override or capacity(T, mcfg, e_pad)

    # --- positions: joint cumsum over all (T*k) assignments on LOCAL experts ---
    idx_flat = idx.reshape(-1)                                   # (T*k,)
    local = idx_flat - expert_offset
    is_local = (local >= 0) & (local < e_loc)
    local_c = jnp.where(is_local, local, e_loc)                  # dump slot e_loc
    onehot = jax.nn.one_hot(local_c, e_loc + 1, dtype=jnp.int32)
    # exclusive cumulative count of earlier assignments to the same expert
    pos_flat = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - onehot,
                                   local_c[:, None], axis=1)[:, 0]
    pos = pos_flat.reshape(T, mcfg.top_k)
    local_e = local_c.reshape(T, mcfg.top_k)
    in_cap = (pos < C) & is_local.reshape(T, mcfg.top_k)
    pos_c = jnp.where(in_cap, pos, C)                            # dump position C

    # --- dispatch: scatter tokens into (e_loc+1, C+1, D); python loop over the
    # k slots (top_k is static and small; an unrolled loop keeps transient
    # memory at O(T*D) per slot AND keeps cost_analysis honest — fori_loop
    # bodies are counted once by XLA's analysis) ---
    dtype = x.dtype
    buf = jnp.zeros((e_loc + 1, C + 1, D), dtype)
    for j in range(mcfg.top_k):
        buf = buf.at[local_e[:, j], pos_c[:, j]].set(xt, mode="drop")
    buf = buf[:e_loc, :C]                                        # (e_loc, C, D)

    # --- expert FFN (swiglu), batched over local experts ---
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(dtype) * up
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])         # (e_loc, C, D)
    out_buf = jnp.pad(out_buf, ((0, 1), (0, 1), (0, 0)))         # dump slots read 0

    # --- combine: gather + weight; python loop over k slots (see dispatch) ---
    y = jnp.zeros((T, D), dtype)
    for j in range(mcfg.top_k):
        g = out_buf[local_e[:, j], pos_c[:, j]]                  # (T, D)
        y = y + g * (w[:, j] * in_cap[:, j]).astype(dtype)[:, None]
    y = y.reshape(B, S, D)

    # --- shared (dense) expert: column->row parallel like a regular MLP, so its
    # output is an unreduced partial that rides the SAME all-reduce as the experts
    if "shared" in p:
        sh = p["shared"]
        u = jnp.einsum("bsd,df->bsf", x, sh["w_up"])
        g = jnp.einsum("bsd,df->bsf", x, sh["w_gate"])
        hshared = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
        y = y + jnp.einsum("bsf,fd->bsd", hshared, sh["w_down"])

    return y, aux / tp

"""Vocab-sharded embedding + LM head — local-shard view (Megatron style)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, padded_vocab


def init_embedding(key, cfg: ModelConfig, tp: int, dtype=jnp.bfloat16) -> dict:
    v = padded_vocab(cfg, tp)
    k1, k2 = jax.random.split(key)
    p = {"table": (jax.random.normal(k1, (v, cfg.d_model), jnp.float32) * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["head"] = (jax.random.normal(k2, (v, cfg.d_model), jnp.float32) * 0.02).astype(dtype)
    return p


def embed_partial(p: dict, tokens, vocab_offset):
    """tokens: (B,S) int32; table is the LOCAL vocab shard.

    Returns the unreduced partial embedding (tokens outside this shard's vocab range
    contribute zero); caller psums over the model axis.
    """
    table = p["table"]
    v_loc = table.shape[0]
    local = tokens - vocab_offset
    ok = (local >= 0) & (local < v_loc)
    e = jnp.take(table, jnp.clip(local, 0, v_loc - 1), axis=0)
    return e * ok[..., None].astype(e.dtype)


def lm_head_local(p: dict, x):
    """x: (B,S,D) replicated -> LOCAL logits (B,S,V_loc) (vocab-sharded output)."""
    w = p.get("head", p["table"])
    return jnp.einsum("bsd,vd->bsv", x, w)

"""Mamba-style selective SSM — local-shard view, TPU adaptation.

Differences from the CUDA mamba kernel (recorded in DESIGN.md §2):
  * the scan is ``jax.lax.associative_scan`` over (decay, update) pairs — the
    TPU-native parallel-prefix form — instead of a fused sequential CUDA kernel;
  * dt / B / C projections read the *replicated* d_model input rather than the
    TP-sharded inner activation, so the block needs no mid-layer collective; the
    only all-reduce is after the row-parallel out-projection (ISO overlaps it);
  * the depthwise conv carries an explicit (width-1)-token state so chunked prefill
    (ISO) is exact across chunk boundaries.

State handoff = (conv_state (B, conv_dim-1, inner_loc), h (B, inner_loc, N)).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import SSMConfig, pad_to_multiple


class SSMState(NamedTuple):
    conv: jnp.ndarray     # (B, conv_dim-1, inner_loc)
    h: jnp.ndarray        # (B, inner_loc, N) fp32


def inner_dim(d_model: int, scfg: SSMConfig, tp: int) -> int:
    return pad_to_multiple(scfg.expand * d_model, tp)


def init_ssm(key, d_model: int, scfg: SSMConfig, tp: int, num_layers: int,
             dtype=jnp.bfloat16) -> dict:
    inner = inner_dim(d_model, scfg, tp)
    n = scfg.state_dim
    ks = jax.random.split(key, 8)
    s, so = 0.02, 0.02 / (2 * num_layers) ** 0.5
    k_z = jax.random.split(ks[6])[0]
    return {
        # x and z input projections kept as SEPARATE weights: a fused (D, 2*inner)
        # matrix would interleave wrongly when the column dim shards over TP.
        "w_x": (jax.random.normal(ks[0], (d_model, inner), jnp.float32) * s).astype(dtype),
        "w_z": (jax.random.normal(k_z, (d_model, inner), jnp.float32) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (scfg.conv_dim, inner), jnp.float32) * s).astype(dtype),
        "w_dt": (jax.random.normal(ks[2], (d_model, inner), jnp.float32) * s).astype(dtype),
        "dt_bias": jnp.zeros((inner,), jnp.float32),
        "w_b": (jax.random.normal(ks[3], (d_model, n), jnp.float32) * s).astype(dtype),
        "w_c": (jax.random.normal(ks[4], (d_model, n), jnp.float32) * s).astype(dtype),
        "a_log": jnp.zeros((inner, n), jnp.float32),          # A = -exp(a_log)
        "d_skip": jnp.ones((inner,), jnp.float32),
        "w_out": (jax.random.normal(ks[5], (inner, d_model), jnp.float32) * so).astype(dtype),
    }


def init_ssm_state(batch: int, inner_loc: int, scfg: SSMConfig) -> SSMState:
    return SSMState(
        conv=jnp.zeros((batch, scfg.conv_dim - 1, inner_loc), jnp.bfloat16),
        h=jnp.zeros((batch, inner_loc, scfg.state_dim), jnp.float32),
    )


def _causal_conv(x, conv_state, w):
    """Depthwise causal conv with carried state.  x: (B,S,inner)."""
    width = w.shape[0]
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)   # (B, S+w-1, inner)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(width))
    new_state = xp[:, -(width - 1):] if width > 1 else conv_state
    return out, new_state


def ssm_partial(p: dict, x, scfg: SSMConfig, state: Optional[SSMState] = None,
                ) -> Tuple[jnp.ndarray, SSMState]:
    """x: (B,S,D) replicated -> (unreduced partial (B,S,D), new state).

    Exact across chunk boundaries given the carried state (ISO invariant).
    """
    B, S, D = x.shape
    inner = p["w_x"].shape[1]
    n = p["a_log"].shape[1]
    if state is None:
        state = SSMState(conv=jnp.zeros((B, p["conv_w"].shape[0] - 1, inner), x.dtype),
                         h=jnp.zeros((B, inner, n), jnp.float32))

    x_in = jnp.einsum("bsd,di->bsi", x, p["w_x"])
    z = jnp.einsum("bsd,di->bsi", x, p["w_z"])
    x_c, conv_new = _causal_conv(x_in, state.conv, p["conv_w"])
    x_c = jax.nn.silu(x_c.astype(jnp.float32))

    dt = jax.nn.softplus(jnp.einsum("bsd,di->bsi", x, p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"])                              # (B,S,inner)
    b_proj = jnp.einsum("bsd,dn->bsn", x, p["w_b"]).astype(jnp.float32)
    c_proj = jnp.einsum("bsd,dn->bsn", x, p["w_c"]).astype(jnp.float32)
    a = -jnp.exp(p["a_log"])                                          # (inner, N)

    decay = jnp.exp(dt[..., None] * a[None, None])                    # (B,S,inner,N)
    drive = (dt * x_c)[..., None] * b_proj[:, :, None, :]             # (B,S,inner,N)

    # parallel prefix over the sequence axis: h_t = decay_t*h_{t-1} + drive_t
    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    prod, hscan = jax.lax.associative_scan(comb, (decay, drive), axis=1)
    h = hscan + prod * state.h[:, None]                               # carry h0 in
    y = jnp.einsum("bsin,bsn->bsi", h, c_proj) + x_c * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"])
    return out, SSMState(conv=conv_new.astype(state.conv.dtype), h=h[:, -1])


def ssm_decode_partial(p: dict, x, scfg: SSMConfig, state: SSMState):
    """Single-token recurrent step (O(1) in sequence length)."""
    return ssm_partial(p, x, scfg, state)

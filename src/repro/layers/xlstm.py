"""xLSTM blocks (arXiv:2405.04517) — local-shard view.

mLSTM: matrix-memory LSTM in the *chunkwise-parallel* formulation (intra-chunk
quadratic attention-like term + inter-chunk recurrent state), which is both the
sub-quadratic form needed for ``long_500k`` and the natural ISO state-handoff point.
TP adaptation (DESIGN.md §4): q/k and the scalar gates are replicated; the v/output
feature dim is column-sharded, the matrix memory C is sharded along its v axis, and
the out-projection is row-parallel — so the block ends in the TP all-reduce that ISO
overlaps.

sLSTM: scalar-memory LSTM with recurrent (block-diagonal per head) connections —
strictly sequential ``lax.scan``; weights replicated, no collective (recorded as the
ISO-inapplicable case in DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig


class MLSTMState(NamedTuple):
    c: jnp.ndarray        # (B, H, hd_k, hd_v_loc) fp32
    n: jnp.ndarray        # (B, H, hd_k) fp32
    m: jnp.ndarray        # (B, H) fp32 log-stabilizer


class SLSTMState(NamedTuple):
    c: jnp.ndarray        # (B, D) fp32
    h: jnp.ndarray        # (B, D) fp32
    n: jnp.ndarray        # (B, D) fp32
    m: jnp.ndarray        # (B, D) fp32


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig, tp: int, dtype=jnp.bfloat16) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    hd = d // h
    ks = jax.random.split(key, 7)
    s, so = 0.02, 0.02 / (2 * cfg.num_layers) ** 0.5
    return {
        "w_q": (jax.random.normal(ks[0], (d, h, hd), jnp.float32) * s).astype(dtype),
        "w_k": (jax.random.normal(ks[1], (d, h, hd), jnp.float32) * s).astype(dtype),
        "w_v": (jax.random.normal(ks[2], (d, h, hd), jnp.float32) * s).astype(dtype),  # sharded on hd
        "w_og": (jax.random.normal(ks[3], (d, h, hd), jnp.float32) * s).astype(dtype),  # sharded on hd
        "w_i": (jax.random.normal(ks[4], (d, h), jnp.float32) * s),
        "w_f": (jax.random.normal(ks[5], (d, h), jnp.float32) * s),
        "f_bias": jnp.full((h,), 3.0, jnp.float32),   # init forget gates open
        "i_bias": jnp.zeros((h,), jnp.float32),
        "w_out": (jax.random.normal(ks[6], (h, hd, d), jnp.float32) * so).astype(dtype),  # row-parallel on hd
    }


def init_mlstm_state(batch: int, heads: int, hd_k: int, hd_v_loc: int) -> MLSTMState:
    return MLSTMState(
        c=jnp.zeros((batch, heads, hd_k, hd_v_loc), jnp.float32),
        n=jnp.zeros((batch, heads, hd_k), jnp.float32),
        m=jnp.full((batch, heads), -1e30, jnp.float32),
    )


def _mlstm_chunk(q, k, v, ilog, flog, state: MLSTMState):
    """One chunk, parallel form.  q,k: (B,L,H,hdk) fp32; v: (B,L,H,hdv_loc) fp32;
    ilog/flog: (B,L,H).  Returns (h_out (B,L,H,hdv_loc), new_state)."""
    B, L, H, hdk = q.shape
    F = jnp.cumsum(flog, axis=1)                            # (B,L,H) cumulative log-f
    # stabilizers: intra source term  i_s - F_s ; inter term  m0 - (F=0 at chunk start)
    src = ilog - F                                          # (B,L,H)
    run_max = jax.lax.associative_scan(jnp.maximum, src, axis=1)
    m0 = state.m                                            # (B,H)
    m_t = jnp.maximum(F + run_max, F + m0[:, None])         # (B,L,H) log-stabilizer per step

    # intra-chunk: scores_ts = (q_t.k_s) * exp(F_t - F_s + i_s - m_t), s<=t
    # (k is pre-scaled by hd^-0.5 at projection so the carried state C sees the
    # same scaling — scaling only the intra logits would break the handoff)
    logits = jnp.einsum("bthd,bshd->bhts", q, k)            # (B,H,T,S)
    Fh = jnp.moveaxis(F, -1, 1)                             # (B,H,L)
    ih = jnp.moveaxis(ilog, -1, 1)
    dmat = Fh[:, :, :, None] - Fh[:, :, None, :] + ih[:, :, None, :]  # (B,H,T,S)
    mask = jnp.tril(jnp.ones((L, L), bool))
    mh = jnp.moveaxis(m_t, -1, 1)                           # (B,H,L)
    w_intra = jnp.where(mask[None, None], jnp.exp(dmat - mh[:, :, :, None]), 0.0)
    h_intra = jnp.einsum("bhts,bshd->bthd", w_intra * logits, v)
    # normalizer follows xLSTM: n_t = sum_s w_ts k_s ; denominator uses |q . n_t|
    n_intra = jnp.einsum("bhts,bshd->bthd", w_intra, k)
    inter_w = jnp.exp(Fh + m0[:, :, None] - mh)             # (B,H,L)
    h_inter = jnp.einsum("bthd,bhdk,bht->bthk", q, state.c, inter_w)
    n_inter = state.n[:, None] * inter_w.transpose(0, 2, 1)[..., None]  # (B,L,H,hdk)

    num = h_intra + h_inter                                 # (B,L,H,hdv_loc)
    n_vec = n_intra + n_inter                               # (B,L,H,hdk)
    denom = jnp.abs(jnp.einsum("bthd,bthd->bth", q, n_vec))
    denom = jnp.maximum(denom, jnp.exp(-jnp.moveaxis(mh, 1, -1)))
    h_out = num / denom[..., None]

    # end-of-chunk state
    m_L = m_t[:, -1]                                        # (B,H)
    FL = F[:, -1]                                           # (B,H)
    carry = jnp.exp(FL + m0 - m_L)                          # (B,H)
    wsrc = jnp.exp(FL[:, None] - F + ilog - m_L[:, None])   # (B,L,H)
    c_new = carry[:, :, None, None] * state.c + \
        jnp.einsum("blh,blhd,blhk->bhdk", wsrc, k, v)
    n_new = carry[:, :, None] * state.n + jnp.einsum("blh,blhd->bhd", wsrc, k)
    return h_out, MLSTMState(c=c_new, n=n_new, m=m_L)


def mlstm_partial(p: dict, x, cfg: ModelConfig, state: Optional[MLSTMState] = None,
                  inner_chunk: int = 256) -> Tuple[jnp.ndarray, MLSTMState]:
    """x: (B,S,D) replicated -> (unreduced partial (B,S,D), new state)."""
    B, S, D = x.shape
    H = cfg.num_heads
    hdk = p["w_q"].shape[2]
    hdv = p["w_v"].shape[2]                                  # local shard of v dim
    if state is None:
        state = init_mlstm_state(B, H, hdk, hdv)

    q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"]).astype(jnp.float32)
    k = jnp.einsum("bsd,dhk->bshk", x, p["w_k"]).astype(jnp.float32) * (hdk ** -0.5)
    v = jnp.einsum("bsd,dhk->bshk", x, p["w_v"]).astype(jnp.float32)
    og = jax.nn.sigmoid(jnp.einsum("bsd,dhk->bshk", x, p["w_og"]).astype(jnp.float32))
    ilog = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["w_i"]) + p["i_bias"]
    flog = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["w_f"]) + p["f_bias"])

    L = min(inner_chunk, S)
    if S % L:
        L = S  # fall back to one chunk for odd lengths (tests use small S)
    nck = S // L

    def step(st, xs):
        qc, kc, vc, ic, fc = xs
        h, st2 = _mlstm_chunk(qc, kc, vc, ic, fc, st)
        return st2, h

    resh = lambda t: t.reshape(B, nck, L, *t.shape[2:]).swapaxes(0, 1)
    state_f, hs = jax.lax.scan(step, state,
                               (resh(q), resh(k), resh(v), resh(ilog), resh(flog)))
    h = hs.swapaxes(0, 1).reshape(B, S, H, hdv) * og
    out = jnp.einsum("bshk,hkd->bsd", h.astype(p["w_out"].dtype), p["w_out"])
    return out, state_f


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    hd = d // h
    ks = jax.random.split(key, 9)
    s = 0.02
    p = {}
    for i, g in enumerate(("i", "f", "z", "o")):
        p[f"w_{g}"] = (jax.random.normal(ks[i], (d, d), jnp.float32) * s).astype(dtype)
        p[f"r_{g}"] = (jax.random.normal(ks[4 + i], (h, hd, hd), jnp.float32) * s)
    p["f_bias"] = jnp.full((d,), 3.0, jnp.float32)
    # named w_proj (not w_out): sLSTM weights are REPLICATED across TP shards,
    # unlike the row-parallel w_out of ssm/mlstm (see sharding/specs rules)
    p["w_proj"] = (jax.random.normal(ks[8], (d, d), jnp.float32) *
                   (s / (2 * cfg.num_layers) ** 0.5)).astype(dtype)
    return p


def init_slstm_state(batch: int, d: int) -> SLSTMState:
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(c=z, h=z, n=z + 1.0, m=z)


def slstm_forward(p: dict, x, cfg: ModelConfig, state: Optional[SLSTMState] = None,
                  ) -> Tuple[jnp.ndarray, SLSTMState]:
    """Strictly sequential scan.  x: (B,S,D) -> (FULL output (B,S,D), state).

    Weights are replicated across TP shards: the caller must NOT psum this block.
    """
    B, S, D = x.shape
    H = cfg.num_heads
    hd = D // H
    if state is None:
        state = init_slstm_state(B, D)

    xf = x.astype(jnp.float32)
    pre = {g: jnp.einsum("bsd,de->bse", xf, p[f"w_{g}"].astype(jnp.float32))
           for g in ("i", "f", "z", "o")}
    pre["f"] = pre["f"] + p["f_bias"]

    def rec(h, g):
        hh = h.reshape(B, H, hd)
        return jnp.einsum("bhk,hkj->bhj", hh, p[f"r_{g}"]).reshape(B, D)

    def step(st, t):
        i_t = pre["i"][:, t] + rec(st.h, "i")
        f_t = pre["f"][:, t] + rec(st.h, "f")
        z_t = jnp.tanh(pre["z"][:, t] + rec(st.h, "z"))
        o_t = jax.nn.sigmoid(pre["o"][:, t] + rec(st.h, "o"))
        m_new = jnp.maximum(jax.nn.log_sigmoid(f_t) + st.m, i_t)
        i_e = jnp.exp(i_t - m_new)
        f_e = jnp.exp(jax.nn.log_sigmoid(f_t) + st.m - m_new)
        c_new = f_e * st.c + i_e * z_t
        n_new = jnp.maximum(f_e * st.n + i_e, 1e-6)
        h_new = o_t * c_new / n_new
        return SLSTMState(c=c_new, h=h_new, n=n_new, m=m_new), h_new

    state_f, hs = jax.lax.scan(step, state, jnp.arange(S))
    y = hs.swapaxes(0, 1).astype(x.dtype)                   # (B,S,D)
    return jnp.einsum("bsd,de->bse", y, p["w_proj"]), state_f

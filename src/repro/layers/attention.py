"""GQA attention — local-shard view (runs inside shard_map).

Forward functions receive the *local* slice of the padded weights (the model axis
shards the head dimension) and return an *unreduced partial* output: the TP
all-reduce after ``o_proj`` is applied by the caller (the ISO scheduler decides when —
that deferral is the paper's mechanism).

Supports: causal prefill, chunked prefill with a prefix KV (ISO), sliding-window
masks, decode against a padded cache with per-request lengths, non-causal encoder
attention and cross-attention (whisper).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers.heads import HeadLayout, expand_heads
from repro.layers.rope import apply_rope


# ---------------------------------------------------------------------------
# init (GLOBAL padded weights; shard_map slices them)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, layout: HeadLayout, dtype=jnp.bfloat16,
                   cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = 0.02
    wq = jax.random.normal(kq, (d, layout.hq, hd), jnp.float32) * s
    wk = jax.random.normal(kk, (d, layout.hkv, hd), jnp.float32) * s
    wv = jax.random.normal(kv, (d, layout.hkv, hd), jnp.float32) * s
    wo = jax.random.normal(ko, (layout.hq, hd, d), jnp.float32) * (s / (2 * cfg.num_layers) ** 0.5)
    p = {
        "wq": expand_heads(wq, layout.q_map, 1).astype(dtype),
        "wk": expand_heads(wk, layout.kv_map, 1).astype(dtype),
        "wv": expand_heads(wv, layout.kv_map, 1).astype(dtype),
        "wo": expand_heads(wo, layout.q_map, 0).astype(dtype),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# forward pieces
# ---------------------------------------------------------------------------

def _head_rms(x, scale, eps):
    xf = x.astype(jnp.float32)
    v = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jnp.reciprocal(jnp.sqrt(v + eps)) * scale).astype(x.dtype)


def project_qkv(p: dict, x, cfg: ModelConfig, positions,
                use_rope: bool = True) -> Tuple:
    """x: (B,S,D) -> q (B,S,Hq_loc,hd), k/v (B,S,Hkv_loc,hd).

    ``positions``: (B,S) absolute positions (chunk offsets included).
    """
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm and "q_norm" in p:
        q = _head_rms(q, p["q_norm"], cfg.rms_eps)
        k = _head_rms(k, p["k_norm"], cfg.rms_eps)
    if use_rope and cfg.pos_type == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def row_starts(start_pos, B):
    """Normalise a chunk-start to a (B,) int32 vector.

    ``start_pos`` may be a static int, a traced scalar (resumed chunked
    prefill), or a (B,) vector (batched multi-request prefill grants, where
    each packed row resumes at its own absolute position)."""
    s = jnp.asarray(start_pos, jnp.int32)
    return jnp.broadcast_to(s, (B,)) if s.ndim == 0 else s


def row_positions(start_pos, B, S):
    """(B, S) absolute positions of S consecutive tokens starting at
    ``start_pos`` (scalar or per-row (B,); see ``row_starts``)."""
    return (row_starts(start_pos, B)[:, None]
            + jnp.arange(S, dtype=jnp.int32)[None, :])


def _k_limit_col(k_limit):
    """Broadcast a key-position bound (scalar or per-row (B,)) against
    (B, Sk) key positions."""
    kl = jnp.asarray(k_limit, jnp.int32)
    return kl[:, None] if kl.ndim == 1 else kl


def sdpa_blockwise(q, k, v, *, q_pos, k_pos, causal: bool = True,
                   window: int = 0, k_valid=None, group_eff: int = 1,
                   block_k: int = 1024):
    """Flash-style blockwise attention in pure XLA: lax.scan over key blocks
    with a running (max, denom, acc) — O(Sq·block_k) live memory instead of the
    O(Sq·Sk) score matrix.  Numerically identical to ``sdpa`` (fp32 softmax).

    This is the §Perf memory-term lever for long-prefill shapes; the Pallas
    kernel (kernels/flash_prefill.py) is the TPU-native equivalent — this path
    is what the XLA dry-run lowers.
    """
    B, Sq, Hq, hd = q.shape
    Sk = k.shape[1]
    Hkv = k.shape[2]
    assert Hq == Hkv * group_eff
    if Sk <= block_k:
        return sdpa(q, k, v, q_pos=q_pos, k_pos=k_pos, causal=causal,
                    window=window, k_valid=k_valid, group_eff=group_eff)
    nb = -(-Sk // block_k)
    pad = nb * block_k - Sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kpos_p = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    kval_p = jnp.pad(k_valid, ((0, 0), (0, pad)), constant_values=False) \
        if k_valid is not None else (kpos_p >= 0)

    qg = q.reshape(B, Sq, Hkv, group_eff, hd).astype(jnp.float32)
    scale = hd ** -0.5
    resh = lambda t: t.reshape(B, nb, block_k, *t.shape[2:]).swapaxes(0, 1)
    ks, vs = resh(kp), resh(vp)
    kps, kvs = resh(kpos_p), resh(kval_p)

    def step(carry, xs):
        m_run, l_run, acc = carry
        kb, vb, kpb, kvb = xs
        s = jnp.einsum("bqhgd,bshd->bhgqs", qg, kb.astype(jnp.float32)) * scale
        mask = kvb[:, None, :]
        if causal:
            mask &= kpb[:, None, :] <= q_pos[:, :, None]
        if window:
            mask &= kpb[:, None, :] > q_pos[:, :, None] - window
        s = jnp.where(mask[:, None, None], s, -1e30)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        # explicit mask multiply: a fully-masked block has s == m_new == -1e30
        # and exp(0) would leak weight 1 per masked key
        p = jnp.exp(s - m_new[..., None]) * mask[:, None, None]
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqs,bshd->bhgqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, group_eff, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, group_eff, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, group_eff, Sq, hd), jnp.float32)
    # unroll: XLA cost analysis counts loop bodies once; full unroll keeps the
    # dry-run roofline honest and lets the TPU scheduler software-pipeline
    (m_f, l_f, acc_f), _ = jax.lax.scan(step, (m0, l0, a0), (ks, vs, kps, kvs),
                                        unroll=True)
    out = acc_f / jnp.maximum(l_f, 1e-30)[..., None]
    return jnp.moveaxis(out, 3, 1).reshape(B, Sq, Hq, hd)


def sdpa(q, k, v, *, q_pos, k_pos, causal: bool = True, window: int = 0,
         k_valid=None, group_eff: int = 1):
    """Core scaled-dot-product attention with GQA grouping, fp32 softmax.

    q: (B,Sq,Hq,hd)   grouped as Hq = Hkv * group_eff
    k,v: (B,Sk,Hkv,hd)
    q_pos: (B,Sq) int32 absolute positions; k_pos: (B,Sk).
    k_valid: optional (B,Sk) bool — cache slots actually filled.

    The normalised view of ``sdpa_partial`` (acc/l; fully-masked rows -> 0).
    """
    out, _, _ = sdpa_partial(q, k, v, q_pos=q_pos, k_pos=k_pos, causal=causal,
                             window=window, k_valid=k_valid,
                             group_eff=group_eff)
    return out


def sdpa_partial(q, k, v, *, q_pos, k_pos, causal: bool = True,
                 window: int = 0, k_valid=None, group_eff: int = 1):
    """``sdpa`` that returns the flash partial-softmax state instead of the
    normalised output: ``(out, m, l)`` with out (B,Sq,Hq,hd) = acc/l fp32,
    m/l (B,Sq,Hq,1) the running max and denominator.  Fully-masked rows come
    back as (0, NEG_INF-ish, 0) — combining states via
    ``merge_softmax_states`` then ignores them exactly.
    """
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    assert Hq == Hkv * group_eff, (Hq, Hkv, group_eff)
    qg = q.reshape(B, Sq, Hkv, group_eff, hd)
    scale = hd ** -0.5
    s = jnp.einsum("bqhgk,bshk->bhgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.ones((B, Sq, Sk), bool)
    if causal:
        mask &= k_pos[:, None, :] <= q_pos[:, :, None]
    if window:
        mask &= k_pos[:, None, :] > q_pos[:, :, None] - window
    if k_valid is not None:
        mask &= k_valid[:, None, :]
    mask_b = mask[:, None, None]                        # (B,1,1,Sq,Sk)
    s = jnp.where(mask_b, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)              # (B,Hkv,g,Sq,1)
    # explicit mask multiply: a fully-masked row has s == m == -1e30 and
    # exp(0) would otherwise leak weight 1 per masked key
    p = jnp.exp(s - m) * mask_b
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgqs,bshk->bqhgk", p, v.astype(jnp.float32))
    out = out.reshape(B, Sq, Hq, hd) / jnp.maximum(
        l.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, 1), 1e-30)
    return (out, m.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, 1),
            l.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, 1))


def merge_softmax_states(o_a, m_a, l_a, o_b, m_b, l_b):
    """Combine two flash partial-softmax states over disjoint key sets.

    Each state is (out, m, l) as returned by ``sdpa_partial`` /
    ``kernels.flash_prefill_paged`` (out = acc/l).  Returns the normalised
    attention output over the union of both key sets, fp32.  A state with
    l == 0 (nothing attended) contributes nothing.
    """
    m = jnp.maximum(m_a, m_b)
    wa = jnp.exp(m_a - m) * l_a
    wb = jnp.exp(m_b - m) * l_b
    return (o_a * wa + o_b * wb) / jnp.maximum(wa + wb, 1e-30)


def o_proj_partial(p: dict, attn_out) -> jnp.ndarray:
    """Row-parallel output projection — returns the UNREDUCED partial sum."""
    return jnp.einsum("bqhk,hkd->bqd", attn_out.astype(p["wo"].dtype), p["wo"])


# ---------------------------------------------------------------------------
# full blocks
# ---------------------------------------------------------------------------

def attn_prefill_partial(p: dict, x, cfg: ModelConfig, layout_group: int, *,
                         start_pos, prefix_kv: Optional[Tuple] = None,
                         prefix_pos=None, window: int = 0, causal: bool = True,
                         k_limit=None):
    """Chunked-prefill attention.  ``start_pos``: absolute position of the
    chunk's first token — static int, traced scalar, or per-row (B,) vector
    (batched multi-request grants).  ``prefix_kv``: (k,v) of all previous
    chunks (local shard).  ``prefix_pos``: optional (B, S_prefix) absolute position
    of each prefix slot, -1 = empty — required when the prefix comes from a paged
    cache (resumed chunked prefill), where slots are padded and slot != position.
    Without it the prefix is assumed dense and contiguous from position 0.
    ``k_limit``: optional absolute position bound, scalar or per-row (B,) —
    keys at positions >= k_limit are masked (bucket-padded tail tokens must
    not be attended; see grant-size bucketing in serving/paged_engine.py).
    Returns (partial_out, (k,v) of THIS chunk for the growing prefix).
    """
    B, S, _ = x.shape
    q_pos = row_positions(start_pos, B, S)
    q, k, v = project_qkv(p, x, cfg, q_pos)
    k_valid = None
    if prefix_kv is not None:
        pk, pv = prefix_kv
        k_all = jnp.concatenate([pk, k], axis=1)
        v_all = jnp.concatenate([pv, v], axis=1)
        if prefix_pos is not None:
            k_pos = jnp.concatenate([prefix_pos.astype(jnp.int32), q_pos],
                                    axis=1)
            k_valid = jnp.concatenate(
                [prefix_pos >= 0, jnp.ones((B, S), bool)], axis=1)
        else:
            k_pos = jnp.arange(k_all.shape[1], dtype=jnp.int32
                               )[None, :].repeat(B, 0)
    else:
        k_all, v_all = k, v
        k_pos = q_pos
    if k_limit is not None:
        lim = k_pos < _k_limit_col(k_limit)
        k_valid = lim if k_valid is None else (k_valid & lim)
    if cfg.attn_impl == "blockwise":
        out = sdpa_blockwise(q, k_all, v_all, q_pos=q_pos, k_pos=k_pos,
                             causal=causal, window=window, k_valid=k_valid,
                             group_eff=layout_group, block_k=cfg.attn_block_k)
    else:
        out = sdpa(q, k_all, v_all, q_pos=q_pos, k_pos=k_pos, causal=causal,
                   window=window, k_valid=k_valid, group_eff=layout_group)
    return o_proj_partial(p, out), (k, v)


def attn_prefill_paged_partial(p: dict, x, cfg: ModelConfig,
                               layout_group: int, *, k_pages, v_pages,
                               block_tables, prefix_lens, start_pos,
                               intra_kv: Optional[Tuple] = None,
                               intra_pos=None, window: int = 0, k_limit=None):
    """Chunked-prefill attention against a PAGED KV prefix (no dense gather).

    x: (B,S,D) one ISO chunk; k_pages/v_pages: (N, ps, Hkv_loc, hd) page pool
    (local shard); block_tables: (B, MB) int32 (-1 pad); prefix_lens: (B,)
    int32 resident prefix tokens (key position j*ps+o attended iff
    < prefix_len — also the prefix-sharing rule: donor KV beyond the shared
    prefix sits at positions >= prefix_len).  ``start_pos``: absolute
    position of the chunk's first token — traced scalar, or a (B,) vector
    when the rows are packed multi-request grants each resuming at its own
    offset (a fresh row rides with prefix_len 0: the kernel returns the
    neutral partial state and the merge reduces to plain causal
    self-attention).  ``intra_kv``/``intra_pos``: (k, v) and positions of
    earlier ISO chunks WITHIN this call (not yet in pages).  ``k_limit``: as
    in ``attn_prefill_partial`` (bucket pad mask, scalar or per-row (B,)).

    The Pallas kernel (kernels/flash_prefill_paged.py) walks the block table
    with an online softmax and returns the partial state over the paged
    prefix; the intra-call keys (earlier chunks + the chunk itself, causal)
    are folded in with one dense partial-softmax merge.  Returns
    (partial_out, (k, v) of THIS chunk); the page scatter is the engine's job.
    """
    from repro.kernels.flash_prefill_paged import flash_prefill_paged
    B, S, _ = x.shape
    q_pos = row_positions(start_pos, B, S)
    q, k, v = project_qkv(p, x, cfg, q_pos)
    q_starts = row_starts(start_pos, B)
    out_p, m_p, l_p = flash_prefill_paged(
        q.transpose(0, 2, 1, 3), k_pages, v_pages, block_tables,
        prefix_lens, q_starts, window=window)
    out_p = out_p.transpose(0, 2, 1, 3)                 # (B,S,Hq,hd)
    m_p = m_p.transpose(0, 2, 1, 3)
    l_p = l_p.transpose(0, 2, 1, 3)
    if intra_kv is not None:
        ik, iv = intra_kv
        k_all = jnp.concatenate([ik, k], axis=1)
        v_all = jnp.concatenate([iv, v], axis=1)
        k_pos = jnp.concatenate([intra_pos.astype(jnp.int32), q_pos], axis=1)
    else:
        k_all, v_all, k_pos = k, v, q_pos
    k_valid = (k_pos < _k_limit_col(k_limit)) if k_limit is not None else None
    out_i, m_i, l_i = sdpa_partial(q, k_all, v_all, q_pos=q_pos, k_pos=k_pos,
                                   causal=True, window=window,
                                   k_valid=k_valid, group_eff=layout_group)
    out = merge_softmax_states(out_p, m_p, l_p, out_i, m_i, l_i)
    return o_proj_partial(p, out), (k, v)


def attn_decode_partial(p: dict, x, cfg: ModelConfig, layout_group: int, *,
                        cache_k, cache_v, lengths, window: int = 0,
                        cache_pos=None):
    """One-token decode against a padded cache.

    x: (B,1,D); cache_k/v: (B,Smax,Hkv_loc,hd); lengths: (B,) tokens already cached.
    ``cache_pos``: optional (B,Smax) absolute position of each slot (-1 = empty) —
    required for ring-buffer (sliding-window) caches where slot != position.
    Returns (partial_out, (k_new, v_new)) — cache insertion is the engine's job
    (it owns the ring-buffer policy for windowed caches).
    """
    B, K = x.shape[0], x.shape[1]
    # positions of the K new tokens (K=1 plain decode; K>1 speculative verify)
    q_pos = (lengths[:, None] + jnp.arange(K, dtype=jnp.int32)[None]
             ).astype(jnp.int32)
    q, k_new, v_new = project_qkv(p, x, cfg, q_pos)
    Smax = cache_k.shape[1]
    if cache_pos is not None:
        k_pos = cache_pos.astype(jnp.int32)
        k_valid = cache_pos >= 0
    else:
        k_pos = jnp.arange(Smax, dtype=jnp.int32)[None, :].repeat(B, 0)
        k_valid = k_pos < lengths[:, None]
    # new tokens attend to cache + themselves (causally among each other)
    k_all = jnp.concatenate([cache_k, k_new], axis=1)
    v_all = jnp.concatenate([cache_v, v_new], axis=1)
    k_pos = jnp.concatenate([k_pos, q_pos], axis=1)
    k_valid = jnp.concatenate([k_valid, jnp.ones((B, K), bool)], axis=1)
    out = sdpa(q, k_all, v_all, q_pos=q_pos, k_pos=k_pos, causal=True,
               window=window, k_valid=k_valid, group_eff=layout_group)
    return o_proj_partial(p, out), (k_new, v_new)


def attn_decode_paged_partial(p: dict, x, cfg: ModelConfig, layout_group: int,
                              *, k_pages, v_pages, block_tables, lengths,
                              window: int = 0, kv_splits: int = 1):
    """Decode straight against the paged KV pool (no dense gather).

    x: (B,K,D) — K=1 plain decode, K>1 a speculative verify window whose
    token qi sits at position ``lengths[b] + qi``; k_pages/v_pages:
    (N, ps, Hkv_loc, hd) page pool (local shard); block_tables: (B, MB) int32
    (-1 pad); lengths: (B,) tokens resident.  ``kv_splits`` > 1 runs the
    kernel's sequence-parallel (split-KV) page walk: S contiguous spans
    emit per-span partials that the kernel's reduce step folds with the
    ``merge_softmax_states`` rule, so the state this layer merges is the
    same at every S.

    The Pallas kernel (kernels/flash_decode.py) walks the block table with an
    online softmax and returns the partial state over paged keys (one per
    window position); the window's own (k, v) — not yet scattered to pages —
    are folded in with one dense lower-triangular partial-softmax merge
    (``sdpa_partial`` over the K new tokens + ``merge_softmax_states``).
    Returns (partial_out (B,K,D), (k_new, v_new)); the page scatter is the
    stack driver's job (core/iso.run_stack_decode).
    """
    from repro.kernels.flash_decode import flash_decode
    B, K = x.shape[0], x.shape[1]
    # positions of the K new tokens (K=1 plain decode; K>1 speculative verify)
    q_pos = (lengths[:, None] + jnp.arange(K, dtype=jnp.int32)[None]
             ).astype(jnp.int32)
    q, k_new, v_new = project_qkv(p, x, cfg, q_pos)
    out_p, m_p, l_p = flash_decode(q, k_pages, v_pages, block_tables,
                                   lengths, window=window,
                                   kv_splits=kv_splits)  # (B,K,Hq,·)
    # intra-window: window token qi attends tokens 0..qi of the window
    # (lower triangular) — their KV is not in the pool during this call
    out_i, m_i, l_i = sdpa_partial(q, k_new, v_new, q_pos=q_pos, k_pos=q_pos,
                                   causal=True, window=window,
                                   group_eff=layout_group)
    out = merge_softmax_states(out_p, m_p, l_p, out_i, m_i, l_i)
    return o_proj_partial(p, out), (k_new, v_new)


def attn_encode_partial(p: dict, x, cfg: ModelConfig, layout_group: int, *,
                        kv_full):
    """Bidirectional (encoder) attention: this chunk's queries attend to the
    precomputed FULL-sequence k/v (projected once per layer — see core/iso.py)."""
    B, S, _ = x.shape
    pos = jnp.zeros((B, S), jnp.int32)
    q, _, _ = project_qkv(p, x, cfg, pos, use_rope=False)
    k, v = kv_full
    k_pos = jnp.zeros((B, k.shape[1]), jnp.int32)
    out = sdpa(q, k, v, q_pos=pos, k_pos=k_pos, causal=False,
               group_eff=layout_group)
    return o_proj_partial(p, out)


def attn_cross_partial(p: dict, x, cfg: ModelConfig, layout_group: int, *,
                       enc_k, enc_v, enc_valid=None):
    """Cross-attention (whisper decoder): q from x, kv precomputed from encoder."""
    B, S, _ = x.shape
    pos = jnp.zeros((B, S), jnp.int32)
    q, _, _ = project_qkv(p, x, cfg, pos, use_rope=False)
    Sk = enc_k.shape[1]
    k_pos = jnp.zeros((B, Sk), jnp.int32)
    out = sdpa(q, enc_k, enc_v, q_pos=pos, k_pos=k_pos, causal=False,
               k_valid=enc_valid, group_eff=layout_group)
    return o_proj_partial(p, out)


def cross_kv(p: dict, enc_out, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output (no rope)."""
    B, S, _ = enc_out.shape
    pos = jnp.zeros((B, S), jnp.int32)
    _, k, v = project_qkv(p, enc_out, cfg, pos, use_rope=False)
    return k, v

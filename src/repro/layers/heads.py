"""GQA head layout under tensor parallelism.

When ``tp > num_kv_heads`` (e.g. qwen3 kv=8 on a 16-way model axis) KV heads must be
replicated (the vLLM rule), and odd head counts (hymba: 25 Q / 5 KV) must pad so both
head axes divide the TP degree *and* every local Q head finds its logical KV head in a
*uniform* slot mapping (q slot ``s`` reads kv slot ``s // group``).  The construction:

    kv_eff  = tp * ceil(kv / tp)                 # kv slots, divisible by tp
    c       = floor(kv_eff / kv)                 # copies per logical kv head
    G       = ceil(Hq / kv)                      # logical GQA group size
    g_eff   = ceil(G / c)                        # q slots per kv slot
    hq_pad  = kv_eff * g_eff                     # q slots, divisible by tp

Logical kv head ``j`` occupies kv slots ``[j*c, (j+1)*c)``; its ``G`` q heads occupy q
slots ``[j*c*g_eff, ...)``.  Padding slots are zero-initialised in the Q and O
projections, making them exact mathematical no-ops.  With tp=1 this reduces to the
unpadded layout whenever ``Hq == kv * G``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class HeadLayout:
    hq: int                  # logical q heads
    hkv: int                 # logical kv heads
    hq_pad: int              # padded q slots (divisible by tp)
    hkv_eff: int             # kv slots incl. replication (divisible by tp)
    group_eff: int           # q slots per kv slot
    q_map: tuple             # slot -> logical q head or -1 (pad)
    kv_map: tuple            # slot -> logical kv head or -1 (pad)

    @property
    def q_waste(self) -> float:
        return 1.0 - self.hq / self.hq_pad

    def q_slot_mask(self) -> np.ndarray:
        return np.array([m >= 0 for m in self.q_map])


def head_layout(hq: int, hkv: int, tp: int) -> HeadLayout:
    assert 1 <= hkv <= hq
    kv_eff = tp * math.ceil(hkv / tp)
    c = kv_eff // hkv                       # copies per logical kv head
    used_kv = hkv * c                       # <= kv_eff; rest are pad slots
    G = math.ceil(hq / hkv)
    g_eff = math.ceil(G / c)
    hq_pad = kv_eff * g_eff
    assert hq_pad % tp == 0 and kv_eff % tp == 0 and c * g_eff >= G

    kv_map = [-1] * kv_eff
    for t in range(used_kv):
        kv_map[t] = t // c
    q_map = [-1] * hq_pad
    for j in range(hkv):
        base = j * c * g_eff
        n_q = min(G, hq - j * G)            # last group may be short
        for w in range(n_q):
            q_map[base + w] = j * G + w
    # invariant: q slot s reads kv slot s // g_eff which must hold its logical kv head
    for s, h in enumerate(q_map):
        if h >= 0:
            assert kv_map[s // g_eff] == h // G, (s, h, hq, hkv, tp)
    return HeadLayout(hq, hkv, hq_pad, kv_eff, g_eff, tuple(q_map), tuple(kv_map))


def expand_heads(w: np.ndarray | "object", mapping, axis: int):
    """Gather logical head slices into padded slots; pad slots become zero.

    ``w`` has the logical head axis at ``axis``; returns the slot-expanded array.
    Works for numpy and jax arrays.
    """
    import jax.numpy as jnp
    mapping = np.asarray(mapping)
    idx = np.where(mapping >= 0, mapping, 0)
    out = jnp.take(w, jnp.asarray(idx), axis=axis)
    mask_shape = [1] * out.ndim
    mask_shape[axis] = len(mapping)
    mask = jnp.asarray((mapping >= 0).reshape(mask_shape), dtype=out.dtype)
    return out * mask

"""Column→row parallel MLP (SwiGLU or GELU) — local-shard view, unreduced output."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, pad_to_multiple


def padded_d_ff(cfg_d_ff: int, tp: int) -> int:
    return pad_to_multiple(cfg_d_ff, tp) if cfg_d_ff else 0


def init_mlp(key, d_model: int, d_ff: int, mlp_type: str, tp: int,
             num_layers: int, dtype=jnp.bfloat16) -> dict:
    ff = padded_d_ff(d_ff, tp)
    k1, k2, k3 = jax.random.split(key, 3)
    s = 0.02
    so = s / (2 * num_layers) ** 0.5
    p = {
        "w_up": (jax.random.normal(k1, (d_model, ff), jnp.float32) * s).astype(dtype),
        "w_down": (jax.random.normal(k3, (ff, d_model), jnp.float32) * so).astype(dtype),
    }
    if mlp_type == "swiglu":
        p["w_gate"] = (jax.random.normal(k2, (d_model, ff), jnp.float32) * s).astype(dtype)
    return p


def mlp_partial(p: dict, x, mlp_type: str):
    """(B,S,D) -> unreduced (B,S,D) partial; caller applies the TP all-reduce."""
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if mlp_type == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])

"""RMSNorm / LayerNorm with fp32 accumulation, bf16 in/out."""
from __future__ import annotations

import jax.numpy as jnp


def rms_norm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jnp.reciprocal(jnp.sqrt(var + eps))
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jnp.reciprocal(jnp.sqrt(var + eps))
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm(params: dict, x, kind: str, eps: float):
    if kind == "ln":
        return layer_norm(x, params["scale"], params["bias"], eps)
    return rms_norm(x, params["scale"], eps)


def init_norm(d: int, kind: str, dtype=jnp.float32) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "ln":
        p["bias"] = jnp.zeros((d,), dtype)
    return p

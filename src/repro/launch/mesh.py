"""Mesh construction.  Functions (not module constants) so importing never touches
jax device state — required by the dry-run's XLA_FLAGS bootstrap ordering."""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro import compat
from repro.config import ParallelConfig


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh: one v5e pod (16x16 = 256 chips), or two pods
    (2x16x16 = 512) with a leading "pod" axis carried over DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(parallel: ParallelConfig):
    return compat.make_mesh(parallel.mesh_shape, parallel.axis_names)


def local_test_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — unit tests."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return compat.make_mesh((data, model), ("data", "model"))


def parallel_for_mesh(mesh) -> ParallelConfig:
    names = mesh.axis_names
    if "pod" in names:
        return ParallelConfig(pods=mesh.shape["pod"], data=mesh.shape["data"],
                              model=mesh.shape["model"])
    return ParallelConfig(data=mesh.shape["data"], model=mesh.shape["model"])

"""Mesh construction.  Functions (not module constants) so importing never touches
jax device state — required by the dry-run's XLA_FLAGS bootstrap ordering."""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro import compat
from repro.config import ParallelConfig


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh: one v5e pod (16x16 = 256 chips), or two pods
    (2x16x16 = 512) with a leading "pod" axis carried over DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(parallel: ParallelConfig):
    return compat.make_mesh(parallel.mesh_shape, parallel.axis_names)


def local_test_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — unit tests."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return compat.make_mesh((data, model), ("data", "model"))


def disagg_meshes(parallel: ParallelConfig):
    """Two disjoint (1, model) meshes — prefill + decode engines for
    disaggregated serving (serving/disagg.py).  Needs 2*model devices (on CPU
    export XLA_FLAGS=--xla_force_host_platform_device_count=<2*model>)."""
    tp = parallel.model
    devs = jax.devices()
    assert 2 * tp <= len(devs), \
        f"disagg under tp={tp} needs {2 * tp} devices, have {len(devs)}"
    shape, axes = (1, tp), ("data", "model")
    return (compat.make_mesh(shape, axes, devices=devs[:tp]),
            compat.make_mesh(shape, axes, devices=devs[tp:2 * tp]))


def parallel_for_mesh(mesh) -> ParallelConfig:
    names = mesh.axis_names
    if "pod" in names:
        return ParallelConfig(pods=mesh.shape["pod"], data=mesh.shape["data"],
                              model=mesh.shape["model"])
    return ParallelConfig(data=mesh.shape["data"], model=mesh.shape["model"])

"""Mesh construction.  Functions (not module constants) so importing never touches
jax device state — required by the dry-run's XLA_FLAGS bootstrap ordering."""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax

from repro import compat
from repro.config import ParallelConfig

# The async-collective recipe: make each all-reduce an independently
# schedulable unit and let the latency-hiding scheduler start it early /
# complete it late.  The deferred decode schedules (core/iso.py
# ``cross_block`` and the ladder driver) open the start→wait window; these
# flags are what lets the compiler actually fill it on GPU backends.  On
# TPU the latency-hiding scheduler is the default.  NOT every build
# registers every flag (XLA aborts at backend init on an unknown flag —
# e.g. CPU-only jaxlibs drop the two async-stream flags), so
# ``enable_latency_hiding`` probes each one in a subprocess first and only
# applies the accepted subset.
LATENCY_HIDING_XLA_FLAGS = (
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)


def _flags_accepted(flags, timeout: float = 120.0) -> bool:
    """True iff this install's XLA parses ``flags`` (throwaway subprocess —
    XLA aborts the whole process on an unknown flag, so probing in-process
    would kill the caller; flag registration also varies per XLA release,
    e.g. async collectives became default and lost their flag)."""
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = " ".join(
        filter(None, [env.get("XLA_FLAGS", ""), *flags]))
    try:
        res = subprocess.run(
            [sys.executable, "-c", "import jax; jax.devices()"],
            env=env, capture_output=True, timeout=timeout)
        return res.returncode == 0
    except Exception:
        return False


def enable_latency_hiding() -> bool:
    """Append the async-collective XLA flags to ``os.environ["XLA_FLAGS"]``.

    MUST run before the first jax backend touch (first jax.devices() /
    make_mesh / jit call) — XLA reads the env once at backend init; that is
    why this module keeps device state out of import time.  Idempotent: a
    flag already present (either value) is left alone so explicit user
    overrides win.  Each missing flag is validated against this install's
    XLA before it lands (subprocess probe, a few seconds per round) —
    unknown flags would otherwise abort the process at backend init.
    Returns True when any flag was newly appended.
    """
    current = os.environ.get("XLA_FLAGS", "")
    have = {f.split("=")[0] for f in current.split() if f.startswith("--")}
    missing = [f for f in LATENCY_HIDING_XLA_FLAGS
               if f.split("=")[0] not in have]
    if not missing:
        return False
    if not _flags_accepted(missing):
        missing = [f for f in missing if _flags_accepted([f])]
    if not missing:
        return False
    os.environ["XLA_FLAGS"] = " ".join(filter(None, [current, *missing]))
    return True


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh: one v5e pod (16x16 = 256 chips), or two pods
    (2x16x16 = 512) with a leading "pod" axis carried over DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(parallel: ParallelConfig):
    return compat.make_mesh(parallel.mesh_shape, parallel.axis_names)


def local_test_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — unit tests."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return compat.make_mesh((data, model), ("data", "model"))


def disagg_meshes(parallel: ParallelConfig):
    """Two disjoint (1, model) meshes — prefill + decode engines for
    disaggregated serving (serving/disagg.py).  Needs 2*model devices (on CPU
    export XLA_FLAGS=--xla_force_host_platform_device_count=<2*model>)."""
    tp = parallel.model
    devs = jax.devices()
    assert 2 * tp <= len(devs), \
        f"disagg under tp={tp} needs {2 * tp} devices, have {len(devs)}"
    shape, axes = (1, tp), ("data", "model")
    return (compat.make_mesh(shape, axes, devices=devs[:tp]),
            compat.make_mesh(shape, axes, devices=devs[tp:2 * tp]))


def parallel_for_mesh(mesh) -> ParallelConfig:
    names = mesh.axis_names
    if "pod" in names:
        return ParallelConfig(pods=mesh.shape["pod"], data=mesh.shape["data"],
                              model=mesh.shape["model"])
    return ParallelConfig(data=mesh.shape["data"], model=mesh.shape["model"])

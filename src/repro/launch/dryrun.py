import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
#   512 placeholder host devices let jax.make_mesh build the production meshes
#   (16x16 single pod, 2x16x16 multi-pod) for lower+compile WITHOUT hardware.

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh) lowers,
compiles, fits, and report the roofline terms (EXPERIMENTS.md §Dry-run/§Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--iso-off]
"""
# NOTE: no `from __future__ import annotations` — the XLA_FLAGS bootstrap must
# stay the first statements of the module.

import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import (Config, ISOConfig, INPUT_SHAPES, ModelConfig,
                          ParallelConfig, RuntimeConfig, get_model_config)
from repro import compat
from repro.core.analysis import overlap_metric, parse_collectives
from repro.launch.mesh import make_production_mesh, parallel_for_mesh
from repro.models import api
from repro.models.decoder import cache_specs, decoder_param_specs
from repro.perf.roofline import roofline_terms
from repro.training.optimizer import adamw_init
from repro.training.trainer import make_train_step

# archs whose full-attention flavour cannot run 500k-token decode; dense archs
# get a sliding-window variant instead (DESIGN.md §Arch-applicability)
LONG_SKIP = {"whisper-medium", "internvl2-2b"}
LONG_WINDOW = 8192


def variant_for_shape(cfg: ModelConfig, shape_name: str) -> Optional[ModelConfig]:
    if shape_name == "long_500k":
        if cfg.name in LONG_SKIP:
            return None
        if cfg.family in ("dense", "moe", "vlm") and not cfg.sliding_window:
            return dataclasses.replace(cfg, sliding_window=LONG_WINDOW)
    return cfg


def build_config(cfg: ModelConfig, mesh, iso_on: bool = True,
                 quantized: bool = False, num_chunks: int = 2,
                 policy: str = "even", seq_parallel: bool = False,
                 grad_int8: bool = False, zero1: bool = False) -> Config:
    parallel = dataclasses.replace(parallel_for_mesh(mesh),
                                   seq_parallel=seq_parallel)
    iso = ISOConfig(enabled=iso_on, num_chunks=num_chunks, split_policy=policy,
                    quantized_comm=quantized)
    return Config(model=cfg, parallel=parallel, iso=iso,
                  runtime=RuntimeConfig(grad_comm_int8=grad_int8, zero1=zero1))


def _abstract_params(cfg: ModelConfig, tp: int):
    return jax.eval_shape(
        lambda: api.init_params(jax.random.PRNGKey(0), cfg, tp))


def _with_periods(cfg: ModelConfig, k: int) -> ModelConfig:
    """Same architecture truncated to k pattern-periods (for two-point loop-cost
    extrapolation — XLA's cost_analysis counts while bodies ONCE)."""
    kw = dict(num_layers=k * len(cfg.block_pattern))
    if cfg.encoder_layers:
        kw["encoder_layers"] = k
    return dataclasses.replace(cfg, **kw)


def _periods_of(cfg: ModelConfig) -> int:
    return cfg.num_layers // len(cfg.block_pattern)


def lower_shape(arch: str, shape_name: str, *, multi_pod: bool = False,
                iso_on: bool = True, quantized: bool = False,
                num_chunks: int = 2, policy: str = "even",
                blockwise_attn: bool = False, grad_int8: bool = False,
                zero1: bool = False, verbose: bool = True) -> Optional[Dict[str, Any]]:
    """Lower + compile one (arch, shape, mesh) combination; return the report."""
    base_cfg = get_model_config(arch)
    shape = INPUT_SHAPES[shape_name]
    cfg = variant_for_shape(base_cfg, shape_name)
    if cfg is None:
        if verbose:
            print(f"SKIP {arch} x {shape_name} (recorded in DESIGN.md)")
        return None
    if blockwise_attn:
        cfg = dataclasses.replace(cfg, attn_impl="blockwise")

    mesh = make_production_mesh(multi_pod=multi_pod)
    config = build_config(cfg, mesh, iso_on=iso_on, quantized=quantized,
                          num_chunks=num_chunks, policy=policy,
                          grad_int8=grad_int8, zero1=zero1)
    tp = config.parallel.model

    def compile_for(cfg_k: ModelConfig, unroll: bool = False):
        cfg_local = config.replace(model=cfg_k)
        if unroll:
            cfg_local = cfg_local.replace(
                runtime=dataclasses.replace(cfg_local.runtime,
                                            unroll_layers=True))
        params_shape = _abstract_params(cfg_k, tp)
        with mesh:
            if shape.kind == "train":
                step_fn, *_ = make_train_step(cfg_local, mesh, params_shape)
                if cfg_local.runtime.zero1:
                    from repro.training.zero import zero1_init_local
                    dp = cfg_local.parallel.pods * cfg_local.parallel.data
                    opt_shape = jax.eval_shape(
                        lambda pr: compat.shard_map(
                            lambda q: zero1_init_local(q, dp), mesh=mesh,
                            in_specs=(make_train_step(cfg_local, mesh, pr)[1],),
                            out_specs=make_train_step(cfg_local, mesh, pr)[2],
                            check_vma=False)(pr), params_shape)
                else:
                    opt_shape = jax.eval_shape(adamw_init, params_shape)
                batch = api.make_inputs(cfg_k, shape.seq_len,
                                        shape.global_batch, abstract=True)
                labels_len = batch["tokens"].shape[1]
                batch["labels"] = jax.ShapeDtypeStruct(
                    (shape.global_batch, labels_len), jnp.int32)
                lowered = step_fn.lower(params_shape, opt_shape, batch,
                                        jax.ShapeDtypeStruct((), jnp.int32))
            elif shape.kind == "prefill":
                from repro.launch import runner
                batch = api.make_inputs(cfg_k, shape.seq_len,
                                        shape.global_batch, abstract=True)
                build = runner.make_prefill_fn(
                    cfg_local, mesh, params_shape,
                    logits_mode="last", return_cache=True,
                    cache_len=shape.seq_len, global_batch=shape.global_batch)
                lowered = build(batch).lower(params_shape, batch)
            else:  # decode
                from repro.launch import runner
                caches_shape = jax.eval_shape(
                    lambda: api.init_caches(cfg_k, shape.global_batch,
                                            shape.seq_len, tp))
                fn = runner.make_decode_fn(cfg_local, mesh,
                                           params_shape, caches_shape,
                                           global_batch=shape.global_batch)
                toks = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
                lens = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
                lowered = fn.lower(params_shape, toks, caches_shape, lens)
            compiled = lowered.compile()
        cost = compat.cost_analysis(compiled)
        coll = parse_collectives(compiled.as_text())
        return compiled, cost, coll

    t0 = time.perf_counter()
    # full-depth compile: the lowering/compile/fit PROOF for the real config
    compiled, cost, coll = compile_for(cfg)
    t_compile = time.perf_counter() - t0

    # two-point loop-cost extrapolation: XLA cost_analysis counts while-loop
    # bodies ONCE, so lower k=1 and k=2 periods and solve
    #   F(k) = entry + k*body  =>  total = entry + P*body
    P = _periods_of(cfg)
    def _extrap(key_fn):
        _, c1, l1 = ex1
        _, c2, l2 = ex2
        f1, f2 = key_fn(c1, l1), key_fn(c2, l2)
        body = max(f2 - f1, 0.0)
        entry = max(f1 - body, 0.0)
        return entry + P * body
    if P > 2:
        # probes UNROLL the layer loop so every layer's ops are visible to the
        # cost analysis; F(k) = entry + k*body then extrapolates exactly
        ex1 = compile_for(_with_periods(cfg, 1), unroll=True)
        ex2 = compile_for(_with_periods(cfg, 2), unroll=True)
        flops_dev = _extrap(lambda c, l: c.get("flops", 0.0))
        bytes_dev = _extrap(lambda c, l: c.get("bytes accessed", 0.0))
        wire_dev = _extrap(lambda c, l: l.wire_bytes)
        coll_counts = {k: int(_extrap(lambda c, l: float(l.counts.get(k, 0))))
                       for k in set(ex1[2].counts) | set(ex2[2].counts)}
    else:
        flops_dev = cost.get("flops", 0.0)
        bytes_dev = cost.get("bytes accessed", 0.0)
        wire_dev = coll.wire_bytes
        coll_counts = dict(coll.counts)

    mem = compiled.memory_analysis()
    n_dev = config.parallel.num_devices
    report: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": "2x16x16" if multi_pod
        else "16x16", "devices": n_dev,
        "iso": iso_on, "num_chunks": num_chunks if iso_on else 1,
        "compile_s": round(t_compile, 1),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_counts": coll_counts,
        "collective_wire_bytes_per_device": wire_dev,
        "memory": {
            "args_bytes": mem.argument_size_in_bytes,
            "out_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
    }
    report["roofline"] = roofline_terms(report, cfg, shape)
    if verbose:
        r = report["roofline"]
        print(f"OK {arch} x {shape_name} [{report['mesh']}] "
              f"compile={report['compile_s']}s "
              f"compute={r['compute_s']:.2e}s memory={r['memory_s']:.2e}s "
              f"collective={r['collective_s']:.2e}s -> {r['bottleneck']}")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--iso-off", action="store_true")
    ap.add_argument("--quantized", action="store_true")
    ap.add_argument("--chunks", type=int, default=2)
    ap.add_argument("--policy", type=str, default="even")
    ap.add_argument("--blockwise-attn", action="store_true")
    ap.add_argument("--grad-int8", action="store_true")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args(argv)

    from repro.configs import ASSIGNED
    archs = [args.arch] if args.arch else ASSIGNED
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    reports, failures = [], []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    r = lower_shape(arch, shape, multi_pod=mp,
                                    iso_on=not args.iso_off,
                                    quantized=args.quantized,
                                    num_chunks=args.chunks, policy=args.policy,
                                    blockwise_attn=args.blockwise_attn,
                                    grad_int8=args.grad_int8, zero1=args.zero1)
                    if r is not None:
                        reports.append(r)
                except Exception as e:  # noqa: BLE001 — report, keep sweeping
                    failures.append((arch, shape, mp, repr(e)[:400]))
                    print(f"FAIL {arch} x {shape} multi_pod={mp}: {e!r}"[:500])
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"reports": reports, "failures": failures}, f, indent=1)
    print(f"\n{len(reports)} OK, {len(failures)} FAIL")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

"""jit(shard_map(...)) wrappers around the model API — the distributed boundary
shared by serving, the dry-run, and the benchmarks."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.config import Config, ISOConfig, ModelConfig
from repro.core.overlap import AxisCtx
from repro.models import api
from repro.models.decoder import cache_specs, decoder_param_specs
from repro.training.trainer import batch_specs, make_axis_ctx


def _b_axes(config: Config, global_batch: int) -> Tuple[str, ...] | None:
    """Batch mesh axes, or None when the batch can't shard (long_500k: B=1)."""
    p = config.parallel
    dp = p.pods * p.data
    return p.batch_axes if global_batch % dp == 0 and global_batch >= dp else None


def input_specs_tree(cfg: ModelConfig, batch: Dict[str, Any], b_axes):
    specs = {}
    for k, v in batch.items():
        specs[k] = P(b_axes, *([None] * (v.ndim - 1)))
    return specs


def make_prefill_fn(config: Config, mesh, params_shape, *,
                    logits_mode: str = "last", return_cache: bool = False,
                    cache_len: int = 0, iso: Optional[ISOConfig] = None,
                    global_batch: int, donate_cache: bool = False):
    cfg = config.model
    iso = iso if iso is not None else config.iso
    ctx = make_axis_ctx(config)
    b_axes = _b_axes(config, global_batch)
    p_specs = decoder_param_specs(params_shape)

    def local_fn(params, batch):
        out = api.prefill(params, cfg, ctx, iso, batch,
                          logits_mode=logits_mode, return_cache=return_cache,
                          cache_len=cache_len,
                          unroll=config.runtime.unroll_layers)
        res = {"logits_local": out.get("logits_local"),
               "moe_aux": out["moe_aux"]}
        if return_cache:
            res["caches"] = out["caches"]
        return res

    def specs_of(batch):
        in_b = input_specs_tree(cfg, batch, b_axes)
        out_specs = {"logits_local": P(b_axes, None, "model"), "moe_aux": P()}
        if return_cache:
            # the prefill-built caches have the same TREE STRUCTURE as empty
            # decode caches (cache_specs only reads names/ndims), so probe the
            # specs from init_caches instead of tracing the full prefill
            dummy = jax.eval_shape(
                lambda: api.init_caches(cfg, global_batch, cache_len or 1,
                                        ctx.tp))
            out_specs["caches"] = cache_specs(dummy, batch_axes=b_axes,
                                              shard_batch=b_axes is not None)
        if logits_mode == "none":
            out_specs["logits_local"] = P()
        return in_b, out_specs

    def build(batch):
        in_b, out_specs = specs_of(batch)
        sm = compat.shard_map(local_fn, mesh=mesh, in_specs=(p_specs, in_b),
                           out_specs=out_specs, check_vma=False)
        return jax.jit(sm)

    return build


def make_decode_fn(config: Config, mesh, params_shape, caches_shape, *,
                   global_batch: int):
    cfg = config.model
    ctx = make_axis_ctx(config)
    b_axes = _b_axes(config, global_batch)
    p_specs = decoder_param_specs(params_shape)
    c_specs = cache_specs(caches_shape, batch_axes=b_axes,
                          shard_batch=b_axes is not None)

    def local_fn(params, tokens, caches, lengths):
        logits, new_caches = api.decode_step(
            params, cfg, ctx, tokens, caches, lengths,
            unroll=config.runtime.unroll_layers)
        return logits, new_caches

    sm = compat.shard_map(
        local_fn, mesh=mesh,
        in_specs=(p_specs, P(b_axes, None), c_specs, P(b_axes)),
        out_specs=(P(b_axes, None, "model"), c_specs),
        check_vma=False)
    return jax.jit(sm, donate_argnums=(2,))


def gather_logits(logits_local, mesh) -> jnp.ndarray:
    """(B,1,V_loc)-sharded logits -> host-replicated full-vocab array."""
    return jax.device_get(logits_local)

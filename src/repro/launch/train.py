"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

End-to-end driver (deliverable b): trains a reduced or full config with the
distributed train step, synthetic data pipeline, checkpointing and logging.  On
this CPU container use ``--preset 100m --steps 300`` (examples/train_small.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.config import (Config, ISOConfig, ModelConfig, ParallelConfig,
                          RuntimeConfig, get_model_config)
from repro.launch.mesh import local_test_mesh, make_mesh
from repro.training import checkpoint as ckpt_lib
from repro.training.data import make_training_batch
from repro.training.trainer import init_train_state, make_train_step


def reduce_cfg(cfg: ModelConfig, preset: str) -> ModelConfig:
    """Shrink an arch to a trainable-on-CPU size, keeping its family/structure."""
    if preset == "full":
        return cfg
    sizes = {"tiny": (2, 128, 512), "100m": (4, 512, 8192)}
    layers, d, vocab = sizes[preset]
    n_pat = len(cfg.block_pattern)
    layers = max(layers, n_pat)
    layers -= layers % n_pat
    heads = max(2, min(cfg.num_heads, d // 64))
    kv = max(1, min(cfg.num_kv_heads, heads))
    kw = dict(num_layers=layers, d_model=d, num_heads=heads, num_kv_heads=kv,
              head_dim=0, d_ff=(d * 4 if cfg.d_ff else 0),
              vocab_size=min(cfg.vocab_size, vocab),
              encoder_layers=min(cfg.encoder_layers, layers),
              encoder_frames=min(cfg.encoder_frames, 64),
              num_patches=min(cfg.num_patches, 16))
    if cfg.moe:
        kw["moe"] = dataclasses.replace(cfg.moe, num_experts=4, top_k=2,
                                        d_ff_expert=d * 2, capacity_factor=2.0,
                                        shared_expert_d_ff=(
                                            d if cfg.moe.shared_expert_d_ff else 0))
    if cfg.sliding_window:
        kw["sliding_window"] = 64
    return dataclasses.replace(cfg, **kw)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="100m", choices=["tiny", "100m", "full"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--iso", action="store_true",
                    help="train with the ISO schedule (default: baseline)")
    ap.add_argument("--ckpt", type=str, default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = reduce_cfg(get_model_config(args.arch), args.preset)
    parallel = ParallelConfig(data=args.data, model=args.model)
    rt = RuntimeConfig(mode="train_iso" if args.iso else "train",
                       seq_len=args.seq_len, global_batch=args.batch,
                       learning_rate=args.lr, max_steps=args.steps,
                       warmup_steps=max(10, args.steps // 20), remat=True)
    config = Config(model=cfg, parallel=parallel, runtime=rt,
                    iso=ISOConfig(num_chunks=2, min_chunk_tokens=32,
                                  chunk_align=16))
    mesh = make_mesh(parallel)
    key = jax.random.PRNGKey(0)
    params, opt = init_train_state(config, mesh, key)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} preset={args.preset} params={n_params/1e6:.1f}M "
          f"mesh={parallel.mesh_shape}")

    step_fn, *_ = make_train_step(config, mesh, jax.eval_shape(lambda: params))
    t_start = time.perf_counter()
    with mesh:
        for step in range(args.steps):
            b = make_training_batch(cfg, args.seq_len, args.batch, step)
            b = {k: jnp.asarray(v) for k, v in b.items()}
            params, opt, loss, gnorm = step_fn(params, opt, b, jnp.int32(step))
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.perf_counter() - t_start
                tok_s = (step + 1) * args.batch * args.seq_len / dt
                print(f"step {step:5d} loss {float(loss):.4f} "
                      f"gnorm {float(gnorm):.3f} tok/s {tok_s:,.0f}")
    if args.ckpt:
        ckpt_lib.save(args.ckpt, {"params": params}, step=args.steps)
        print(f"checkpoint -> {args.ckpt}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

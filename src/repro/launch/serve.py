"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

End-to-end driver (deliverable b): spins up the Engine on a reduced config,
submits a batch of synthetic requests, and reports latency/throughput with ISO
on vs off — the paper's experiment shape, runnable on this CPU container.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import Config, ISOConfig, ParallelConfig, RuntimeConfig, \
    ServingConfig, get_model_config
from repro.launch.train import reduce_cfg
from repro.models import api
from repro.serving import Engine, PagedEngine, Request
from repro.serving.requests import SamplingParams


def _drive(eng, args):
    """Step the engine to completion, printing a one-line metrics summary
    every ``--metrics-every`` steps (the dense and paged engines share the
    loop; pool columns are paged-only)."""
    if hasattr(eng, "prefill"):               # DisaggRouter: two engines
        return _drive_disagg(eng, args)
    if not args.metrics_every:
        return eng.run_until_complete()
    paged = hasattr(eng, "alloc")
    t_last = time.perf_counter()
    toks_last = 0
    for _ in range(10_000):
        eng.step()
        waiting = eng.scheduler.waiting if paged else eng.pending
        done = not waiting and all(s is None for s in eng.slots)
        m = eng.metrics
        if m["steps"] % args.metrics_every == 0 or done:
            now = time.perf_counter()
            toks = m["decode_tokens"] + m["prefill_samples"]
            rate = (toks - toks_last) / max(now - t_last, 1e-9)
            t_last, toks_last = now, toks
            active = sum(s is not None for s in eng.slots)
            line = (f"[metrics] step={m['steps']} active={active} "
                    f"waiting={len(waiting)} tok/s={rate:.1f}")
            if paged:
                line += (f" pool={eng.alloc.used_pages}/"
                         f"{eng.alloc.num_pages}"
                         f" frag={eng.alloc.fragmentation()}")
                if eng.spec_k:
                    line += f" accept/call={eng.accepted_per_call():.2f}"
            print(line)
        if done:
            break
    out = {}
    for st in eng._finished:
        out[st.request.rid] = st.generated
    return out


def _drive_disagg(router, args):
    """Step the router to completion; per-phase metrics lines on request."""
    if not args.metrics_every:
        return router.run_until_complete()
    t_last = time.perf_counter()
    toks_last = steps = 0
    for _ in range(10_000):
        router.step()
        steps += 1
        done = router.done()
        if steps % args.metrics_every == 0 or done:
            pm = router.prefill.metrics
            dm = router.decode.metrics
            now = time.perf_counter()
            toks = dm["decode_tokens"] + pm["prefill_samples"]
            rate = (toks - toks_last) / max(now - t_last, 1e-9)
            t_last, toks_last = now, toks
            ms = router.migration_stats()
            print(f"[metrics] step={steps} "
                  f"prefill_active="
                  f"{sum(s is not None for s in router.prefill.slots)} "
                  f"decode_active="
                  f"{sum(s is not None for s in router.decode.slots)} "
                  f"waiting={len(router.prefill.scheduler.waiting)} "
                  f"tok/s={rate:.1f} "
                  f"migrated={ms['migrated_requests']} "
                  f"deferrals={ms['deferrals']}")
        if done:
            break
    out = {}
    for st in router.prefill._finished + router.decode._finished:
        out[st.request.rid] = st.generated
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m", "full"])
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--iso-off", action="store_true")
    ap.add_argument("--chunks", type=int, default=2)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache + chunked-prefill scheduler")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-budget", type=int, default=64)
    ap.add_argument("--policy", default="fcfs", choices=["fcfs", "priority"])
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: the paged engine runs under "
                         "shard_map over a (1, tp) mesh (needs tp devices; on "
                         "CPU export XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=<tp>)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a common system prompt of N tokens to every "
                         "request (exercises CoW prefix/page sharing)")
    ap.add_argument("--no-prefix-sharing", action="store_true")
    ap.add_argument("--no-batched-prefill", action="store_true",
                    help="run prefill grants batch-1 (one forward call per "
                         "grant) instead of packing same-bucket grants into "
                         "one batched call per scheduler tick")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated serving: one prefill engine + one "
                         "decode engine, requests migrate by KV-page "
                         "transfer the moment their prompt is resident "
                         "(serving/disagg.py; with --tp N the two engines "
                         "run on disjoint N-device meshes — needs 2N "
                         "devices)")
    ap.add_argument("--decode-pool-pages", type=int, default=0,
                    help="decode-side page-pool size under --disagg "
                         "(0 = same as the prefill pool); a full decode "
                         "pool defers migration, it never drops requests")
    ap.add_argument("--migrate-batch", type=int, default=0,
                    help="max requests migrated per router step under "
                         "--disagg (0 = all that fit); batched transfers "
                         "keep CoW page sharing across the move")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: verify a (k+1)-token "
                         "self-drafted window per decode step (greedy only; "
                         "paged engine runs it through the flash-decode "
                         "kernel, dense engine through the padded cache)")
    ap.add_argument("--cost-table", default="", metavar="PATH|auto",
                    help="measured cost model (perf/costmodel.py): 'auto' "
                         "loads the bundled per-platform table under "
                         "src/repro/perf/tables/, a path loads that table; "
                         "the engine/scheduler then CHOOSE split counts, "
                         "chunk sizes, pack widths and the spec gate from "
                         "measurements (any load failure falls back to "
                         "static defaults with one warning trace event)")
    ap.add_argument("--autotune", action="store_true",
                    help="before serving, profile this machine (smoke "
                         "sweeps) and serve with the resulting cost model "
                         "(ignores --cost-table); write a persistent table "
                         "with benchmarks/autotune.py instead")
    ap.add_argument("--trace-out", default=None, metavar="trace.json",
                    help="export the engine's structured trace as Chrome-"
                         "trace JSON (open at https://ui.perfetto.dev)")
    ap.add_argument("--metrics-every", type=int, default=0, metavar="N",
                    help="print a one-line metrics summary every N engine "
                         "steps (active slots, pool occupancy, tok/s, "
                         "accept rate)")
    ap.add_argument("--jax-profile", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the run into DIR "
                         "(TensorBoard/Perfetto; engine closure dispatches "
                         "are TraceAnnotation'd)")
    ap.add_argument("--probe-overlap", action="store_true",
                    help="after the run, measure decode overlap efficiency "
                         "per collective schedule (sequential vs batch-split "
                         "vs ladder vs cross-block on identical synthetic "
                         "batches; paged engine only)")
    ap.add_argument("--decode-schedule", default="auto",
                    choices=["auto", "sequential", "batch_split",
                             "cross_block"],
                    help="decode collective schedule (core/iso.py): auto "
                         "picks batch_split when the mesh + batch allow it; "
                         "cross_block defers every all-reduce to the next "
                         "stage top (token-identical; pays off with "
                         "--latency-hiding).  Ladder wiring is an ARCH "
                         "(--arch ladder-qwen3-4b ...), not a schedule flag")
    ap.add_argument("--latency-hiding", action="store_true",
                    help="set the async-collective XLA flags "
                         "(launch/mesh.LATENCY_HIDING_XLA_FLAGS) before "
                         "backend init so the latency-hiding scheduler can "
                         "fill the deferred-collective windows the "
                         "cross_block/ladder schedules open")
    args = ap.parse_args(argv)
    if args.latency_hiding:
        # must land in XLA_FLAGS before the first backend touch below
        # (jax.random.PRNGKey init); mesh.py keeps imports side-effect-free
        # precisely so this ordering works
        from repro.launch.mesh import enable_latency_hiding
        if enable_latency_hiding():
            print("[xla] async-collective latency-hiding flags enabled")
    if args.probe_overlap and not args.paged:
        ap.error("--probe-overlap requires --paged")
    if (args.autotune or args.cost_table) and not args.paged:
        ap.error("--autotune/--cost-table require --paged (the dense Engine "
                 "has no cost-model decision points)")
    if args.spec_k and args.temperature > 0:
        ap.error("--spec-k is greedy-only (needs --temperature 0)")
    if args.disagg and not args.paged:
        ap.error("--disagg requires --paged (migration moves KV pages)")
    if args.disagg and (args.probe_overlap or args.autotune):
        ap.error("--disagg does not combine with --probe-overlap/--autotune")
    if (args.decode_pool_pages or args.migrate_batch) and not args.disagg:
        ap.error("--decode-pool-pages/--migrate-batch require --disagg")

    cfg = reduce_cfg(get_model_config(args.arch), args.preset)
    if args.paged and cfg.family == "audio":
        ap.error("--paged does not support enc-dec (audio) archs yet")
    if args.tp > 1 and not args.paged:
        ap.error("--tp requires --paged (the dense Engine stays single-device)")
    iso = ISOConfig(enabled=not args.iso_off, num_chunks=args.chunks,
                    min_chunk_tokens=16, chunk_align=16)
    max_len = args.shared_prefix + args.prompt_len + args.max_new + 8
    max_len = max_len + (args.spec_k + 1 if args.spec_k else 0)
    serving = ServingConfig(page_size=args.page_size, max_batch=args.max_batch,
                            max_len=max_len,
                            prefill_token_budget=args.prefill_budget,
                            scheduler_policy=args.policy,
                            prefix_sharing=not args.no_prefix_sharing,
                            prefill_batching=not args.no_batched_prefill,
                            spec_k=args.spec_k,
                            cost_table="" if args.autotune
                            else args.cost_table,
                            disagg=args.disagg,
                            decode_pool_pages=args.decode_pool_pages,
                            migrate_batch=args.migrate_batch,
                            decode_schedule=args.decode_schedule,
                            latency_hiding=args.latency_hiding)
    config = Config(model=cfg, parallel=ParallelConfig(data=1, model=args.tp),
                    iso=iso, runtime=RuntimeConfig(mode="serve"),
                    serving=serving)
    key = jax.random.PRNGKey(0)
    params = api.init_params(key, cfg, tp=args.tp)
    if args.paged:
        mesh = None
        if args.tp > 1 and not args.disagg:
            from repro.launch.mesh import make_mesh
            mesh = make_mesh(config.parallel)
        if args.autotune:
            # in-process profile of THIS machine/mesh (smoke sweeps), then
            # serve with the resulting model injected
            import dataclasses

            from repro.perf.costmodel import CostModel, autotune
            print("[autotune] profiling (smoke sweeps)...")
            table = autotune(config, params, mesh=mesh, smoke=True,
                             log=lambda msg: print(f"[autotune] {msg}"))
            config = config.replace(serving=dataclasses.replace(
                serving, cost_model=CostModel(table)))
        if args.disagg:
            from repro.serving.disagg import DisaggRouter
            pmesh = dmesh = None
            if args.tp > 1:
                from repro.launch.mesh import disagg_meshes
                pmesh, dmesh = disagg_meshes(config.parallel)
            eng = DisaggRouter(config, params, prefill_mesh=pmesh,
                               decode_mesh=dmesh)
        else:
            eng = PagedEngine(config, params, mesh=mesh)
        if not args.disagg and eng.cost_model is not None:
            print(f"[costmodel] active: platform={eng.cost_model.platform} "
                  f"tp={eng.cost_model.tp} "
                  f"alpha={eng.cost_model.alpha_s:.3e}s "
                  f"beta={eng.cost_model.beta_s_per_byte:.3e}s/B")
    else:
        eng = Engine(config, params, mesh=None, max_batch=args.max_batch,
                     max_len=max_len, bucket=32, spec_k=args.spec_k)

    rng = np.random.default_rng(0)
    system = rng.integers(2, cfg.vocab_size,
                          args.shared_prefix).astype(np.int32)
    t0 = time.perf_counter()
    for i in range(args.requests):
        plen = int(rng.integers(args.prompt_len // 2, args.prompt_len))
        prompt = rng.integers(2, cfg.vocab_size, plen).astype(np.int32)
        if args.shared_prefix:
            prompt = np.concatenate([system, prompt])
        req = Request(
            prompt=prompt,
            sampling=SamplingParams(max_new_tokens=args.max_new, eos_id=-1,
                                    temperature=args.temperature, seed=i))
        if cfg.family == "audio":
            req.frames = (rng.standard_normal(
                (cfg.encoder_frames, cfg.d_model)) * 0.1).astype(np.float32)
        if cfg.family == "vlm":
            req.patches = (rng.standard_normal(
                (cfg.num_patches, cfg.d_model)) * 0.1).astype(np.float32)
        eng.add_request(req)
    if args.jax_profile:
        from repro.obs import jaxprof
        jaxprof.start(args.jax_profile)
    outs = _drive(eng, args)
    wall = time.perf_counter() - t0
    if args.jax_profile:
        from repro.obs import jaxprof
        jaxprof.stop()

    total_new = sum(len(v) for v in outs.values())
    if args.disagg:
        pm, dm = eng.prefill.metrics, eng.decode.metrics
        ms = eng.migration_stats()
        ttft = pm["ttft_sum"] / max(pm["ttft_n"], 1)
        tpot = eng.decode.registry.histogram("tpot")
        print(f"arch={cfg.name} iso={'off' if args.iso_off else 'on'} "
              f"disagg requests={len(outs)} new_tokens={total_new} "
              f"wall={wall:.2f}s")
        print(f"prefill phase: {pm['prefill_tokens']} tok in "
              f"{pm['prefill_s']:.2f}s calls={pm['prefill_calls']} "
              f"grants={pm['prefill_grants']} ttft={ttft * 1e3:.1f}ms")
        print(f"decode phase: {dm['decode_tokens']} tok in "
              f"{dm['decode_s']:.2f}s calls={dm['decode_calls']} "
              f"tpot={tpot.mean * 1e3:.2f}ms "
              f"preemptions={dm['preemptions']}")
        print(f"migration: transfers={ms['migrations']} "
              f"requests={ms['migrated_requests']} "
              f"pages={ms['migrated_pages']} "
              f"us={ms['migration_us']:.0f} "
              f"deferrals={ms['deferrals']} "
              f"bounce_backs={ms['bounce_backs']}")
        if args.trace_out:
            from repro.obs import write_chrome_trace
            ev = eng.prefill.trace.events() + eng.decode.trace.events()
            n = write_chrome_trace(ev, args.trace_out)
            print(f"trace: {n} events -> {args.trace_out} (both engines)")
        for rid in sorted(outs)[:3]:
            print(f"  rid {rid}: {outs[rid][:10]}"
                  f"{'...' if len(outs[rid]) > 10 else ''}")
        return 0
    m = eng.metrics
    print(f"arch={cfg.name} iso={'off' if args.iso_off else 'on'} "
          f"requests={len(outs)} new_tokens={total_new} wall={wall:.2f}s")
    print(f"prefill: {m['prefill_tokens']} tok in {m['prefill_s']:.2f}s | "
          f"decode: {m['decode_s']:.2f}s | completed={m['completed']}")
    if args.paged:
        s = eng.page_stats()
        ttft = m["ttft_sum"] / max(m["ttft_n"], 1)
        print(f"paged: steps={m['steps']} prefill_calls={m['prefill_calls']} "
              f"prefill_grants={m['prefill_grants']} "
              f"preemptions={m['preemptions']} ttft={ttft * 1e3:.1f}ms | "
              f"pages={s['num_pages']}x{s['page_size']} "
              f"kv_reserved={s['kv_bytes_reserved']}B tp={args.tp}")
        print(f"sharing: shared_tokens={m['prefix_shared_tokens']} "
              f"cow_copies={m['cow_copies']} "
              f"peak_pages={m['peak_used_pages']}")
        if args.autotune or args.cost_table:
            ev = eng.trace.events()
            dec = sum(1 for e in ev if e.kind == "decision")
            warn = sum(1 for e in ev if e.kind == "warning")
            print(f"costmodel: decisions={dec} warnings={warn} "
                  f"(see --trace-out for per-decision detail)")
        if args.spec_k:
            print(f"speculative: spec_k={args.spec_k} "
                  f"verify_calls={m['spec_calls']} "
                  f"accepted_per_call={eng.accepted_per_call():.2f} "
                  f"decode_tokens={m['decode_tokens']}")
    elif args.spec_k:
        print(f"speculative: spec_k={args.spec_k} "
              f"extra_accepted={m['spec_accepted']} "
              f"decode_calls={m['decode_calls']} "
              f"decode_tokens={m['decode_tokens']}")
    if args.probe_overlap:
        res = eng.measure_overlap_efficiency()
        exp = res["exposed_comm_s"]
        print(f"overlap probe: efficiency={res['overlap_efficiency']:.3f} "
              f"ladder_speedup={res['ladder_speedup']:.3f}"
              f"{' (proxy)' if res['ladder_proxy'] else ''} "
              f"exposed_comm="
              f"{'n/a' if exp is None else f'{exp * 1e3:.2f}ms'} "
              f"(tp={res['tp']}, B={res['batch']})")
        for name, t in sorted(res["schedules"].items()):
            print(f"  schedule {name:<12} {t * 1e3:.2f} ms/step")
    if args.trace_out:
        from repro.obs import write_chrome_trace
        n = write_chrome_trace(eng.trace.events(), args.trace_out)
        print(f"trace: {n} events -> {args.trace_out} "
              f"(dropped={eng.trace.dropped}; open at https://ui.perfetto.dev)")
    for rid in sorted(outs)[:3]:
        print(f"  rid {rid}: {outs[rid][:10]}{'...' if len(outs[rid]) > 10 else ''}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Typed metrics: counters, gauges, histograms behind a dict-compatible view.

The engines used to keep a raw ``self.metrics`` dict — fine for sums, useless
for distributions (a TTFT *mean* hides the p99 the scheduler actually
degrades).  This module keeps the dict IDIOM (``metrics["decode_tokens"] += n``
still works, every existing test reads unchanged) while the storage becomes
typed instruments:

  * ``Counter``   — monotonically-growing scalar (float or int);
  * ``Gauge``     — last-set value, with the running peak tracked for free;
  * ``Histogram`` — fixed bucket ladder (upper edges), O(1) observe, and
    bucket-interpolated percentiles.  Ladders are FIXED per quantity
    (``TTFT_BUCKETS_S`` etc.) so histograms from different runs/engines are
    mergeable bucket-by-bucket — the Prometheus rule.

``MetricsRegistry.view()`` returns the MutableMapping the engines expose as
``.metrics``.  Scalars (counters and gauges) live in one namespace; histograms
are reached through the registry only (``registry.histogram("ttft")``) — a
distribution has no single scalar value to impersonate.

Pure Python, no JAX: observe/inc are a dict lookup and an add, so keeping the
registry always-on costs nanoseconds against millisecond-scale jitted calls
(benchmarks/engine_bench.py measures the end-to-end overhead per PR).
"""
from __future__ import annotations

from bisect import bisect_left
from typing import Dict, Iterator, List, MutableMapping, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# fixed bucket ladders (upper edges, ascending; +inf overflow bucket implied)
# ---------------------------------------------------------------------------

# time-to-first-token, seconds: 0.5ms .. 10s, ~geometric
TTFT_BUCKETS_S: Tuple[float, ...] = (
    0.0005, 0.001, 0.002, 0.005, 0.01, 0.02, 0.05, 0.1, 0.2, 0.5,
    1.0, 2.0, 5.0, 10.0)

# time-per-output-token, seconds: 0.1ms .. 1s
TPOT_BUCKETS_S: Tuple[float, ...] = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0)

# prefill grant size, tokens: power-of-two ladder mirroring grant bucketing
GRANT_SIZE_BUCKETS: Tuple[float, ...] = (
    1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)

# tokens accepted per speculative verify call (K is small)
ACCEPT_LEN_BUCKETS: Tuple[float, ...] = (1, 2, 3, 4, 6, 8, 12, 16)


class Counter:
    """Monotonic scalar.  ``set`` exists only for the legacy dict view."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n

    def set(self, v) -> None:
        self.value = v

    def __repr__(self) -> str:
        return f"Counter({self.name}={self.value})"


class Gauge:
    """Last-set value; the running peak comes along for free."""
    __slots__ = ("name", "value", "peak")

    def __init__(self, name: str):
        self.name = name
        self.value = 0
        self.peak = 0

    def set(self, v) -> None:
        self.value = v
        if v > self.peak:
            self.peak = v

    def __repr__(self) -> str:
        return f"Gauge({self.name}={self.value}, peak={self.peak})"


class Histogram:
    """Fixed-ladder histogram: ``edges`` are ascending upper bounds; bucket i
    counts observations <= edges[i] (and > edges[i-1]); one overflow bucket
    catches the rest.  ``percentile`` interpolates linearly inside the bucket
    the rank falls in, clamped by the observed min/max so tiny samples don't
    report a bucket edge nobody hit."""
    __slots__ = ("name", "edges", "counts", "n", "sum", "min", "max")

    def __init__(self, name: str, edges: Sequence[float]):
        assert edges and list(edges) == sorted(edges), \
            f"histogram {name}: edges must be ascending, got {edges}"
        self.name = name
        self.edges: Tuple[float, ...] = tuple(float(e) for e in edges)
        self.counts: List[int] = [0] * (len(self.edges) + 1)
        self.n = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect_left(self.edges, v)] += 1
        self.n += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.sum / self.n if self.n else 0.0

    def percentile(self, q: float) -> float:
        """q in [0, 1].  0.0 with no observations."""
        if not self.n:
            return 0.0
        assert 0.0 <= q <= 1.0, q
        rank = q * self.n
        seen = 0.0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            if seen + c >= rank:
                lo = self.edges[i - 1] if i > 0 else min(self.min, self.edges[0])
                hi = self.edges[i] if i < len(self.edges) else self.max
                lo = max(lo, self.min)
                hi = min(hi, self.max)
                if hi <= lo:
                    return lo
                frac = (rank - seen) / c
                return lo + frac * (hi - lo)
            seen += c
        return self.max

    def snapshot(self) -> Dict[str, float]:
        return {"n": self.n, "sum": self.sum, "mean": self.mean,
                "min": self.min if self.n else 0.0,
                "max": self.max if self.n else 0.0,
                "p50": self.percentile(0.50), "p90": self.percentile(0.90),
                "p99": self.percentile(0.99)}

    def __repr__(self) -> str:
        return (f"Histogram({self.name}: n={self.n} mean={self.mean:.4g} "
                f"p50={self.percentile(0.5):.4g} "
                f"p99={self.percentile(0.99):.4g})")


class MetricsView(MutableMapping):
    """The engines' ``.metrics``: a MutableMapping over the registry's scalar
    namespace.  ``m[k] += 1`` and ``m[k] = max(m[k], v)`` hit Counter/Gauge
    storage; missing keys raise KeyError like the dict did (engines
    pre-register their key set, so a typo'd metric name still fails loudly)."""

    def __init__(self, registry: "MetricsRegistry"):
        self._r = registry

    def __getitem__(self, key: str):
        return self._r._scalars[key].value

    def __setitem__(self, key: str, value) -> None:
        s = self._r._scalars.get(key)
        if s is None:
            s = self._r.counter(key)
        s.set(value)

    def __delitem__(self, key: str) -> None:
        del self._r._scalars[key]

    def __iter__(self) -> Iterator[str]:
        return iter(self._r._scalars)

    def __len__(self) -> int:
        return len(self._r._scalars)

    def __repr__(self) -> str:
        return repr({k: s.value for k, s in self._r._scalars.items()})


class MetricsRegistry:
    """Create-on-first-use instrument store.  One per engine."""

    def __init__(self):
        self._scalars: Dict[str, object] = {}     # Counter | Gauge
        self._hists: Dict[str, Histogram] = {}
        self._view = MetricsView(self)

    # ---- instruments ------------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self._scalars.get(name)
        if c is None:
            c = self._scalars[name] = Counter(name)
        assert isinstance(c, Counter), f"{name} is {type(c).__name__}"
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._scalars.get(name)
        if g is None:
            g = self._scalars[name] = Gauge(name)
        assert isinstance(g, Gauge), f"{name} is {type(g).__name__}"
        return g

    def histogram(self, name: str,
                  edges: Optional[Sequence[float]] = None) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            assert edges is not None, \
                f"histogram {name} not registered and no edges given"
            h = self._hists[name] = Histogram(name, edges)
        return h

    def counters(self, names: Sequence[str]) -> None:
        """Pre-register a key set so ``view[k]`` never KeyErrors for it and
        ``== 0`` assertions hold before first increment."""
        for n in names:
            self.counter(n)

    # ---- access -----------------------------------------------------------
    def view(self) -> MetricsView:
        return self._view

    @property
    def histograms(self) -> Dict[str, Histogram]:
        return dict(self._hists)

    def snapshot(self) -> Dict[str, object]:
        """Flat JSON-ready dump: scalars by name, gauges add ``name_peak``,
        histograms add ``name_{n,sum,mean,min,max,p50,p90,p99}``."""
        out: Dict[str, object] = {}
        for name, s in self._scalars.items():
            out[name] = s.value
            if isinstance(s, Gauge):
                out[name + "_peak"] = s.peak
        for name, h in self._hists.items():
            for k, v in h.snapshot().items():
                out[f"{name}_{k}"] = v
        return out

"""Chrome-trace (Perfetto-loadable) JSON export of a TraceEvent stream.

The output follows the Trace Event Format: a ``traceEvents`` list of
``"X"`` complete slices (events with ``dur > 0``), ``"i"`` instants, and
``"C"`` counter series (pool occupancy), timestamps in MICROseconds.  Open it
at https://ui.perfetto.dev (or chrome://tracing) — docs/observability.md.

Track layout: per-request events render on a thread per engine slot
(``tid = 10 + slot``); slot-less events land on fixed subsystem tracks
(engine 0, scheduler 1, allocator 2).  ``validate_chrome_trace`` is the CI
trace-schema lane's oracle: structural keys, known phase types, numeric
non-negative timestamps in non-decreasing order.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Sequence

from repro.obs.trace import TraceEvent

_PID = 1
_TRACK_ENGINE, _TRACK_SCHED, _TRACK_ALLOC = 0, 1, 2
_KIND_TRACK = {
    "grant": _TRACK_SCHED, "pack": _TRACK_SCHED, "defer": _TRACK_SCHED,
    "alloc": _TRACK_ALLOC, "free": _TRACK_ALLOC, "cow": _TRACK_ALLOC,
    "adopt": _TRACK_ALLOC, "pool": _TRACK_ALLOC,
}
_COUNTER_KINDS = ("pool",)


def _tid(ev: TraceEvent) -> int:
    if ev.slot >= 0:
        return 10 + ev.slot
    return _KIND_TRACK.get(ev.kind, _TRACK_ENGINE)


def chrome_trace(events: Sequence[TraceEvent],
                 process_name: str = "repro-serving") -> Dict[str, Any]:
    """Trace Event Format document.  Event times are rebased to the stream's
    first timestamp so the trace starts at t=0."""
    t0 = min((ev.ts for ev in events), default=0.0)
    out: List[Dict[str, Any]] = [
        {"name": "process_name", "ph": "M", "pid": _PID, "tid": 0,
         "args": {"name": process_name}},
        {"name": "thread_name", "ph": "M", "pid": _PID, "tid": _TRACK_ENGINE,
         "args": {"name": "engine"}},
        {"name": "thread_name", "ph": "M", "pid": _PID, "tid": _TRACK_SCHED,
         "args": {"name": "scheduler"}},
        {"name": "thread_name", "ph": "M", "pid": _PID, "tid": _TRACK_ALLOC,
         "args": {"name": "allocator"}},
    ]
    slots = sorted({ev.slot for ev in events if ev.slot >= 0})
    for s in slots:
        out.append({"name": "thread_name", "ph": "M", "pid": _PID,
                    "tid": 10 + s, "args": {"name": f"slot {s}"}})
    for ev in sorted(events, key=lambda e: e.ts):
        ts_us = (ev.ts - t0) * 1e6
        args: Dict[str, Any] = dict(ev.payload)
        if ev.rid >= 0:
            args["rid"] = ev.rid
        if ev.kind in _COUNTER_KINDS:
            # counter series: numeric args only
            out.append({"name": ev.kind, "ph": "C", "pid": _PID,
                        "tid": _tid(ev), "ts": ts_us,
                        "args": {k: v for k, v in args.items()
                                 if isinstance(v, (int, float))}})
        elif ev.dur > 0:
            out.append({"name": ev.kind, "ph": "X", "pid": _PID,
                        "tid": _tid(ev), "ts": ts_us, "dur": ev.dur * 1e6,
                        "args": args})
        else:
            out.append({"name": ev.kind, "ph": "i", "pid": _PID,
                        "tid": _tid(ev), "ts": ts_us, "s": "t", "args": args})
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(events: Sequence[TraceEvent], path: str,
                       process_name: str = "repro-serving") -> int:
    """Write the JSON document; returns the number of trace events written
    (metadata records excluded)."""
    doc = chrome_trace(events, process_name=process_name)
    with open(path, "w") as f:
        json.dump(doc, f)
    return sum(1 for e in doc["traceEvents"] if e["ph"] != "M")


def validate_chrome_trace(doc: Any) -> List[str]:
    """Structural validation; returns a list of problems (empty = valid)."""
    problems: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["document is not a dict with a traceEvents list"]
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        return ["traceEvents is not a list"]
    last_ts = None
    for i, e in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            problems.append(f"{where}: not an object")
            continue
        for key in ("name", "ph", "pid", "tid"):
            if key not in e:
                problems.append(f"{where}: missing {key!r}")
        ph = e.get("ph")
        if ph not in ("X", "i", "C", "M"):
            problems.append(f"{where}: unknown ph {ph!r}")
        if ph == "M":
            continue
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"{where}: bad ts {ts!r}")
            continue
        if last_ts is not None and ts < last_ts:
            problems.append(f"{where}: ts {ts} < previous {last_ts} "
                            "(not monotonic)")
        last_ts = ts
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: X event with bad dur {dur!r}")
        if ph == "i" and e.get("s") not in ("t", "p", "g"):
            problems.append(f"{where}: instant with bad scope {e.get('s')!r}")
        if ph == "C":
            args = e.get("args", {})
            if not args or not all(isinstance(v, (int, float))
                                   for v in args.values()):
                problems.append(f"{where}: counter args must be numeric")
    return problems

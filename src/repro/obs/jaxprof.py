"""jax.profiler hooks: line device timelines up with the host trace.

``annotate(name)`` wraps the engines' jitted-closure dispatches.  While a
profiler trace is active it returns ``jax.profiler.TraceAnnotation`` — the
host slice shows up in the device timeline with the same name as the
engine's own ``prefill_call``/``decode_call`` events, so the two traces can
be correlated by eye in Perfetto.  With no active trace it returns a shared
nullcontext: the hot path pays one module-global read, nothing else.

``start(dir)`` / ``stop()`` wrap ``jax.profiler.start_trace``/``stop_trace``
(exposed in launch/serve.py as ``--jax-profile DIR``).
"""
from __future__ import annotations

import contextlib

_active = False
_NULL = contextlib.nullcontext()


def profiling_active() -> bool:
    return _active


def annotate(name: str):
    if not _active:
        return _NULL
    import jax
    return jax.profiler.TraceAnnotation(name)


def start(log_dir: str) -> None:
    global _active
    import jax
    jax.profiler.start_trace(log_dir)
    _active = True


def stop() -> None:
    global _active
    if not _active:
        return
    import jax
    _active = False
    jax.profiler.stop_trace()

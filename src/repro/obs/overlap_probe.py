"""Overlap-efficiency probe: how much decode all-reduce does each schedule hide?

Times the decode collective schedules (core/iso.py) against each other on
IDENTICAL synthetic batches through the paged engine's real jitted decode
closure machinery:

  * ``sequential``  — immediate reduce per stage (the baseline);
  * ``batch_split`` — each batch half's reduce hides behind the other half's
    compute (``run_stack_decode_overlap``; needs B >= 2);
  * ``ladder``      — the ladder-residual driver with deferred collectives
    (``run_stack_decode_ladder``): stage k-1's reduce completes behind stage
    k's compute, across block boundaries, at any B;
  * ``cross_block`` — deferred reduces resolving at the next stage top
    (``run_stack_decode`` schedule="cross_block"): token-identical to
    sequential, a structural window for the XLA latency-hiding scheduler.

and decomposes the step:

    overlap_efficiency        = 1 - t_batch_split / t_sequential
    overlap_efficiency_ladder = 1 - t_ladder / t_sequential
    ladder_speedup            = t_sequential / t_ladder
    hidden_comm               = max(0, t_sequential - t_batch_split)
    exposed_comm              = max(0, t_batch_split - t_compute)

``t_compute`` comes from a closure with collectives DISABLED (``AxisCtx()``
— tp_axis None degrades psum to identity inside the same shard_map), i.e.
the compute-only floor; the gap between the sequential path and that floor
is the step's total communication time.  Without a mesh there is no
collective to hide, the schedules coincide and every efficiency reports ~0
— the probe is still exercised (tests), it just measures nothing.

On a STANDARD-wired engine the ladder number is a proxy: it times the
ladder-REWIRED function (a different model — see configs/ladder.py) at this
engine's exact shapes, which is legitimate for timing because the two twins
are FLOP-identical; ``ladder_proxy=True`` flags it.  On a ladder-wired
engine, "sequential" is the immediate-collective twin of the same ladder
function, so ``ladder_speedup`` is a true schedule speedup and
``batch_split`` is skipped (the ladder driver owns the overlap).

Safety: the probe builds its OWN closures in ``engine._probe_decode_fns``
(never ``_decode_fns`` — the CI compile-guard lane pins that cache's key
set), none of the engine's decode closures donate their buffers, and every
output is discarded after a ``jax.block_until_ready`` fence — engine
KV/state arrays are untouched, so the probe can run before, between or
after real traffic.  Inputs are synthetic: a full batch of fake block
tables pointing at real pool pages with near-full lengths (the memory-bound
regime the paper's decode claim is about).
"""
from __future__ import annotations

import statistics
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.blocks import pattern_all_reduces


def _median_time(call, iters: int, warmup: int) -> float:
    for _ in range(max(1, warmup)):
        jax.block_until_ready(call())
    samples = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        samples.append(time.perf_counter() - t0)
    return statistics.median(samples)


def decode_overlap_probe(engine, iters: int = 10, warmup: int = 3
                         ) -> Dict[str, Any]:
    """Measure the engine's per-schedule decode-step times.

    Returns ``{overlap_efficiency, overlap_efficiency_ladder,
    ladder_speedup, ladder_proxy, schedules, t_sequential_s, t_overlap_s,
    t_ladder_s, t_cross_block_s, t_compute_s, exposed_comm_s,
    hidden_comm_s, comm_total_s, batch, tokens_resident, tp, iters}``.
    ``t_overlap_s`` keeps its historic meaning (the batch-split time; 0.0
    when B < 2).  ``t_compute_s``/``exposed_comm_s`` are None when the
    collectives-disabled variant cannot run (exotic shard_map spec
    mismatch)."""
    B = engine.max_batch
    ps, MB = engine.ps, engine.max_blocks
    ladder_wired = engine.cfg.residual_wiring == "ladder"
    result: Dict[str, Any] = {
        "overlap_efficiency": 0.0, "overlap_efficiency_ladder": 0.0,
        "ladder_speedup": 0.0, "ladder_proxy": not ladder_wired,
        "schedules": {}, "t_sequential_s": 0.0, "t_overlap_s": 0.0,
        "t_ladder_s": 0.0, "t_cross_block_s": 0.0,
        "t_compute_s": None, "exposed_comm_s": None, "hidden_comm_s": 0.0,
        "comm_total_s": None, "batch": B, "tokens_resident": 0,
        "tp": engine.tp, "iters": iters,
    }

    # synthetic resident state: every slot holds as many pages as an even
    # pool split allows, lengths one short of capacity (the +1 decode token
    # lands in the last page — no allocator involvement, tables are fake)
    blocks_per_row = max(1, min(MB, engine.alloc.num_pages // B))
    L = blocks_per_row * ps - 1
    result["tokens_resident"] = L * B
    bt = np.full((B, MB), -1, np.int32)
    for b in range(B):
        bt[b, :blocks_per_row] = np.arange(
            b * blocks_per_row, (b + 1) * blocks_per_row, dtype=np.int32)
    toks = jnp.zeros((B, 1), jnp.int32)
    bt_j = jnp.asarray(bt)
    lens = jnp.full((B,), L, jnp.int32)
    mask = jnp.ones((B,), bool)

    def run(fn):
        def call():
            out = fn(engine.params, toks, bt_j, lens, engine.kv.arrays,
                     engine.states, mask)
            return out[0]                 # fence on logits; rest discarded
        with engine._mesh_ctx():
            return _median_time(call, iters, warmup)

    # schedule sweep: on a ladder-wired engine "sequential"/"ladder" resolve
    # (via models/decoder.decode_step) to the immediate/deferred twins of
    # the ladder function, and batch_split is skipped — the ladder driver
    # owns the overlap; cross_block only applies to the standard wiring
    names = ["sequential", "ladder"] if ladder_wired else \
        ["sequential", "batch_split", "ladder", "cross_block"]
    if B < 2 and "batch_split" in names:
        names.remove("batch_split")       # batch-split needs two halves
    if not pattern_all_reduces(engine.cfg.block_pattern):
        names.remove("ladder")            # ladder needs all-reducing stages
    for name in names:
        result["schedules"][name] = run(engine._get_probe_decode(name))
    t_seq = result["t_sequential_s"] = result["schedules"]["sequential"]
    t_ovl = result["t_overlap_s"] = result["schedules"].get("batch_split",
                                                            0.0)
    t_lad = result["t_ladder_s"] = result["schedules"].get("ladder", 0.0)
    result["t_cross_block_s"] = result["schedules"].get("cross_block", 0.0)
    if t_seq > 0 and t_ovl > 0:
        result["overlap_efficiency"] = 1.0 - t_ovl / t_seq
    if t_seq > 0 and t_lad > 0:
        result["overlap_efficiency_ladder"] = 1.0 - t_lad / t_seq
        result["ladder_speedup"] = t_seq / t_lad
    result["hidden_comm_s"] = max(0.0, t_seq - t_ovl) if t_ovl > 0 else 0.0
    try:
        t_cmp = run(engine._get_probe_decode("sequential", comm=False))
        result["t_compute_s"] = t_cmp
        if t_ovl > 0:
            result["exposed_comm_s"] = max(0.0, t_ovl - t_cmp)
        result["comm_total_s"] = max(0.0, t_seq - t_cmp)
    except Exception:
        # the no-comm variant is best-effort: identity collectives inside a
        # sharded closure can trip spec checks on some JAX versions; the
        # headline efficiency numbers above never depend on it
        pass
    return result

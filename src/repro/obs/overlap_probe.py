"""Overlap-efficiency probe: how much decode all-reduce does ISO hide?

Times the batch-split overlapped decode schedule
(``core/iso.run_stack_decode_overlap``) against the sequential one
(``run_stack_decode``) on IDENTICAL synthetic batches through the paged
engine's real jitted decode closure, and decomposes the step:

    overlap_efficiency = 1 - t_overlap / t_sequential
    hidden_comm        = max(0, t_sequential - t_overlap)
    exposed_comm       = max(0, t_overlap - t_compute)       (per step)

``t_compute`` comes from a third closure with collectives DISABLED
(``AxisCtx()`` — tp_axis None degrades psum to identity inside the same
shard_map), i.e. the compute-only floor; the gap between the sequential path
and that floor is the step's total communication time.  Without a mesh there
is no collective to hide, all three paths coincide and efficiency reports
~0 — the probe is still exercised (tests), it just measures nothing.

Safety: the probe builds its OWN closures in ``engine._probe_decode_fns``
(never ``_decode_fns`` — the CI compile-guard lane pins that cache's key
set), none of the engine's decode closures donate their buffers, and every
output is discarded after a ``jax.block_until_ready`` fence — engine KV/state
arrays are untouched, so the probe can run before, between or after real
traffic.  Inputs are synthetic: a full batch of fake block tables pointing at
real pool pages with near-full lengths (the memory-bound regime the paper's
decode claim is about).
"""
from __future__ import annotations

import statistics
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def _median_time(call, iters: int, warmup: int) -> float:
    for _ in range(max(1, warmup)):
        jax.block_until_ready(call())
    samples = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        samples.append(time.perf_counter() - t0)
    return statistics.median(samples)


def decode_overlap_probe(engine, iters: int = 10, warmup: int = 3
                         ) -> Dict[str, Any]:
    """Measure the engine's decode-step overlap efficiency.

    Returns ``{overlap_efficiency, t_sequential_s, t_overlap_s, t_compute_s,
    exposed_comm_s, hidden_comm_s, comm_total_s, batch, tokens_resident,
    tp, iters}``.  ``t_compute_s``/``exposed_comm_s`` are None when the
    collectives-disabled variant cannot run (exotic shard_map spec mismatch).
    """
    B = engine.max_batch
    ps, MB = engine.ps, engine.max_blocks
    result: Dict[str, Any] = {
        "overlap_efficiency": 0.0, "t_sequential_s": 0.0, "t_overlap_s": 0.0,
        "t_compute_s": None, "exposed_comm_s": None, "hidden_comm_s": 0.0,
        "comm_total_s": None, "batch": B, "tokens_resident": 0,
        "tp": engine.tp, "iters": iters,
    }
    if B < 2:
        return result                     # batch-split needs two halves

    # synthetic resident state: every slot holds as many pages as an even
    # pool split allows, lengths one short of capacity (the +1 decode token
    # lands in the last page — no allocator involvement, tables are fake)
    blocks_per_row = max(1, min(MB, engine.alloc.num_pages // B))
    L = blocks_per_row * ps - 1
    result["tokens_resident"] = L * B
    bt = np.full((B, MB), -1, np.int32)
    for b in range(B):
        bt[b, :blocks_per_row] = np.arange(
            b * blocks_per_row, (b + 1) * blocks_per_row, dtype=np.int32)
    toks = jnp.zeros((B, 1), jnp.int32)
    bt_j = jnp.asarray(bt)
    lens = jnp.full((B,), L, jnp.int32)
    mask = jnp.ones((B,), bool)

    def run(fn):
        def call():
            out = fn(engine.params, toks, bt_j, lens, engine.kv.arrays,
                     engine.states, mask)
            return out[0]                 # fence on logits; rest discarded
        with engine._mesh_ctx():
            return _median_time(call, iters, warmup)

    t_seq = run(engine._get_probe_decode(overlap=False))
    t_ovl = run(engine._get_probe_decode(overlap=True))
    result["t_sequential_s"] = t_seq
    result["t_overlap_s"] = t_ovl
    if t_seq > 0:
        result["overlap_efficiency"] = 1.0 - t_ovl / t_seq
    result["hidden_comm_s"] = max(0.0, t_seq - t_ovl)
    try:
        t_cmp = run(engine._get_probe_decode(overlap=False, comm=False))
        result["t_compute_s"] = t_cmp
        result["exposed_comm_s"] = max(0.0, t_ovl - t_cmp)
        result["comm_total_s"] = max(0.0, t_seq - t_cmp)
    except Exception:
        # the no-comm variant is best-effort: identity collectives inside a
        # sharded closure can trip spec checks on some JAX versions; the
        # headline efficiency number above never depends on it
        pass
    return result

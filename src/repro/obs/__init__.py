"""Serving-stack observability: typed metrics, structured traces, profiling.

The measurement spine of the serving stack (docs/observability.md):

  * ``registry``  — typed counters/gauges/histograms behind a dict-compatible
    ``MetricsView`` so engine code and tests keep their ``metrics["key"]``
    idiom while percentiles/peaks come from real distributions;
  * ``trace``     — a bounded ring of structured ``TraceEvent``s emitted by
    the scheduler (grant/pack/defer), allocator (alloc/free/cow/adopt) and
    engine phase loops (prefill/decode calls, spec verify, preemption);
  * ``export``    — Chrome-trace/Perfetto JSON from the ring (plus schema
    validation used by the CI trace-schema lane);
  * ``replay``    — recompute counters from a trace stream; the conservation
    oracle (trace must reproduce the registry) tests pin;
  * ``jaxprof``   — ``jax.profiler`` TraceAnnotation/start_trace hooks so
    device timelines line up with host events;
  * ``overlap_probe`` — measures how much decode all-reduce the batch-split
    ISO schedule actually hides: ``overlap_efficiency = 1 - t_ovl/t_seq``.
"""
from repro.obs.registry import (ACCEPT_LEN_BUCKETS, GRANT_SIZE_BUCKETS,
                                TPOT_BUCKETS_S, TTFT_BUCKETS_S, Counter, Gauge,
                                Histogram, MetricsRegistry, MetricsView)
from repro.obs.trace import TraceEvent, TraceRing
from repro.obs.export import (chrome_trace, validate_chrome_trace,
                              write_chrome_trace)
from repro.obs.replay import replay_counters

__all__ = [
    "ACCEPT_LEN_BUCKETS", "GRANT_SIZE_BUCKETS", "TPOT_BUCKETS_S",
    "TTFT_BUCKETS_S", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "MetricsView", "TraceEvent", "TraceRing", "chrome_trace",
    "validate_chrome_trace", "write_chrome_trace", "replay_counters",
]

"""Structured trace events: a bounded ring the serving stack narrates into.

Every scheduler decision, allocator mutation and engine phase call appends one
``TraceEvent`` — cheap enough to leave on in production (one dataclass per
event; the ring drops the oldest events past ``capacity`` and counts what it
dropped, so memory is bounded no matter how long the engine runs).

Event vocabulary (payload keys in parentheses; -1 rid/slot = not applicable):

  scheduler   ``grant``   (start, n, padded, last)      one per prefill grant
              ``pack``    (rows, padded)                one per multi-row pack
              ``defer``   ()                            packmate-sharing defer
  allocator   ``alloc``   (n, free, used)               pages from free list
              ``free``    (n, free, used)               pages released
              ``rc_drop`` (n)                           sharer refcount drops
                                                        (no physical release)
              ``cow``     (old, new)                    copy-on-write copy
              ``adopt``   (n_pages, tokens)             prefix-share adoption
  engine      ``admit``   ()                            request -> slot
              ``grant_commit`` (start, n, last)        grant actually ran
              ``prefill_call`` (tokens, pad, rows, calls...)  span, dur > 0
              ``decode_call``  (k, active)              span, dur > 0
              ``sample``  (first, ttft?)                prefill-final sample
              ``accept``  (n, spec)                     tokens committed/slot
              ``spec_rollback`` (n)                     positions invalidated
              ``evict``   ()                            preemption victim
              ``finish``  ()                            request completed
              ``pool``    (used, free, frag)            per-step occupancy
  disagg      ``detach``  ()                            request exported out
              ``attach``  ()                            request imported in
              ``migrate`` (n, rids, us)                 one per PageTransfer,
                                                        n = distinct pages
  cost model  ``decision`` (point, chosen, static, ...) model-driven choice
              ``warning``  (what, reason, path)         degradation notice

``decision`` records every choice the measured cost model
(perf/costmodel.py) made instead of a static default — ``point`` is one of
``kv_splits``/``grant_cap``/``pack_rows``/``spec_gate``, ``chosen`` the
model's answer, ``static`` what the constant would have done, plus the
decision's inputs (depth, k, padded, expected_accept).  ``warning`` is
emitted exactly once per failed cost-table load (missing / malformed /
platform-mismatch) before falling back to static defaults.  Both are
bookkeeping-neutral: ``replay.replay_counters`` ignores kinds outside its
counter vocabulary, and the Chrome-trace exporter renders any unknown kind
as an instant.

``replay.replay_counters`` reconstructs the engine's counters from exactly
this vocabulary — the conservation tests pin that the narration is complete.
Timestamps are ``time.perf_counter()`` seconds (monotonic); spans carry their
START time plus ``dur`` so the Chrome-trace exporter can emit real slices.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


@dataclass
class TraceEvent:
    ts: float                          # perf_counter seconds (monotonic)
    kind: str
    rid: int = -1                      # request id, -1 when not applicable
    slot: int = -1                     # engine slot, -1 when not applicable
    dur: float = 0.0                   # span duration (0 = instant)
    payload: Dict[str, Any] = field(default_factory=dict)


class TraceRing:
    """Bounded event buffer.  ``enabled=False`` turns ``emit`` into a no-op
    (the obs-off configuration the overhead benchmark compares against)."""

    def __init__(self, capacity: int = 65536, enabled: bool = True):
        assert capacity > 0
        self.capacity = capacity
        self.enabled = enabled
        self.dropped = 0
        self._buf: deque = deque(maxlen=capacity)

    def emit(self, kind: str, rid: int = -1, slot: int = -1, dur: float = 0.0,
             ts: Optional[float] = None, **payload) -> None:
        if not self.enabled:
            return
        if len(self._buf) == self.capacity:
            self.dropped += 1
        self._buf.append(TraceEvent(
            ts=time.perf_counter() if ts is None else ts,
            kind=kind, rid=rid, slot=slot, dur=dur, payload=payload))

    def events(self) -> List[TraceEvent]:
        """Insertion order (oldest surviving event first)."""
        return list(self._buf)

    def clear(self) -> None:
        self._buf.clear()
        self.dropped = 0

    def __len__(self) -> int:
        return len(self._buf)

"""Replay a TraceEvent stream back into counters.

The conservation oracle: if the trace narration is complete, replaying it must
reproduce the registry's final counter values exactly (decode_tokens, grants,
preemptions, completions, ...) and page conservation must hold
(``pages_allocated - pages_freed == used_pages``).  tests/test_obs.py and the
CI trace-schema lane pin both.  Only works on an un-wrapped ring (no drops) —
``TraceRing.dropped == 0``.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, Sequence

from repro.obs.trace import TraceEvent

# counter names replay can reconstruct; keys match the engine registries
REPLAYABLE = (
    "prefill_grants", "resumed_grants", "prefill_calls", "prefill_tokens",
    "decode_calls", "spec_calls", "decode_tokens", "spec_tokens",
    "prefill_samples", "ttft_n", "preemptions", "completed", "cow_copies",
    "prefix_shared_tokens", "migrations", "migrated_pages",
)


def replay_counters(events: Sequence[TraceEvent]) -> Dict[str, int]:
    """Counter values implied by the event stream.  Also returns the
    allocator-conservation pair ``pages_allocated``/``pages_freed``."""
    c: Dict[str, int] = defaultdict(int)
    for name in REPLAYABLE:
        c[name] = 0
    c["pages_allocated"] = 0
    c["pages_freed"] = 0
    for ev in events:
        k, p = ev.kind, ev.payload
        if k == "grant_commit":
            # scheduler "grant" issues are narration only: a grant can be
            # dropped (packmate eviction) and re-issued; commits are exact
            c["prefill_grants"] += 1
            if p.get("start", 0) > 0:
                c["resumed_grants"] += 1
        elif k == "prefill_call":
            c["prefill_calls"] += 1
            c["prefill_tokens"] += p.get("tokens", 0)
        elif k == "decode_call":
            c["decode_calls"] += 1
            if p.get("k", 1) > 1:
                c["spec_calls"] += 1
        elif k == "accept":
            n = p.get("n", 0)
            c["decode_tokens"] += n
            if p.get("spec"):
                c["spec_tokens"] += n
        elif k == "sample":
            c["prefill_samples"] += 1
            if p.get("first"):
                c["ttft_n"] += 1
        elif k == "evict":
            c["preemptions"] += 1
        elif k == "finish":
            c["completed"] += 1
        elif k == "cow":
            c["cow_copies"] += 1
        elif k == "adopt":
            c["prefix_shared_tokens"] += p.get("tokens", 0)
        elif k == "alloc":
            c["pages_allocated"] += p.get("n", 0)
        elif k == "free":
            c["pages_freed"] += p.get("n", 0)
        elif k == "migrate":
            # one span per PageTransfer on the DETACHING engine; n = distinct
            # pages moved.  The per-rid detach/attach instants and the
            # refcount-drop narration (rc_drop) are bookkeeping-neutral.
            c["migrations"] += 1
            c["migrated_pages"] += p.get("n", 0)
    return dict(c)

"""AdamW + global-norm clipping + warmup-cosine schedule, pure JAX pytree ops.

Runs on LOCAL shards inside shard_map: updates are elementwise, and the global
grad-norm is assembled with explicit psums (model axis for sharded leaves), so the
clip threshold is identical on every device.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                      nu=zeros(params))


def warmup_cosine(step, base_lr: float, warmup: int, total: int,
                  min_frac: float = 0.1):
    s = step.astype(jnp.float32)
    warm = s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(math.pi * prog))
    return base_lr * jnp.where(s < warmup, warm, cos)


def global_norm_sq_local(grads) -> jnp.ndarray:
    return sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
               for g in jax.tree_util.tree_leaves(grads))


def adamw_update(params, grads, state: AdamWState, *, lr, weight_decay: float,
                 grad_clip: float, global_norm_sq=None,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8
                 ) -> Tuple[Any, AdamWState]:
    """One AdamW step.  ``global_norm_sq``: pre-reduced squared grad norm (the
    caller psums the local contribution across the mesh); defaults to local."""
    if global_norm_sq is None:
        global_norm_sq = global_norm_sq_local(grads)
    gnorm = jnp.sqrt(global_norm_sq)
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if grad_clip else 1.0
    step = state.step + 1
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * jnp.square(gf)
        mhat = m2 / c1
        vhat = v2 / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay and p.ndim >= 2:          # decay matrices only
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, tree = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tree, [o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)

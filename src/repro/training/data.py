"""Synthetic LM data pipeline.

Deterministic, seekable token streams so multi-host data parallelism can shard by
``(host_index, step)`` without coordination, plus a document-packing simulation
(random-length docs separated by EOS, packed to fixed windows — what a real prefill
workload looks like).  For the audio/vlm families the stub frontends are random
embeddings with matching token targets.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np

from repro.config import ModelConfig


@dataclass
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    eos_id: int = 1
    mean_doc_len: int = 512


class SyntheticLM:
    """Markov-ish synthetic token stream: next token depends on the previous one
    through a fixed random permutation + noise, so models can actually reduce loss
    (pure-uniform data gives nothing to learn)."""

    def __init__(self, dc: DataConfig):
        self.dc = dc
        rng = np.random.default_rng(dc.seed)
        self.perm = rng.permutation(dc.vocab_size)

    def batch(self, step: int, host: int = 0, num_hosts: int = 1
              ) -> Dict[str, np.ndarray]:
        dc = self.dc
        b_loc = dc.global_batch // num_hosts
        rng = np.random.default_rng(
            (dc.seed * 1_000_003 + step) * 65_537 + host)
        toks = np.empty((b_loc, dc.seq_len + 1), np.int32)
        for i in range(b_loc):
            toks[i] = self._pack_docs(rng, dc.seq_len + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def _pack_docs(self, rng, n: int) -> np.ndarray:
        out = np.empty(n, np.int32)
        filled = 0
        while filled < n:
            dlen = max(2, int(rng.exponential(self.dc.mean_doc_len)))
            dlen = min(dlen, n - filled)
            doc = np.empty(dlen, np.int32)
            doc[0] = rng.integers(2, self.dc.vocab_size)
            noise = rng.random(dlen) < 0.15
            rand = rng.integers(2, self.dc.vocab_size, dlen)
            for t in range(1, dlen):
                doc[t] = rand[t] if noise[t] else self.perm[doc[t - 1]]
            if dlen >= 2:
                doc[-1] = self.dc.eos_id
            out[filled:filled + dlen] = doc
            filled += dlen
        return out

    def iterator(self, start_step: int = 0, host: int = 0, num_hosts: int = 1
                 ) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step, host, num_hosts)
            step += 1


def make_training_batch(cfg: ModelConfig, seq_len: int, global_batch: int,
                        step: int, seed: int = 0) -> Dict[str, np.ndarray]:
    """Family-aware batch: adds stub frontend embeddings where needed."""
    dc = DataConfig(seq_len=seq_len, global_batch=global_batch,
                    vocab_size=cfg.vocab_size, seed=seed)
    base = SyntheticLM(dc).batch(step)
    rng = np.random.default_rng(seed * 7919 + step)
    if cfg.family == "audio":
        base["frames"] = (rng.standard_normal(
            (global_batch, cfg.encoder_frames, cfg.d_model)) * 0.1
        ).astype(np.float32)
    if cfg.family == "vlm":
        n_p = min(cfg.num_patches, max(1, seq_len // 2))
        base["patches"] = (rng.standard_normal(
            (global_batch, n_p, cfg.d_model)) * 0.1).astype(np.float32)
        base["tokens"] = base["tokens"][:, :seq_len - n_p]
        base["labels"] = base["labels"][:, :seq_len - n_p]
    return base

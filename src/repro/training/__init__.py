from repro.training.optimizer import adamw_init, adamw_update  # noqa: F401
from repro.training.loss import sharded_xent  # noqa: F401

"""Distributed train step: jit(shard_map(local_step)) with manual collectives.

Parallelism layout (DESIGN.md §5):
  * batch over ("pod","data")   — gradients pmean'd over those axes;
  * Megatron TP over "model"    — sharded-leaf grads are already complete per
    shard; replicated-leaf grads (norms, routers, replicated gate weights) are
    psum'd over "model" (each shard saw a different partial path);
  * the forward pass is the SAME stack the serving path uses, so the paper's ISO
    schedule is available at training time too (off by default — the paper targets
    inference; flip ``RuntimeConfig`` to measure it).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.config import Config, ISOConfig
from repro.core.overlap import AxisCtx
from repro.models import api
from repro.models.decoder import decoder_param_specs
from repro.training.loss import sharded_xent
from repro.training.optimizer import (AdamWState, adamw_init, adamw_update,
                                      warmup_cosine)


def make_axis_ctx(config: Config) -> AxisCtx:
    p = config.parallel
    return AxisCtx(tp_axis="model", tp=p.model, dp_axes=p.batch_axes,
                   quantized_comm=config.iso.quantized_comm)


def batch_specs(cfg_model, batch_axes) -> Dict[str, P]:
    specs = {"tokens": P(batch_axes, None), "labels": P(batch_axes, None)}
    if cfg_model.family == "audio":
        specs["frames"] = P(batch_axes, None, None)
    if cfg_model.family == "vlm":
        specs["patches"] = P(batch_axes, None, None)
    return specs


def spec_has(spec: P, axis: str) -> bool:
    for e in spec:
        if e == axis or (isinstance(e, (tuple, list)) and axis in e):
            return True
    return False


_IS_SPEC = lambda x: isinstance(x, P)


def _grad_reduce(grads, param_specs, ctx: AxisCtx, dp_sizes=(),
                 int8: bool = False):
    """pmean over data axes everywhere; psum over model for replicated leaves
    (every TP shard saw a different partial path through them).  ``int8``
    compresses the data-parallel wire traffic (quantized_collectives) — the
    collective-term lever for trillion-parameter configs (EXPERIMENTS §Perf)."""
    from repro.core.quantized_collectives import quantized_pmean

    def red(spec, g):
        if ctx.dp_axes:
            if int8 and g.size >= 1 << 16:   # small leaves aren't worth it
                g = quantized_pmean(g, ctx.dp_axes, dp_sizes)
            else:
                g = jax.lax.pmean(g, ctx.dp_axes)
        if ctx.tp_axis and not spec_has(spec, ctx.tp_axis):
            g = jax.lax.psum(g, ctx.tp_axis)
        return g
    return jax.tree_util.tree_map(red, param_specs, grads, is_leaf=_IS_SPEC)


def _norm_sq(grads, param_specs, ctx: AxisCtx):
    sharded, repl = 0.0, 0.0
    specs = jax.tree_util.tree_leaves(param_specs, is_leaf=_IS_SPEC)
    for spec, g in zip(specs, jax.tree_util.tree_leaves(grads)):
        s = jnp.sum(jnp.square(g.astype(jnp.float32)))
        if ctx.tp_axis and spec_has(spec, ctx.tp_axis):
            sharded = sharded + s
        else:
            repl = repl + s
    if ctx.tp_axis:
        sharded = jax.lax.psum(sharded, ctx.tp_axis)
    return sharded + repl


def make_train_step(config: Config, mesh, params_shape):
    cfg = config.model
    rt = config.runtime
    ctx = make_axis_ctx(config)
    iso_train = config.iso if rt.mode == "train_iso" else \
        dataclasses.replace(config.iso, enabled=False)
    p_specs = decoder_param_specs(params_shape)
    b_specs = batch_specs(cfg, config.parallel.batch_axes)
    opt_specs = AdamWState(step=P(), mu=p_specs, nu=p_specs)

    def loss_fn(params, batch):
        out = api.prefill(params, cfg, ctx, iso_train, batch,
                          logits_mode="all", remat=rt.remat,
                          unroll=rt.unroll_layers)
        logits = out["logits_local"]
        if cfg.family == "vlm":
            n_p = batch["patches"].shape[1]
            logits = logits[:, n_p:, :]
        loss = sharded_xent(logits, batch["labels"], ctx)
        loss = loss + 0.01 * out["moe_aux"]
        return loss

    p = config.parallel
    dp_sizes = (p.pods, p.data) if p.pods > 1 else (p.data,)
    dp = p.pods * p.data

    if rt.zero1:
        from repro.training.zero import zero1_update_local, zero_state_specs
        opt_specs = zero_state_specs(p_specs, p.batch_axes)

        def local_step(params, opt_state, batch, step):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            if ctx.dp_axes:
                loss = jax.lax.pmean(loss, ctx.dp_axes)
            # model-axis reduction only; ZeRO's psum_scatter reduces over data
            grads = jax.tree_util.tree_map(
                lambda spec, g: jax.lax.psum(g, ctx.tp_axis)
                if ctx.tp_axis and not spec_has(spec, ctx.tp_axis) else g,
                p_specs, grads, is_leaf=_IS_SPEC)
            lr = warmup_cosine(step, rt.learning_rate, rt.warmup_steps,
                               rt.max_steps)
            new_params, new_opt, gnorm = zero1_update_local(
                params, grads, opt_state, p_specs, tp_axis=ctx.tp_axis,
                dp_axes=ctx.dp_axes, dp=dp, lr=lr,
                weight_decay=rt.weight_decay, grad_clip=rt.grad_clip)
            return new_params, new_opt, loss, gnorm
    else:
        def local_step(params, opt_state, batch, step):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            if ctx.dp_axes:
                loss = jax.lax.pmean(loss, ctx.dp_axes)
            grads = _grad_reduce(grads, p_specs, ctx, dp_sizes=dp_sizes,
                                 int8=rt.grad_comm_int8)
            nsq = _norm_sq(grads, p_specs, ctx)
            lr = warmup_cosine(step, rt.learning_rate, rt.warmup_steps,
                               rt.max_steps)
            new_params, new_opt = adamw_update(
                params, grads, opt_state, lr=lr, weight_decay=rt.weight_decay,
                grad_clip=rt.grad_clip, global_norm_sq=nsq)
            return new_params, new_opt, loss, jnp.sqrt(nsq)

    sm = compat.shard_map(
        local_step, mesh=mesh,
        in_specs=(p_specs, opt_specs, b_specs, P()),
        out_specs=(p_specs, opt_specs, P(), P()),
        check_vma=False)
    return jax.jit(sm, donate_argnums=(0, 1)), p_specs, opt_specs, b_specs


def init_train_state(config: Config, mesh, key, dtype=jnp.bfloat16):
    """Initialise params + optimizer state directly with their final shardings."""
    cfg = config.model
    p = config.parallel
    tp = p.model

    def init_params_only():
        return api.init_params(key, cfg, tp, dtype)

    p_shapes = jax.eval_shape(init_params_only)
    p_specs = decoder_param_specs(p_shapes)
    p_shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), p_specs, is_leaf=_IS_SPEC)

    if config.runtime.zero1:
        from repro.training.zero import zero1_init_local, zero_state_specs
        o_specs = zero_state_specs(p_specs, p.batch_axes)
        dp = p.pods * p.data
        o_shardings = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), o_specs, is_leaf=_IS_SPEC)
        with mesh:
            params = jax.jit(init_params_only, out_shardings=p_shardings)()
            opt_init = compat.shard_map(
                lambda pr: zero1_init_local(pr, dp), mesh=mesh,
                in_specs=(p_specs,), out_specs=o_specs, check_vma=False)
            opt = jax.jit(opt_init, out_shardings=o_shardings)(params)
        return params, opt

    def init():
        params = init_params_only()
        return params, adamw_init(params)

    o_specs = AdamWState(step=P(), mu=p_specs, nu=p_specs)
    out_shardings = (
        p_shardings,
        jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), o_specs,
                               is_leaf=_IS_SPEC),
    )
    with mesh:
        return jax.jit(init, out_shardings=out_shardings)()

"""Sharded checkpoint save/restore without external deps.

Each host writes its addressable shards to ``<dir>/shard_<k>.npz`` plus a JSON
manifest of the pytree structure; restore rebuilds global arrays via
``jax.make_array_from_single_device_arrays``.  Single-process (this container)
degenerates to one shard file, but the format is multi-host correct.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save(path: str, tree, step: int = 0) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    arrays = {}
    meta = {"step": step, "leaves": {}}
    for name, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        arrays[name] = arr
        meta["leaves"][name] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    np.savez(os.path.join(path, f"shard_{jax.process_index()}.npz"),
             **{k: v for k, v in arrays.items()})
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(meta, f)


def restore(path: str, tree_like):
    """Restore into the structure (and dtypes) of ``tree_like``."""
    with open(os.path.join(path, "manifest.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, f"shard_{jax.process_index()}.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for pathk, leaf in flat:
        name = jax.tree_util.keystr(pathk)
        arr = data[name]
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), meta["step"]

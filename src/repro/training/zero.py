"""ZeRO-1: shard AdamW optimizer state over the data axis.

The kimi-k2 dry-run showed the honest blocker for trillion-param training on
v5e: fp32 mu/nu replicated across the data axis cost ~31 GiB/device (>16 GiB
HBM).  ZeRO-1 keeps ONE slice of (mu, nu) per data shard:

    grad  --psum_scatter(data)-->  my grad slice         (wire: (n-1)/n · B)
    AdamW on the slice (elementwise)
    param --all_gather(data)-->    replicated new param  (wire: (n-1)/n · B)

Total wire equals the plain pmean all-reduce (2·(n-1)/n · B) — roofline-neutral
— while optimizer memory divides by the data-parallel degree.

Layout: every param leaf is handled in a FLATTENED local view (the leaf a model
shard holds), padded to the dp degree; the optimizer state leaves are
(N_local_pad / dp,) fp32 vectors whose GLOBAL arrays carry spec
P(("model-if-sharded...", ) ...) — see ``zero_state_specs``.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat


class Zero1State(NamedTuple):
    step: jnp.ndarray
    mu: Any            # per-leaf (N_local_pad/dp,) fp32 shards
    nu: Any


def _pad_len(n: int, dp: int) -> int:
    return int(math.ceil(n / dp) * dp)


def zero1_init_local(params_local, dp: int) -> Zero1State:
    """Init from LOCAL param shards (inside shard_map) — each device keeps its
    1/dp slice of the flattened leaf."""
    def z(x):
        return jnp.zeros((_pad_len(x.size, dp) // dp,), jnp.float32)
    zeros = jax.tree_util.tree_map(z, params_local)
    return Zero1State(step=jnp.zeros((), jnp.int32), mu=zeros, nu=zeros)


def zero_state_specs(param_specs, dp_axes) -> Zero1State:
    """Global PartitionSpecs for the state: each leaf is globally
    (model_shards_if_any..., dp, N/dp) flattened to 1-D per (model, data)
    coordinate; we materialise it as a 1-D array sharded over BOTH the model
    axes of its param (via the leading reshape trick being unnecessary — the
    state array's single dim is sharded over (model?, data)).
    """
    from repro.training.trainer import spec_has, _IS_SPEC

    def spec(pspec):
        axes = []
        for e in pspec:
            if e is None:
                continue
            if isinstance(e, (tuple, list)):
                axes.extend(e)
            else:
                axes.append(e)
        shard_over = tuple(a for a in ("model",) if a in axes) + tuple(dp_axes)
        return P(shard_over)

    leaf_specs = jax.tree_util.tree_map(spec, param_specs, is_leaf=_IS_SPEC)
    return Zero1State(step=P(), mu=leaf_specs, nu=leaf_specs)


def zero1_update_local(params_local, grads_local, state: Zero1State,
                       param_specs, *, tp_axis, dp_axes: Tuple[str, ...],
                       dp: int, lr, weight_decay: float, grad_clip: float,
                       b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8):
    """One ZeRO-1 AdamW step on LOCAL shards (inside shard_map).

    ``grads_local``: grads already psum'd over the MODEL axis for replicated
    leaves but NOT reduced over data — the psum_scatter here performs the data
    reduction directly into each device's slice.  Global-norm clipping is
    computed on the reduced SLICES (slices partition the full gradient, so
    psum of slice norms over (data [+ model for sharded leaves]) is exact).
    """
    from repro.training.trainer import spec_has, _IS_SPEC

    flat_p, tree = jax.tree_util.tree_flatten(params_local)
    flat_g = jax.tree_util.tree_leaves(grads_local)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    flat_spec = jax.tree_util.tree_leaves(param_specs, is_leaf=_IS_SPEC)

    # pass 1: scatter-reduce every leaf into my slice; accumulate norms
    slices, nsq_sharded, nsq_repl = [], 0.0, 0.0
    for p, g, spec in zip(flat_p, flat_g, flat_spec):
        n_pad = _pad_len(p.size, dp)
        gf = g.astype(jnp.float32).reshape(-1)
        if n_pad != p.size:
            gf = jnp.pad(gf, (0, n_pad - p.size))
        g_slice = jax.lax.psum_scatter(gf, dp_axes, scatter_dimension=0,
                                       tiled=True) / dp
        slices.append(g_slice)
        s = jnp.sum(jnp.square(g_slice))
        if tp_axis and spec_has(spec, tp_axis):
            nsq_sharded = nsq_sharded + s
        else:
            nsq_repl = nsq_repl + s
    nsq = jax.lax.psum(nsq_sharded, (tp_axis, *dp_axes)) if tp_axis else 0.0
    nsq = nsq + jax.lax.psum(nsq_repl, dp_axes)
    gnorm = jnp.sqrt(nsq)
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if grad_clip else jnp.float32(1.0)

    step = state.step + 1
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    # pass 2: AdamW on the slice, all_gather the fresh params
    new_p, new_m, new_v = [], [], []
    for p, g_slice, m, v in zip(flat_p, slices, flat_m, flat_v):
        n, n_pad = p.size, _pad_len(p.size, dp)
        g_slice = g_slice * scale
        m2 = b1 * m + (1 - b1) * g_slice
        v2 = b2 * v + (1 - b2) * jnp.square(g_slice)
        delta = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
        pf = p.astype(jnp.float32).reshape(-1)
        if n_pad != n:
            pf = jnp.pad(pf, (0, n_pad - n))
        p_slice = jax.lax.dynamic_slice_in_dim(
            pf, _my_offset(dp_axes, n_pad // dp), n_pad // dp)
        if weight_decay and p.ndim >= 2:
            delta = delta + weight_decay * p_slice
        p_new_slice = p_slice - lr * delta
        p_full = jax.lax.all_gather(p_new_slice, dp_axes, axis=0, tiled=True)
        new_p.append(p_full[:n].reshape(p.shape).astype(p.dtype))
        new_m.append(m2)
        new_v.append(v2)

    unf = lambda leaves: jax.tree_util.tree_unflatten(tree, leaves)
    return unf(new_p), Zero1State(step=step, mu=unf(new_m), nu=unf(new_v)), gnorm


def _my_offset(dp_axes: Tuple[str, ...], slice_len: int):
    idx = 0
    for ax in dp_axes:
        idx = idx * compat.axis_size(ax) + jax.lax.axis_index(ax)
    return idx * slice_len

"""Vocab-sharded cross-entropy (Megatron-style) — local-shard view."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.overlap import AxisCtx


def sharded_xent(logits_local, labels, ctx: AxisCtx, *, mask=None):
    """logits_local: (B,S,V_loc) this shard's vocab slice; labels: (B,S) global ids.

    Returns mean NLL over unmasked tokens (replicated across model shards).
    """
    lf = logits_local.astype(jnp.float32)
    v_loc = lf.shape[-1]
    offset = ctx.axis_index() * v_loc

    # the max shift is gradient-neutral (and pmax has no AD rule), so stop the
    # gradient BEFORE the collective
    local_max = jax.lax.stop_gradient(jnp.max(lf, axis=-1))
    gmax = jax.lax.pmax(local_max, ctx.tp_axis) if ctx.tp_axis else local_max
    se = jnp.sum(jnp.exp(lf - gmax[..., None]), axis=-1)
    gse = jax.lax.psum(se, ctx.tp_axis) if ctx.tp_axis else se
    log_z = gmax + jnp.log(gse)

    local_idx = labels - offset
    ok = (local_idx >= 0) & (local_idx < v_loc)
    cl = jnp.take_along_axis(
        lf, jnp.clip(local_idx, 0, v_loc - 1)[..., None], axis=-1)[..., 0]
    cl = jnp.where(ok, cl, 0.0)
    gcl = jax.lax.psum(cl, ctx.tp_axis) if ctx.tp_axis else cl

    nll = log_z - gcl
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

"""Whisper-style encoder-decoder backbone.

Per the assignment carve-out, the mel-spectrogram + conv frontend is a STUB:
``input_specs`` feeds precomputed frame embeddings (B, encoder_frames, D).  The
encoder runs bidirectional attention with the ISO schedule (chunks are even freer
than causal ones — no KV ordering constraint; see DESIGN.md §4).  The decoder is a
(self-attn, cross-attn, MLP) stack; every one of its three stages ends in a TP
all-reduce, giving ISO a deeper per-layer pipeline than a dense decoder.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ISOConfig, ModelConfig
from repro.core.overlap import AxisCtx
from repro.layers import attention as attn_lib
from repro.layers.heads import head_layout
from repro.layers.rope import sinusoidal_embedding
from repro.models import decoder as dec_lib


def encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(
        cfg, num_layers=cfg.encoder_layers, block_pattern=("attn_mlp",),
        pos_type="sinusoidal")


def decoder_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, block_pattern=("dec_block",),
                               pos_type="sinusoidal")


def init_whisper_params(key, cfg: ModelConfig, tp: int, dtype=jnp.bfloat16) -> Dict:
    k_enc, k_dec = jax.random.split(key)
    enc = dec_lib.init_decoder_params(k_enc, encoder_cfg(cfg), tp, dtype)
    enc.pop("embed")                         # frontend stub provides embeddings
    dec = dec_lib.init_decoder_params(k_dec, decoder_cfg(cfg), tp, dtype)
    return {"encoder": enc, "decoder": dec}


def encode(params, cfg: ModelConfig, ctx: AxisCtx, iso: ISOConfig, frames,
           remat: bool = False):
    """frames: (B, F, D) stub frontend output -> encoder hidden states."""
    ecfg = encoder_cfg(cfg)
    embeds = frames + sinusoidal_embedding(
        frames.shape[1], cfg.d_model).astype(frames.dtype)[None]
    out = dec_lib.prefill(params["encoder"], ecfg, ctx, iso, embeds=embeds,
                          logits_mode="none", mode="encode", remat=remat)
    return out["hidden"]


def _cross_statics(params, cfg: ModelConfig, enc_out):
    """Precompute per-decoder-layer cross K/V, stacked over periods."""
    dcfg = decoder_cfg(cfg)
    stacked = params["decoder"]["periods"][0]["cross"]

    def one(p_cross):
        return attn_lib.cross_kv(p_cross, enc_out, dcfg)

    ks, vs = jax.vmap(one)(stacked)
    return ({"cross_k": ks, "cross_v": vs},)


def whisper_prefill(params, cfg: ModelConfig, ctx: AxisCtx, iso: ISOConfig, *,
                    frames, tokens, logits_mode: str = "all",
                    return_cache: bool = False, cache_len: int = 0,
                    remat: bool = False, unroll: bool = False) -> Dict[str, Any]:
    enc_out = encode(params, cfg, ctx, iso, frames, remat=remat)
    statics = _cross_statics(params, cfg, enc_out)
    dcfg = decoder_cfg(cfg)
    out = dec_lib.prefill(params["decoder"], dcfg, ctx, iso, tokens=tokens,
                          logits_mode=logits_mode, return_cache=return_cache,
                          cache_len=cache_len, remat=remat, unroll=unroll,
                          layer_statics=statics)
    if return_cache:
        caches = list(out["caches"])
        caches[0] = dict(caches[0], **statics[0])
        out["caches"] = tuple(caches)
    out["enc_out"] = enc_out
    return out


def whisper_decode_step(params, cfg: ModelConfig, ctx: AxisCtx, tokens, caches,
                        lengths, unroll: bool = False):
    return dec_lib.decode_step(params["decoder"], decoder_cfg(cfg), ctx, tokens,
                               caches, lengths, unroll=unroll)


def init_whisper_caches(cfg: ModelConfig, batch: int, cache_len: int, tp: int,
                        enc_frames: int = 0, dtype=jnp.bfloat16):
    """Decode caches incl. zero cross-KV placeholders (filled by a real prefill)."""
    dcfg = decoder_cfg(cfg)
    caches = list(dec_lib.init_caches(dcfg, batch, cache_len, tp, dtype))
    layout = head_layout(cfg.num_heads, max(cfg.num_kv_heads, 1), tp)
    hkv = layout.hkv_eff                    # GLOBAL padded kv heads
    hd = cfg.resolved_head_dim
    F = enc_frames or cfg.encoder_frames
    periods = dcfg.num_layers
    caches[0] = dict(
        caches[0],
        cross_k=jnp.zeros((periods, batch, F, hkv, hd), dtype),
        cross_v=jnp.zeros((periods, batch, F, hkv, hd), dtype))
    return tuple(caches)

"""Block stage functions — the composable unit the ISO scheduler drives.

A transformer layer is a list of *stages*; each stage maps a (normed) chunk of the
residual stream to an output that either NEEDS the TP all-reduce (``reduces=True``,
the unreduced-partial convention) or is already complete (sLSTM, whose weights are
replicated).  The scheduler (core/iso.py) owns residual adds and collective timing —
that separation IS the paper's contribution, so blocks never call ``lax.psum``.

Per-stage sequential state (the cross-chunk dependency ISO must respect):
  attn    -> growing (k,v) prefix              (chunked-prefill KV rule, paper §3.1)
  ssm     -> SSMState carry                    (same producer/consumer edge)
  mlstm   -> MLSTMState carry
  slstm   -> SLSTMState carry
  mlp/moe -> none (token-local, freely reorderable)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp

from repro.config import (BLOCK_ATTN_MLP, BLOCK_ATTN_MOE, BLOCK_HYBRID,
                          BLOCK_MLSTM, BLOCK_SLSTM, ModelConfig)
from repro.layers import attention as attn_lib
from repro.layers import mlp as mlp_lib
from repro.layers import moe as moe_lib
from repro.layers import ssm as ssm_lib
from repro.layers import xlstm as xlstm_lib
from repro.layers.norms import norm


@dataclass
class StageCtx:
    cfg: ModelConfig
    group_eff: int                     # local GQA group (q slots per kv slot)
    tp: int
    expert_offset: Any = 0             # traced int for MoE shards
    mode: str = "prefill"              # prefill | decode | encode
    window: int = 0
    lengths: Optional[jnp.ndarray] = None   # decode: (B,) cached token counts
    # resumed chunked prefill (paged engine): absolute position of this call's
    # first token — static int, traced scalar, or per-row (B,) vector (batched
    # multi-request grants); chunk starts stay call-relative
    pos_offset: Any = 0
    # paged decode (flash-decode over block tables): (B, MB) int32 page ids per
    # request, and the (B,) bool mask of slots really decoding this step
    block_tables: Optional[jnp.ndarray] = None
    decode_mask: Optional[jnp.ndarray] = None
    # split-KV (sequence-parallel) flash-decode: partition each request's
    # page walk into this many contiguous spans, folded by the kernel's
    # reduce step (kernels/flash_decode.py).  Static — part of the decode
    # closure's compile key (serving keys closures on (K, S)).
    kv_splits: int = 1
    # grant-size bucketing (paged prefill): number of REAL tokens in this call
    # — traced scalar, or per-row (B,) vector for batched grants whose rows
    # carry different real lengths.  Call-relative positions >= valid_len are
    # pad and must neither be attended as keys nor scatter KV.  None = no
    # padding.
    valid_len: Any = None


def _n1(p, x, cfg):
    return norm(p["norm1"], x, cfg.norm_type, cfg.rms_eps)


def _n2(p, x, cfg):
    return norm(p["norm2"], x, cfg.norm_type, cfg.rms_eps)


# --------------------------------------------------------------------------
# stages; each returns (out, new_seq_state, extras)
# --------------------------------------------------------------------------

def _resume_prefix(seq_state, cache, sctx: StageCtx, start_pos, B):
    """Effective attention prefix for a (possibly resumed) prefill chunk.

    ``seq_state``: (k, v) accumulated across chunks WITHIN this call (positions
    ``pos_offset .. pos_offset+start_pos``, contiguous).  ``cache``: optional
    persistent prefix from earlier engine steps (paged gather: padded slots,
    ``pos`` -1 = empty).  Returns (prefix_kv, prefix_pos) for
    ``attn_prefill_partial``; prefix_pos is None when the prefix is dense from 0.
    """
    if cache is None or "k" not in cache:
        if seq_state is not None and not _static_zero(sctx.pos_offset):
            return seq_state, attn_lib.row_positions(sctx.pos_offset, B,
                                                     start_pos)
        return seq_state, None
    ck, cv, cpos = cache["k"], cache["v"], cache["pos"]
    if seq_state is None:
        return (ck, cv), cpos
    sk, sv = seq_state
    intra = attn_lib.row_positions(sctx.pos_offset, B, start_pos)
    return ((jnp.concatenate([ck, sk], axis=1),
             jnp.concatenate([cv, sv], axis=1)),
            jnp.concatenate([cpos.astype(jnp.int32), intra], axis=1))


def _static_zero(off) -> bool:
    return isinstance(off, int) and off == 0


def _prefill_attn(p_attn, xn, kv_state, cache, sctx: StageCtx, start_pos, B):
    """One chunk's prefill attention, dispatched on the cache layout.

    A cache exposing ``k_pages``/``v_pages`` means the persistent prefix
    lives in the page pool: the chunk attends it IN PLACE through the paged
    flash-prefill kernel (block tables + prefix lengths ride in via
    ``sctx.block_tables``/``sctx.lengths``), and only the intra-call KV
    (``kv_state``, earlier ISO chunks of this call) is attended densely.
    Otherwise the classic path: dense/gathered prefix via ``_resume_prefix``.
    Returns (partial, kv_new of this chunk)."""
    cfg = sctx.cfg
    k_limit = None
    if sctx.valid_len is not None:
        k_limit = sctx.pos_offset + sctx.valid_len
    if cache is not None and "k_pages" in cache:
        intra_pos = None
        if kv_state is not None:
            intra_pos = attn_lib.row_positions(sctx.pos_offset, B, start_pos)
        return attn_lib.attn_prefill_paged_partial(
            p_attn, xn, cfg, sctx.group_eff,
            k_pages=cache["k_pages"], v_pages=cache["v_pages"],
            block_tables=sctx.block_tables, prefix_lens=sctx.lengths,
            start_pos=sctx.pos_offset + start_pos,
            intra_kv=kv_state, intra_pos=intra_pos,
            window=sctx.window, k_limit=k_limit)
    prefix_kv, prefix_pos = _resume_prefix(kv_state, cache, sctx, start_pos, B)
    return attn_lib.attn_prefill_partial(
        p_attn, xn, cfg, sctx.group_eff,
        start_pos=sctx.pos_offset + start_pos,
        prefix_kv=prefix_kv, prefix_pos=prefix_pos, window=sctx.window,
        k_limit=k_limit)


def attn_stage(p, x, start_pos, seq_state, sctx: StageCtx, cache=None):
    cfg = sctx.cfg
    xn = _n1(p, x, cfg)
    if sctx.mode == "decode":
        if "k_pages" in cache:
            partial, kv_new = attn_lib.attn_decode_paged_partial(
                p["attn"], xn, cfg, sctx.group_eff,
                k_pages=cache["k_pages"], v_pages=cache["v_pages"],
                block_tables=sctx.block_tables, lengths=sctx.lengths,
                window=sctx.window, kv_splits=sctx.kv_splits)
        else:
            partial, kv_new = attn_lib.attn_decode_partial(
                p["attn"], xn, cfg, sctx.group_eff,
                cache_k=cache["k"], cache_v=cache["v"], lengths=sctx.lengths,
                window=sctx.window, cache_pos=cache.get("pos"))
        return partial, seq_state, {"kv": kv_new}
    if sctx.mode == "encode":
        # seq_state holds the full-sequence (k, v) projected by the scheduler
        partial = attn_lib.attn_encode_partial(
            p["attn"], xn, cfg, sctx.group_eff, kv_full=seq_state)
        return partial, seq_state, {}
    partial, kv_new = _prefill_attn(p["attn"], xn, seq_state, cache, sctx,
                                    start_pos, x.shape[0])
    if seq_state is None:
        new_state = kv_new
    else:
        new_state = (jnp.concatenate([seq_state[0], kv_new[0]], axis=1),
                     jnp.concatenate([seq_state[1], kv_new[1]], axis=1))
    return partial, new_state, {"kv": kv_new}


def cross_attn_stage(p, x, start_pos, seq_state, sctx: StageCtx, cache=None):
    cfg = sctx.cfg
    xn = norm(p["norm_cross"], x, cfg.norm_type, cfg.rms_eps)
    partial = attn_lib.attn_cross_partial(
        p["cross"], xn, cfg, sctx.group_eff,
        enc_k=cache["cross_k"], enc_v=cache["cross_v"])
    return partial, seq_state, {}


def mlp_stage(p, x, start_pos, seq_state, sctx: StageCtx, cache=None):
    xn = _n2(p, x, sctx.cfg)
    return mlp_lib.mlp_partial(p["mlp"], xn, sctx.cfg.mlp_type), seq_state, {}


def moe_stage(p, x, start_pos, seq_state, sctx: StageCtx, cache=None):
    xn = _n2(p, x, sctx.cfg)
    partial, aux = moe_lib.moe_partial(
        p["moe"], xn, sctx.cfg.moe, tp=sctx.tp, expert_offset=sctx.expert_offset)
    return partial, seq_state, {"moe_aux": aux}


def hybrid_stage(p, x, start_pos, seq_state, sctx: StageCtx, cache=None):
    """hymba: parallel attention + mamba heads sharing the pre-norm input; their
    unreduced partials ADD, so the fused block still ends in ONE all-reduce."""
    cfg = sctx.cfg
    xn = _n1(p, x, cfg)
    kv_state, ssm_state = seq_state if seq_state is not None else (None, None)
    if sctx.mode == "decode":
        if "k_pages" in cache:
            a_part, kv_new = attn_lib.attn_decode_paged_partial(
                p["attn"], xn, cfg, sctx.group_eff,
                k_pages=cache["k_pages"], v_pages=cache["v_pages"],
                block_tables=sctx.block_tables, lengths=sctx.lengths,
                window=sctx.window, kv_splits=sctx.kv_splits)
        else:
            a_part, kv_new = attn_lib.attn_decode_partial(
                p["attn"], xn, cfg, sctx.group_eff,
                cache_k=cache["k"], cache_v=cache["v"], lengths=sctx.lengths,
                window=sctx.window, cache_pos=cache.get("pos"))
        s_part, ssm_new = ssm_lib.ssm_decode_partial(
            p["ssm"], xn, cfg.ssm, cache["ssm"])
        return a_part + s_part, seq_state, {"kv": kv_new, "ssm": ssm_new}
    if ssm_state is None and cache is not None and "ssm" in cache:
        ssm_state = cache["ssm"]          # resumed chunked prefill carry
    a_part, kv_new = _prefill_attn(p["attn"], xn, kv_state, cache, sctx,
                                   start_pos, x.shape[0])
    s_part, ssm_new = ssm_lib.ssm_partial(p["ssm"], xn, cfg.ssm, ssm_state)
    if kv_state is None:
        kv_acc = kv_new
    else:
        kv_acc = (jnp.concatenate([kv_state[0], kv_new[0]], axis=1),
                  jnp.concatenate([kv_state[1], kv_new[1]], axis=1))
    return a_part + s_part, (kv_acc, ssm_new), {"kv": kv_new, "ssm": ssm_new}


def mlstm_stage(p, x, start_pos, seq_state, sctx: StageCtx, cache=None):
    cfg = sctx.cfg
    xn = _n1(p, x, cfg)
    state = seq_state
    if state is None and cache is not None and "mlstm" in cache:
        state = cache["mlstm"]            # decode, or resumed-prefill carry
    out, new_state = xlstm_lib.mlstm_partial(p["mlstm"], xn, cfg, state)
    return out, new_state, {"mlstm": new_state}


def slstm_stage(p, x, start_pos, seq_state, sctx: StageCtx, cache=None):
    cfg = sctx.cfg
    xn = _n1(p, x, cfg)
    state = seq_state
    if state is None and cache is not None and "slstm" in cache:
        state = cache["slstm"]            # decode, or resumed-prefill carry
    out, new_state = xlstm_lib.slstm_forward(p["slstm"], xn, cfg, state)
    return out, new_state, {"slstm": new_state}


# --------------------------------------------------------------------------
# block registry: kind -> [(stage_fn, reduces)]
# --------------------------------------------------------------------------

BLOCK_STAGES = {
    BLOCK_ATTN_MLP: ((attn_stage, True), (mlp_stage, True)),
    BLOCK_ATTN_MOE: ((attn_stage, True), (moe_stage, True)),
    BLOCK_HYBRID: ((hybrid_stage, True), (mlp_stage, True)),
    BLOCK_MLSTM: ((mlstm_stage, True),),
    BLOCK_SLSTM: ((slstm_stage, False),),      # replicated weights: NO collective
    "dec_block": ((attn_stage, True), (cross_attn_stage, True), (mlp_stage, True)),
}


def pattern_all_reduces(pattern) -> bool:
    """True when every stage of every block kind in ``pattern`` ends in a TP
    all-reduce — the precondition for the ladder-residual wiring
    (core/iso.run_stack_decode_ladder): a non-reducing stage (sLSTM) has no
    collective to lag behind the next stage's compute, so the one-stage
    residual lag would change the function for no overlap win."""
    return all(r for kind in pattern for _, r in BLOCK_STAGES[kind])


# --------------------------------------------------------------------------
# per-layer param init
# --------------------------------------------------------------------------

def init_block_params(key, cfg: ModelConfig, kind: str, layout, tp: int,
                      dtype=jnp.bfloat16, cross: bool = False) -> Dict:
    import jax
    from repro.layers.norms import init_norm
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"norm1": init_norm(cfg.d_model, cfg.norm_type)}
    if kind in (BLOCK_ATTN_MLP, BLOCK_ATTN_MOE, BLOCK_HYBRID, "dec_block"):
        p["attn"] = attn_lib.init_attention(ks[0], cfg, layout, dtype)
        p["norm2"] = init_norm(cfg.d_model, cfg.norm_type)
    if kind in (BLOCK_ATTN_MLP, "dec_block"):
        p["mlp"] = mlp_lib.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_type,
                                    tp, cfg.num_layers, dtype)
    if kind == BLOCK_ATTN_MOE:
        p["moe"] = moe_lib.init_moe(ks[2], cfg.d_model, cfg.moe, tp,
                                    cfg.num_layers, dtype)
    if kind == BLOCK_HYBRID:
        p["ssm"] = ssm_lib.init_ssm(ks[3], cfg.d_model, cfg.ssm, tp,
                                    cfg.num_layers, dtype)
        p["mlp"] = mlp_lib.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_type,
                                    tp, cfg.num_layers, dtype)
    if kind == BLOCK_MLSTM:
        p["mlstm"] = xlstm_lib.init_mlstm(ks[4], cfg, tp, dtype)
    if kind == BLOCK_SLSTM:
        p["slstm"] = xlstm_lib.init_slstm(ks[4], cfg, dtype)
    if kind == "dec_block":
        p["cross"] = attn_lib.init_attention(ks[5], cfg, layout, dtype, cross=True)
        p["norm_cross"] = init_norm(cfg.d_model, cfg.norm_type)
    return p

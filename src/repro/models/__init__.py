from repro.models.decoder import (  # noqa: F401
    init_decoder_params, prefill, decode_step, init_caches,
)

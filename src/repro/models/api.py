"""Unified model API — family dispatch used by launch/, serving/ and training/.

Every family exposes:
  init(key, cfg, tp)                          -> params
  prefill(params, cfg, ctx, iso, batch, ...)  -> dict (logits_local, caches, ...)
  decode(params, cfg, ctx, batch, caches, lengths) -> (logits_local, caches)
  make_inputs(cfg, shape, key|ShapeDtypeStruct)    -> input pytree

``batch`` input pytrees per family:
  dense/moe/hybrid/ssm : {"tokens": (B,S) int32}
  vlm                  : {"tokens": (B,S_text), "patches": (B,P,D)}   (stub ViT)
  audio                : {"frames": (B,F,D), "tokens": (B,S)}         (stub conv)
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ISOConfig, ModelConfig
from repro.core.overlap import AxisCtx
from repro.models import decoder as dec_lib
from repro.models import whisper as whisper_lib


def init_params(key, cfg: ModelConfig, tp: int, dtype=jnp.bfloat16):
    if cfg.family == "audio":
        return whisper_lib.init_whisper_params(key, cfg, tp, dtype)
    return dec_lib.init_decoder_params(key, cfg, tp, dtype)


def prefill(params, cfg: ModelConfig, ctx: AxisCtx, iso: ISOConfig,
            batch: Dict[str, Any], **kw):
    if cfg.family == "audio":
        return whisper_lib.whisper_prefill(
            params, cfg, ctx, iso, frames=batch["frames"],
            tokens=batch["tokens"], **kw)
    if cfg.family == "vlm":
        return dec_lib.prefill(params, cfg, ctx, iso, tokens=batch["tokens"],
                               extra_embeds=batch["patches"], **kw)
    return dec_lib.prefill(params, cfg, ctx, iso, tokens=batch["tokens"], **kw)


def decode_step(params, cfg: ModelConfig, ctx: AxisCtx, tokens, caches, lengths,
                unroll: bool = False, block_tables=None, decode_mask=None,
                overlap_batch: bool = False, kv_splits: int = 1,
                schedule: str = None):
    """tokens: (B,K) — K=1 plain decode, K>1 a speculative verify window
    (dense caches AND the paged path via ``block_tables``; see
    models/decoder.decode_step for the full contract).  ``kv_splits`` (static)
    selects split-KV flash-decode for the paged path; ``schedule`` picks the
    collective schedule (sequential / batch_split / cross_block / ladder /
    ladder_seq — ``overlap_batch`` is the legacy batch_split spelling)."""
    if cfg.family == "audio":
        assert block_tables is None, "paged decode does not support enc-dec"
        return whisper_lib.whisper_decode_step(params, cfg, ctx, tokens, caches,
                                               lengths, unroll=unroll)
    return dec_lib.decode_step(params, cfg, ctx, tokens, caches, lengths,
                               unroll=unroll, block_tables=block_tables,
                               decode_mask=decode_mask,
                               overlap_batch=overlap_batch,
                               kv_splits=kv_splits, schedule=schedule)


def init_caches(cfg: ModelConfig, batch: int, cache_len: int, tp: int,
                dtype=jnp.bfloat16):
    if cfg.family == "audio":
        return whisper_lib.init_whisper_caches(cfg, batch, cache_len, tp,
                                               dtype=dtype)
    return dec_lib.init_caches(cfg, batch, cache_len, tp, dtype)


def init_state_caches(cfg: ModelConfig, batch: int, tp: int,
                      dtype=jnp.bfloat16):
    """Per-slot recurrent-state caches for the paged engine: the dense caches
    minus k/v/pos (KV lives in the page pool — serving/kvcache.py)."""
    assert cfg.family != "audio", "paged engine does not support enc-dec yet"
    caches = dec_lib.init_caches(cfg, batch, 1, tp, dtype)
    return tuple({k: v for k, v in c.items() if k not in ("k", "v", "pos")}
                 for c in caches)


def make_inputs(cfg: ModelConfig, seq_len: int, global_batch: int,
                key=None, abstract: bool = False, dtype=jnp.bfloat16):
    """Concrete (random) or abstract (ShapeDtypeStruct) model inputs."""
    B, S = global_batch, seq_len

    def tok(shape):
        if abstract:
            return jax.ShapeDtypeStruct(shape, jnp.int32)
        return jax.random.randint(key, shape, 0, cfg.vocab_size, jnp.int32)

    def emb(shape):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return (jax.random.normal(key, shape, jnp.float32) * 0.1).astype(dtype)

    if cfg.family == "audio":
        return {"frames": emb((B, cfg.encoder_frames, cfg.d_model)),
                "tokens": tok((B, S))}
    if cfg.family == "vlm":
        n_p = min(cfg.num_patches, max(1, S // 2))
        return {"tokens": tok((B, S - n_p)),
                "patches": emb((B, n_p, cfg.d_model))}
    return {"tokens": tok((B, S))}

"""Decoder-stack model driver (dense / MoE / hybrid / xLSTM / VLM backbones).

All forward code is written in the *local-shard view* and expects to run inside
``jax.shard_map`` (launch/, serving/, training/ own that boundary).  With
``AxisCtx(tp_axis=None)`` the same code runs single-device (unit tests, oracles).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import (BLOCK_SLSTM, ISOConfig, ModelConfig, padded_vocab)
from repro.core.chunking import split_chunks
from repro.core.iso import run_stack_decode, run_stack_prefill
from repro.core.overlap import AxisCtx, psum_now
from repro.layers import embeddings as emb_lib
from repro.layers import ssm as ssm_lib
from repro.layers import xlstm as xlstm_lib
from repro.layers.heads import head_layout
from repro.layers.norms import init_norm, norm
from repro.layers.rope import sinusoidal_embedding
from repro.models.blocks import StageCtx, init_block_params


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def pattern_periods(cfg: ModelConfig) -> int:
    n = len(cfg.block_pattern)
    assert cfg.num_layers % n == 0, (cfg.num_layers, cfg.block_pattern)
    return cfg.num_layers // n


def init_decoder_params(key, cfg: ModelConfig, tp: int, dtype=jnp.bfloat16) -> Dict:
    periods = pattern_periods(cfg)
    layout = head_layout(cfg.num_heads, max(cfg.num_kv_heads, 1), tp)
    k_emb, k_layers, k_norm = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "embed": emb_lib.init_embedding(k_emb, cfg, tp, dtype),
        "final_norm": init_norm(cfg.d_model, cfg.norm_type),
    }
    pos_params = []
    for i, kind in enumerate(cfg.block_pattern):
        keys = jax.random.split(jax.random.fold_in(k_layers, i), periods)
        stacked = jax.vmap(
            lambda k: init_block_params(k, cfg, kind, layout, tp, dtype))(keys)
        pos_params.append(stacked)
    params["periods"] = tuple(pos_params)
    return params


# ---------------------------------------------------------------------------
# forward helpers
# ---------------------------------------------------------------------------

def _stage_ctx(cfg: ModelConfig, ctx: AxisCtx, mode: str,
               lengths=None) -> StageCtx:
    layout = head_layout(cfg.num_heads, max(cfg.num_kv_heads, 1), ctx.tp)
    expert_offset = 0
    if cfg.moe is not None:
        e_loc = cfg.moe.padded_experts(ctx.tp) // ctx.tp
        expert_offset = ctx.axis_index() * e_loc
    return StageCtx(cfg=cfg, group_eff=layout.group_eff, tp=ctx.tp,
                    expert_offset=expert_offset, mode=mode,
                    window=cfg.sliding_window, lengths=lengths)


def embed_tokens(params, tokens, cfg: ModelConfig, ctx: AxisCtx, *, pos_offset=0):
    v_loc = params["embed"]["table"].shape[0]
    vocab_offset = ctx.axis_index() * v_loc
    e = emb_lib.embed_partial(params["embed"], tokens, vocab_offset)
    e = psum_now(e, ctx)
    if cfg.pos_type == "sinusoidal":
        S = tokens.shape[1]
        e = e + sinusoidal_embedding(S, cfg.d_model, pos_offset).astype(e.dtype)[None]
    return e


def _final(params, x, cfg):
    return norm(params["final_norm"], x, cfg.norm_type, cfg.rms_eps)


def _sinusoid_at(positions, d_model: int):
    """Sinusoidal embedding at traced per-request positions.  (B,) -> (B, D)."""
    half = d_model // 2
    inv = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# prefill (ISO lives here)
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, ctx: AxisCtx, iso: ISOConfig, *,
            tokens=None, embeds=None, extra_embeds=None,
            logits_mode: str = "all", return_cache: bool = False,
            cache_len: int = 0, remat: bool = False, unroll: bool = False,
            layer_statics=None, mode: str = "prefill",
            prefix_caches=None, pos_offset=0,
            block_tables=None, prefix_lens=None, valid_len=None,
            return_extras: bool = False) -> Dict[str, Any]:
    """Run the stack over a full prompt — or one resumed slice of it — with the
    ISO schedule.

    tokens: (B,S) int32, or embeds: (B,S,D) precomputed (audio/vlm frontends).
    extra_embeds: (B,S0,D) prepended continuous tokens (VLM patches).

    Resumed chunked prefill (paged engine): ``prefix_caches`` is a per-position
    tuple of dicts stacked over periods — attention positions carry a gathered
    ``{k, v, pos}`` prefix (padded slots, pos -1 = empty), recurrent positions
    carry their ``{ssm|mlstm|slstm}`` state — and ``pos_offset`` (static int or
    traced scalar) is the absolute position of this call's first token.  The
    call's own chunking still happens here, so ISO overlap applies within the
    resumed slice exactly as in a monolithic prefill.

    Paged resumed prefill: when ``prefix_caches`` carries page pools
    (``k_pages``/``v_pages``) instead of a gathered dense prefix, pass
    ``block_tables`` (B, MB) and ``prefix_lens`` (B,) so attention reads the
    prefix in place through the paged flash-prefill kernel.  ``valid_len``
    (traced scalar) marks how many of this call's tokens are real — the
    bucket-padded tail beyond it is masked out of attention (grant-size
    bucketing; see serving/paged_engine.py).

    Batched multi-request grants: ``pos_offset``, ``prefix_lens`` and
    ``valid_len`` may all be per-row (B,) vectors — each row is one packed
    prefill grant resuming at its own absolute position with its own paged
    prefix (0 for a fresh request) and its own real-token count.  The ISO
    chunk split is over the shared (bucket-padded) call length, so the
    overlap schedule applies to the whole packed batch at once.
    """
    if embeds is None:
        embeds = embed_tokens(params, tokens, cfg, ctx)
        if cfg.pos_type == "sinusoidal" and not (isinstance(pos_offset, int)
                                                 and pos_offset == 0):
            raise NotImplementedError(
                "resumed prefill with sinusoidal positions (traced offset)")
    if extra_embeds is not None:
        embeds = jnp.concatenate([extra_embeds.astype(embeds.dtype), embeds], axis=1)
    B, S, D = embeds.shape

    lengths = split_chunks(S, iso, cfg, tp=ctx.tp)
    ladder = cfg.residual_wiring == "ladder"
    if ladder:
        # ladder wiring supplies the overlap itself (stage k-1's reduce
        # hides behind stage k's compute); an ISO chunk interleave would
        # resolve each chunk's pending during the OTHER chunk's unit and
        # silently restore the standard wiring per chunk — single-chunk
        # schedule, always, so chunked/resumed grants stay function-equal
        lengths = [S]
    starts, acc = [], 0
    for l in lengths:
        starts.append(acc)
        acc += l
    x_chunks = []
    off = 0
    for l in lengths:
        x_chunks.append(jax.lax.slice_in_dim(embeds, off, off + l, axis=1))
        off += l

    assert layer_statics is None or prefix_caches is None
    sctx = _stage_ctx(cfg, ctx, mode)
    sctx.pos_offset = pos_offset
    sctx.block_tables = block_tables
    sctx.lengths = prefix_lens
    sctx.valid_len = valid_len
    xs_final, extras = run_stack_prefill(
        params["periods"], cfg.block_pattern, x_chunks, tuple(starts), sctx, ctx,
        layer_statics=layer_statics if prefix_caches is None else prefix_caches,
        remat=remat, unroll=unroll, ladder=ladder)
    x = jnp.concatenate(xs_final, axis=1) if len(xs_final) > 1 else xs_final[0]
    x = _final(params, x, cfg)

    out: Dict[str, Any] = {"hidden": x, "num_chunks": len(lengths),
                           "chunk_lengths": lengths}
    if logits_mode == "all":
        out["logits_local"] = emb_lib.lm_head_local(params["embed"], x)
    elif logits_mode == "last":
        out["logits_local"] = emb_lib.lm_head_local(
            params["embed"], x[:, -1:, :])
    aux = 0.0
    for ex in extras:
        if "moe_aux" in ex:
            aux = aux + jnp.sum(ex["moe_aux"])
    out["moe_aux"] = aux
    if return_cache:
        out["caches"] = _build_caches(extras, cfg, B, S, cache_len or S, ctx)
    if return_extras:
        # raw per-position extras stacked over periods: kv_k/kv_v of the S new
        # tokens + final recurrent states — the paged engine scatters these
        out["extras"] = extras
    return out


def _build_caches(extras: Sequence[Dict], cfg: ModelConfig, B: int, S: int,
                  cache_len: int, ctx: AxisCtx):
    """Convert per-position prefill extras into decode caches."""
    caches = []
    for i, kind in enumerate(cfg.block_pattern):
        ex = extras[i]
        c: Dict[str, Any] = {}
        if "kv_k" in ex:
            k, v = ex["kv_k"], ex["kv_v"]              # (Pd,B,S,H,hd)
            Pd = k.shape[0]
            eff_len = min(cache_len, cfg.sliding_window) if cfg.sliding_window \
                else cache_len
            ck = jnp.zeros((Pd, B, eff_len, k.shape[3], k.shape[4]), k.dtype)
            cv = jnp.zeros_like(ck)
            cpos = jnp.full((Pd, B, eff_len), -1, jnp.int32)
            n_keep = min(S, eff_len)
            src_k = k[:, :, S - n_keep:]
            src_v = v[:, :, S - n_keep:]
            pos_vals = jnp.arange(S - n_keep, S, dtype=jnp.int32)
            slots = pos_vals % eff_len
            ck = ck.at[:, :, slots].set(src_k)
            cv = cv.at[:, :, slots].set(src_v)
            cpos = cpos.at[:, :, slots].set(
                jnp.broadcast_to(pos_vals, (Pd, B, n_keep)))
            c.update(k=ck, v=cv, pos=cpos)
        for sk in ("ssm", "mlstm", "slstm"):
            if sk in ex:
                c[sk] = ex[sk]
        caches.append(c)
    return tuple(caches)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

DECODE_SCHEDULES = ("sequential", "batch_split", "cross_block", "ladder",
                    "ladder_seq")


def decode_step(params, cfg: ModelConfig, ctx: AxisCtx, tokens, caches,
                lengths, unroll: bool = False, block_tables=None,
                decode_mask=None, overlap_batch: bool = False,
                kv_splits: int = 1,
                schedule: str = None) -> Tuple[jnp.ndarray, Any]:
    """tokens: (B,K) int32 — K=1 plain decode, K>1 a speculative verify
    window whose token qi sits at position ``lengths[b] + qi``; lengths:
    (B,) tokens already processed.

    Paged decode (flash-decode over the page pool): caches carry
    ``k_pages``/``v_pages`` per attention position and ``block_tables``
    (B, MB) maps positions to pages; ``decode_mask`` (B,) marks the slots
    really decoding (others scatter to the scratch page).  The K-token
    window runs through the same kernel grid (see kernels/flash_decode.py)
    and scatters all K positions' KV.  ``kv_splits`` (static) runs the
    paged attention's page walk as that many sequence-parallel spans
    (split-KV flash-decode) — it rides through StageCtx into every decode
    driver, orthogonal to the schedule.

    ``schedule`` picks the collective schedule (core/iso.py):

    * ``"sequential"`` — immediate reduce per stage (run_stack_decode);
    * ``"batch_split"`` — each batch half's reduce hides behind the other
      half's compute (run_stack_decode_overlap; falls back to sequential
      at B < 2);
    * ``"cross_block"`` — deferred reduces resolve at the next stage top,
      riding the scan carry across block boundaries (token-identical to
      sequential);
    * ``"ladder"`` / ``"ladder_seq"`` — the ladder-residual driver with
      deferred / immediate collectives (run_stack_decode_ladder).

    A ladder-wired config (``cfg.residual_wiring == "ladder"``) always runs
    the ladder driver — the wiring is part of the model function — with any
    non-sequential schedule mapping to deferred collectives.  Conversely,
    forcing ``schedule="ladder"`` on a standard-wired config runs the
    REWIRED function (the overlap probe uses this as a timing proxy; never
    serve with it).  ``overlap_batch=True`` is the legacy spelling of
    ``schedule="batch_split"``.

    Returns (logits_local (B,K,V_loc), updated caches).
    """
    if schedule is None:
        schedule = "batch_split" if overlap_batch else "sequential"
    assert schedule in DECODE_SCHEDULES, schedule
    K = tokens.shape[1]
    x = embed_tokens(params, tokens, cfg, ctx)
    if cfg.pos_type == "sinusoidal":
        # embed_tokens added position-0.. sinusoids; replace with per-request pos
        base = sinusoidal_embedding(K, cfg.d_model, 0).astype(jnp.float32)[None]
        pos = lengths[:, None] + jnp.arange(K)[None]
        per_req = jax.vmap(lambda p: _sinusoid_at(p, cfg.d_model))(pos)
        x = (x.astype(jnp.float32) - base + per_req).astype(x.dtype)
    sctx = _stage_ctx(cfg, ctx, "decode", lengths=lengths)
    sctx.block_tables = block_tables
    sctx.decode_mask = decode_mask
    sctx.kv_splits = kv_splits
    if cfg.residual_wiring == "ladder" or schedule in ("ladder", "ladder_seq"):
        from repro.core.iso import run_stack_decode_ladder
        x, new_caches = run_stack_decode_ladder(
            params["periods"], cfg.block_pattern, x, caches, sctx, ctx,
            unroll=unroll,
            defer=schedule not in ("sequential", "ladder_seq"))
    elif schedule == "batch_split":
        from repro.core.iso import run_stack_decode_overlap
        x, new_caches = run_stack_decode_overlap(
            params["periods"], cfg.block_pattern, x, caches, sctx, ctx,
            unroll=unroll)
    else:
        x, new_caches = run_stack_decode(params["periods"], cfg.block_pattern,
                                         x, caches, sctx, ctx, unroll=unroll,
                                         schedule=schedule)
    x = _final(params, x, cfg)
    logits = emb_lib.lm_head_local(params["embed"], x)
    return logits, new_caches


def init_caches(cfg: ModelConfig, batch: int, cache_len: int, tp: int,
                dtype=jnp.bfloat16):
    """Empty decode caches — GLOBAL (padded) shapes; cache_specs shards the kv
    head / SSM inner dims over the model axis (local views divide by tp)."""
    periods = pattern_periods(cfg)
    layout = head_layout(cfg.num_heads, max(cfg.num_kv_heads, 1), tp)
    hd = cfg.resolved_head_dim
    eff_len = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    caches = []
    for kind in cfg.block_pattern:
        c: Dict[str, Any] = {}
        if kind in ("attn_mlp", "attn_moe", "hybrid", "dec_block"):
            hkv = layout.hkv_eff
            c["k"] = jnp.zeros((periods, batch, eff_len, hkv, hd), dtype)
            c["v"] = jnp.zeros((periods, batch, eff_len, hkv, hd), dtype)
            c["pos"] = jnp.full((periods, batch, eff_len), -1, jnp.int32)
        if kind == "hybrid":
            inner = ssm_lib.inner_dim(cfg.d_model, cfg.ssm, tp)
            c["ssm"] = ssm_lib.SSMState(
                conv=jnp.zeros((periods, batch, cfg.ssm.conv_dim - 1, inner),
                               dtype),
                h=jnp.zeros((periods, batch, inner, cfg.ssm.state_dim),
                            jnp.float32))
        if kind == "mlstm":
            # GLOBAL state: (B,H,hd_k,hd_v) — cache_specs shards hd_v over TP
            hdk = cfg.d_model // cfg.num_heads
            st = xlstm_lib.init_mlstm_state(batch, cfg.num_heads, hdk, hdk)
            c["mlstm"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (periods,) + a.shape).copy(), st)
        if kind == "slstm":
            st = xlstm_lib.init_slstm_state(batch, cfg.d_model)
            c["slstm"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (periods,) + a.shape).copy(), st)
        caches.append(c)
    return tuple(caches)


# ---------------------------------------------------------------------------
# PartitionSpecs for the shard_map boundary
# ---------------------------------------------------------------------------

def _leaf_spec(path, leaf, batch_axes) -> P:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = names[-1]
    parents = set(names[:-1])
    nd = leaf.ndim
    stacked = "periods" in str(path)         # layer-stacked leaves get leading None

    def w(spec):                             # prepend the period-stacking dim
        return P(*( (None,) + tuple(spec) )) if stacked else P(*spec)

    if "slstm" in parents:                   # sLSTM weights are fully replicated
        return w((None,) * (nd - (1 if stacked else 0)))
    if name in ("table", "head"):
        return P("model", None)
    if name in ("wq", "wk", "wv"):
        return w((None, "model", None))
    if name == "wo":
        return w(("model", None, None))
    if name in ("w_up", "w_gate", "w_down"):
        if nd - (1 if stacked else 0) == 3:  # MoE expert-stacked
            return w(("model", None, None))
        return w((None, "model")) if name != "w_down" else w(("model", None))
    if name == "router":
        return w((None, None))
    if name in ("w_v", "w_og"):              # mlstm value path: shard feature dim
        return w((None, None, "model"))
    if name == "w_out":
        if nd - (1 if stacked else 0) == 3:  # mlstm (H, hd_loc, D)
            return w((None, "model", None))
        return w(("model", None))            # ssm (inner_loc, D)
    if name in ("w_x", "w_z", "w_dt", "conv_w"):
        return w((None, "model"))
    if name in ("dt_bias", "d_skip"):
        return w(("model",))
    if name == "a_log":
        return w(("model", None))
    # everything else (norms, gates, slstm, w_b/w_c, biases): replicated
    return w((None,) * (nd - (1 if stacked else 0)))


def decoder_param_specs(params_shape, batch_axes=("data",)):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, batch_axes), params_shape)


def cache_specs(caches_shape, batch_axes=("data",), shard_batch: bool = True):
    b = batch_axes if shard_batch else None

    def spec(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        nd = leaf.ndim
        last = names[-1] if names else ""
        if last in ("k", "v", "cross_k", "cross_v"):
            return P(None, b, None, "model", None)
        if last == "pos":
            return P(None, b, None)
        if "ssm" in names:                   # SSMState leaves (P,B,*,inner_loc*)
            if nd == 4 and "conv" in str(path):
                return P(None, b, None, "model")
            return P(None, b, "model", None)
        if "mlstm" in names:
            if nd == 5:                      # c: (P,B,H,hdk,hdv_loc)
                return P(None, b, None, None, "model")
            return P(*( (None, b) + (None,) * (nd - 2) ))
        return P(*( (None, b) + (None,) * (nd - 2) ))

    return jax.tree_util.tree_map_with_path(spec, caches_shape)
